"""Hybrid-architecture example: a reduced Jamba (Mamba + attention + MoE).

Shows the token-mixer drop-in property: Mamba layers sit where attention
would, MoE sits where FFN would — the stack is *pure config*. Trains the
reduced jamba family variant and then decodes with its O(1) recurrent state.

Run: PYTHONPATH=src python examples/hybrid_jamba.py
"""

import jax
import numpy as np

from repro.configs import registry
from repro.core.config import config_for_function
from repro.core.module import functional
from repro.inference.engine import InferenceEngine
from repro.trainer import optimizers as opt_lib
from repro.trainer.trainer import SpmdTrainer


def main():
    spec = registry.get_spec("jamba-1.5-large-398b")
    model_cfg = spec.make_smoke()  # same family: mamba+attn+MoE pattern
    vocab = model_cfg.decoder.vocab_size

    trainer_cfg = SpmdTrainer.default_config().set(
        name="trainer", model=model_cfg, max_steps=50, log_every_n=25)
    trainer_cfg.input.set(task="lm", vocab_size=vocab, seq_len=32,
                          global_batch_size=8)
    trainer_cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=3e-3)
    trainer = trainer_cfg.instantiate()
    result = trainer.run()
    print(f"[jamba] hybrid params={result['num_params']:,} "
          f"loss {result['history'][0]['loss']:.3f} -> "
          f"{result['final']['loss']:.3f} "
          f"(includes MoE aux={result['final']['aux_loss']:.4f})")

    # Decode: mamba conv/ssm states + attention KV cache in one opaque tree.
    params = jax.device_get(result["state"]["params"])
    engine = InferenceEngine.default_config().set(
        name="engine", model=model_cfg, max_len=64, slots=2).instantiate()
    engine.load(params)
    prompts = np.random.default_rng(0).integers(0, vocab, size=(2, 8))
    tokens, metrics = engine.generate(prompts, max_new_tokens=8)
    print(f"[jamba] decoded {tokens.shape} tokens, "
          f"tpot={metrics['tpot_s']*1e3:.2f}ms")

    cache = engine.init_cache(2)
    leaves = jax.tree_util.tree_flatten_with_path(cache)[0]
    kinds = sorted({jax.tree_util.keystr(p).split("'")[-2] for p, _ in leaves})
    print(f"[jamba] heterogeneous decode state leaves: {kinds}")
    print("[jamba] OK")


if __name__ == "__main__":
    main()
