"""Serving example: the paged streaming gateway over a trained checkpoint.

Trains a small LM briefly, then serves a mixed queue of requests through the
full serving subsystem (paper §6 grown to serving scale): paged KV cache
(config knob on attention, §4.2), iteration-level scheduler with chunked
prefill, and the streaming gateway with per-request sampling params —
reporting p50/p99 TTFT / TPOT, tokens/s, and KV-page utilization.

Run: PYTHONPATH=src python examples/serve_llm.py
"""

import numpy as np
import jax

from repro.configs import common as c
from repro.core.config import config_for_function
from repro.inference.engine import InferenceEngine
from repro.serving import SamplingParams, ServingGateway
from repro.trainer import optimizers as opt_lib
from repro.trainer.trainer import SpmdTrainer

MAX_LEN = 64
SLOTS = 4
PAGE_SIZE = 8


def build_model(vocab=64, dim=64):
    attn = c.attention_cfg(num_heads=4, num_kv_heads=2, rope_theta=10000.0)
    # The serving subsystem is config-assembled (§4.2): the SAME modules
    # train dense and serve paged — one knob, no model change. Half the
    # dense engine's full-residency pages: paging pressure is the point.
    attn.set(kv_cache_layout="paged", page_size=PAGE_SIZE,
             num_pages=1 + SLOTS * (MAX_LEN // PAGE_SIZE) // 2)
    layer = c.layer_cfg(dim, attn, c.ffn_cfg(dim * 2))
    decoder = c.decoder_cfg(vocab_size=vocab, dim=dim,
                            stack=c.repeat_cfg(layer, 2, remat=None))
    return c.lm_cfg(decoder)


def main():
    model_cfg = build_model()
    trainer_cfg = SpmdTrainer.default_config().set(
        name="trainer", model=model_cfg, max_steps=40, log_every_n=20)
    trainer_cfg.input.set(task="lm", vocab_size=64, seq_len=32,
                          global_batch_size=8)
    trainer_cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=5e-3)
    trainer = trainer_cfg.instantiate()
    result = trainer.run()
    params = jax.device_get(result["state"]["params"])
    print(f"[serve] trained {result['num_params']:,} params, "
          f"final loss {result['final']['loss']:.3f}")

    # Same modules, now serving (unified train/inference).
    engine_cfg = InferenceEngine.default_config().set(
        name="engine", model=model_cfg, max_len=MAX_LEN, slots=SLOTS)
    engine = engine_cfg.instantiate()
    engine.load(params)

    gateway = ServingGateway(engine, prefill_chunk=8)
    rng = np.random.default_rng(0)

    # Non-blocking submission: mixed prompt lengths, mixed sampling params,
    # two priority classes. Nothing runs until the gateway is driven.
    rids = []
    for i in range(10):
        prompt = rng.integers(0, 64, size=(int(rng.integers(4, 24)),))
        rids.append(gateway.submit(
            prompt,
            sampling=SamplingParams(
                max_new_tokens=int(rng.integers(4, 12)),
                temperature=0.7 if i % 3 == 0 else 0.0,
                top_k=8 if i % 3 == 0 else 0),
            priority=int(i % 2)))

    # Token-level streaming for the first request: tokens arrive while the
    # other nine requests make progress on the same scheduler iterations.
    streamed = []
    for tok in gateway.stream(rids[0]):
        streamed.append(tok)
    print(f"[serve] request {rids[0]} streamed tokens: {streamed}")

    # Drain the rest and report serving telemetry.
    results = gateway.drain()
    m = gateway.metrics()
    print(f"[serve] served {m['completed']} requests on {SLOTS} slots "
          f"(paged KV: {engine.config.model.decoder.stack.layer.self_attention.num_pages} "
          f"pages x {PAGE_SIZE} tokens, chunked prefill, "
          f"preemptions={m['preemptions']})")
    print(f"[serve] TTFT p50={m['ttft_p50_s'] * 1e3:.1f}ms "
          f"p99={m['ttft_p99_s'] * 1e3:.1f}ms  "
          f"TPOT p50={m['tpot_p50_s'] * 1e3:.2f}ms "
          f"p99={m['tpot_p99_s'] * 1e3:.2f}ms  "
          f"throughput={m['tokens_per_s']:.0f} tok/s")
    lens = sorted(len(r.tokens) for r in results.values())
    print(f"[serve] output lengths: {lens}")

    # Plain batched generation still works on the paged engine (identity
    # tables would need full residency, so use a dense engine for the
    # apples-to-apples Table-4 numbers).
    tokens, metrics = engine.generate(
        rng.integers(0, 64, size=(2, 8)), max_new_tokens=8)
    print(f"[serve] batched generate on the paged engine: {tokens.shape} "
          f"ttft={metrics['ttft_s'] * 1e3:.1f}ms")
    print("[serve] OK")


if __name__ == "__main__":
    main()
