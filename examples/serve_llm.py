"""Serving example: continuous batching over a trained checkpoint (§6).

Trains a small LM briefly, then serves a mixed queue of requests through the
slot-scheduled engine, reporting TTFT / TPOT / throughput (paper Table 4's
metrics).

Run: PYTHONPATH=src python examples/serve_llm.py
"""

import numpy as np
import jax

from repro.configs import common as c
from repro.core.config import config_for_function
from repro.inference.engine import InferenceEngine, Request
from repro.trainer import optimizers as opt_lib
from repro.trainer.trainer import SpmdTrainer


def build_model(vocab=64, dim=64):
    attn = c.attention_cfg(num_heads=4, num_kv_heads=2, rope_theta=10000.0)
    attn.set(impl="ref")
    layer = c.layer_cfg(dim, attn, c.ffn_cfg(dim * 2))
    decoder = c.decoder_cfg(vocab_size=vocab, dim=dim,
                            stack=c.repeat_cfg(layer, 2, remat=None))
    return c.lm_cfg(decoder)


def main():
    model_cfg = build_model()
    trainer_cfg = SpmdTrainer.default_config().set(
        name="trainer", model=model_cfg, max_steps=40, log_every_n=20)
    trainer_cfg.input.set(task="lm", vocab_size=64, seq_len=32,
                          global_batch_size=8)
    trainer_cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=5e-3)
    trainer = trainer_cfg.instantiate()
    result = trainer.run()
    params = jax.device_get(result["state"]["params"])
    print(f"[serve] trained {result['num_params']:,} params, "
          f"final loss {result['final']['loss']:.3f}")

    # Same modules, now serving (unified train/inference).
    engine_cfg = InferenceEngine.default_config().set(
        name="engine", model=model_cfg, max_len=64, slots=4)
    engine = engine_cfg.instantiate()
    engine.load(params)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 64, size=(10, 8))
    requests = [Request(request_id=i, prompt=prompts[i],
                        max_new_tokens=int(rng.integers(4, 12)))
                for i in range(10)]
    results = engine.serve(requests)
    ttfts = [r.ttft_s for r in results]
    tpots = [r.tpot_s for r in results if r.tpot_s > 0]
    print(f"[serve] served {len(results)} requests on "
          f"{engine_cfg.slots} slots (continuous batching)")
    print(f"[serve] TTFT mean={np.mean(ttfts)*1e3:.1f}ms  "
          f"TPOT mean={np.mean(tpots)*1e3:.2f}ms")

    # Plain batched generation for throughput (Fig. 5's metric).
    tokens, metrics = engine.generate(prompts[:4], max_new_tokens=16)
    print(f"[serve] batched throughput={metrics['throughput_tok_s']:.0f} tok/s "
          f"ttft={metrics['ttft_s']*1e3:.1f}ms tpot={metrics['tpot_s']*1e3:.2f}ms")
    print("[serve] OK")


if __name__ == "__main__":
    main()
