"""Quickstart: the paper's developer experience in ~60 lines.

1. Compose a model from the layer library (hierarchical configs, §4.1).
2. Integrate MoE into it with the famous ~10-line replace_config traversal —
   zero changes to any layer or model code (§2.1).
3. Swap the RoPE variant the same way.
4. Train it with the SpmdTrainer.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp

from repro.configs import common as c
from repro.core.config import config_for_function, replace_config
from repro.layers import FeedForward
from repro.layers.moe import MoELayer
from repro.layers.rope import LinearScaledRotaryEmbedding, RotaryEmbedding
from repro.trainer import optimizers as opt_lib
from repro.trainer.trainer import SpmdTrainer


def main():
    # --- 1. compose a small transformer LM entirely from configs ----------
    attn = c.attention_cfg(num_heads=4, num_kv_heads=2, rope_theta=10000.0)
    layer = c.layer_cfg(64, attn, c.ffn_cfg(128))
    decoder = c.decoder_cfg(vocab_size=64, dim=64,
                            stack=c.repeat_cfg(layer, 2, remat=None))
    model = c.lm_cfg(decoder)

    trainer_cfg = SpmdTrainer.default_config().set(
        name="trainer", model=model, max_steps=60, log_every_n=20, seed=0)
    trainer_cfg.input.set(task="lm", vocab_size=64, seq_len=32,
                          global_batch_size=8)
    trainer_cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=5e-3)

    # --- 2. THE paper demo: drop-in MoE via config traversal --------------
    n = replace_config(
        trainer_cfg,
        target=FeedForward,
        new_cfg=MoELayer.default_config().set(num_experts=4, top_k=2),
        propagate=("input_dim", "hidden_dim"),
    )
    print(f"[quickstart] replaced {n} FFN template(s) with MoE "
          "(0 LoC changed in any layer/model)")

    # --- 3. swap the RoPE variant the same way ------------------------------
    replace_config(
        trainer_cfg,
        target=RotaryEmbedding,
        new_cfg=LinearScaledRotaryEmbedding.default_config().set(
            scaling_factor=2.0),
        propagate=("dim", "theta"),
    )

    # --- 4. train ------------------------------------------------------------
    trainer = trainer_cfg.instantiate()
    result = trainer.run()
    first, last = result["history"][0], result["history"][-1]
    print(f"[quickstart] params={result['num_params']:,}")
    print(f"[quickstart] loss {first['loss']:.3f} -> {last['loss']:.3f} "
          f"(aux {last['aux_loss']:.4f})")
    assert last["loss"] < first["loss"], "training should reduce loss"
    print("[quickstart] OK")


if __name__ == "__main__":
    main()
