from repro.inference.engine import GenerationResult, InferenceEngine, Request
