"""Inference engine: unified training/inference via module reuse (paper §6).

The engine reuses the exact training modules — the KV cache is an
encapsulated component of each token mixer, so the engine only moves opaque
state pytrees. Supports:

  * prefill + single-token decode (``serve_step``): the function the decode
    dry-run shapes lower,
  * batched generation with greedy/temperature sampling,
  * continuous batching: a slot-based scheduler that admits new requests into
    finished slots mid-flight (Orca-style, §6) without recompiling.

TTFT/TPOT benchmarks (paper Table 4) run on this engine.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import REQUIRED, ConfigBase, Required, config_class
from repro.core.module import Module, functional, no_context

__all__ = ["InferenceEngine", "Request", "GenerationResult"]


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    arrival_time: float = 0.0


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    tokens: List[int]
    ttft_s: float = 0.0  # time to first token
    tpot_s: float = 0.0  # mean time per output token


class InferenceEngine(Module):
    @config_class
    class Config(Module.Config):
        model: Required[ConfigBase] = REQUIRED  # a CausalLM config
        max_len: Required[int] = REQUIRED
        slots: int = 8  # concurrent sequences (continuous batching width)
        eos_token: int = -1  # -1: never stop early
        pad_token: int = 0

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._add_child("model", cfg.model)
        self._params = None
        self._jit_prefill = None
        self._jit_decode = None

    # ----------------------------------------------------------------- setup

    @no_context
    def load(self, params: Any):
        self._params = params

    @no_context
    def init_cache(self, batch_size: Optional[int] = None):
        cfg = self.config
        B = batch_size or cfg.slots
        cache, _ = functional(self.model, state=self._params,
                              inputs=(B, cfg.max_len), method="init_states")
        return cache

    # ---------------------------------------------------------- pure serving

    @no_context
    def prefill_fn(self) -> Callable:
        """(params, cache, prompt_ids) -> (cache, last_logits)."""
        model = self.model

        def prefill(params, cache, prompt_ids):
            (cache, logits), _ = functional(
                model, state=params,
                inputs={"state": cache, "input_ids": prompt_ids},
                method="prefill")
            return cache, logits[:, -1]

        return prefill

    @no_context
    def serve_step_fn(self) -> Callable:
        """(params, cache, ids_step (B,1)) -> (cache, logits (B,V)).

        ONE new token against a full-length KV cache — the decode dry-run
        shape. Reused verbatim by generate()/continuous batching.
        """
        model = self.model

        def serve_step(params, cache, ids_step):
            (cache, logits), _ = functional(
                model, state=params,
                inputs={"state": cache, "ids_step": ids_step},
                method="extend_step")
            return cache, logits[:, -1]

        return serve_step

    # ------------------------------------------------------------ generation

    @no_context
    def generate(self, prompts: np.ndarray, *, max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Tuple[np.ndarray, Dict[str, float]]:
        """Batched generation: one prefill + N decode steps. Returns
        (tokens (B, max_new_tokens), timing metrics)."""
        assert self._params is not None, "call load() first"
        B = prompts.shape[0]
        cache = self.init_cache(B)
        prefill = jax.jit(self.prefill_fn())
        decode = jax.jit(self.serve_step_fn(), donate_argnums=(1,))

        t0 = time.perf_counter()
        cache, logits = prefill(self._params, cache, jnp.asarray(prompts))
        logits.block_until_ready()
        ttft = time.perf_counter() - t0

        key = jax.random.PRNGKey(seed)
        outs = []
        t1 = time.perf_counter()
        for step in range(max_new_tokens):
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            outs.append(nxt)
            cache, logits = decode(self._params, cache, nxt[:, None])
        jax.block_until_ready(logits)
        tpot = (time.perf_counter() - t1) / max_new_tokens
        tokens = np.asarray(jnp.stack(outs, axis=1))
        return tokens, {"ttft_s": ttft, "tpot_s": tpot,
                        "throughput_tok_s": B * max_new_tokens /
                        max(time.perf_counter() - t1, 1e-9)}

    # ---------------------------------------------------- continuous batching

    @no_context
    def batch_axes(self):
        """Per-leaf batch-axis map: the axis where init_cache(1) and
        init_cache(slots) shapes differ. Caches are opaque pytrees; this is
        the only structural fact splicing needs."""
        cfg = self.config
        model = self.model

        def shapes(B):
            f = lambda: functional(model, state=self._params,  # noqa: E731
                                   inputs=(B, cfg.max_len), method="init_states")[0]
            return jax.eval_shape(f)

        s1, sN = shapes(1), shapes(cfg.slots)

        def axis(a, b):
            for i, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return i
            return None  # no batch axis (shared leaf)

        return jax.tree.map(axis, s1, sN)

    @no_context
    def serve(self, requests: List[Request]) -> List[GenerationResult]:
        """Slot-based continuous batching.

        All slots decode together each step; finished slots are refilled from
        the queue by prefilling into a fresh single-slot cache and splicing it
        into the batch cache on each leaf's batch axis. Per-slot cache
        positions ("pos"/"index") make mid-flight admission exact. Model code
        is untouched — the cache is an opaque pytree (paper §6).
        """
        assert self._params is not None
        cfg = self.config
        S = cfg.slots
        queue = sorted(requests, key=lambda r: r.arrival_time)
        results: Dict[int, GenerationResult] = {}

        prefill1 = jax.jit(self.prefill_fn())
        decode = jax.jit(self.serve_step_fn(), donate_argnums=(1,))

        batch_cache = self.init_cache(S)
        axes = self.batch_axes()
        slot_req: List[Optional[Request]] = [None] * S
        slot_tokens: List[List[int]] = [[] for _ in range(S)]
        slot_t0: List[float] = [0.0] * S

        def splice(bc, c1, ax, slot):
            if ax is None:
                return bc
            src = jnp.take(c1, 0, axis=ax)
            idx = tuple([slice(None)] * ax + [slot])
            return bc.at[idx].set(src)

        def admit(slot: int, req: Request):
            nonlocal batch_cache
            c1 = self.init_cache(1)
            t0 = time.perf_counter()
            c1, logits1 = prefill1(self._params, c1, jnp.asarray(req.prompt[None]))
            ttft = time.perf_counter() - t0
            results[req.request_id] = GenerationResult(req.request_id, [], ttft_s=ttft)
            batch_cache = jax.tree.map(
                lambda bc, c, ax: splice(bc, c, ax, slot), batch_cache, c1, axes)
            slot_req[slot] = req
            slot_tokens[slot] = [int(jnp.argmax(logits1[0]))]
            slot_t0[slot] = time.perf_counter()

        while queue or any(r is not None for r in slot_req):
            # Admit into free slots.
            for s in range(S):
                if slot_req[s] is None and queue:
                    admit(s, queue.pop(0))
            active = [s for s in range(S) if slot_req[s] is not None]
            if not active:
                break
            last = jnp.asarray(
                [[slot_tokens[s][-1] if slot_req[s] is not None else cfg.pad_token]
                 for s in range(S)], jnp.int32)
            batch_cache, logits = decode(self._params, batch_cache, last)
            nxt = np.asarray(jnp.argmax(logits, axis=-1))
            for s in active:
                req = slot_req[s]
                slot_tokens[s].append(int(nxt[s]))
                done = (len(slot_tokens[s]) >= req.max_new_tokens or
                        int(nxt[s]) == cfg.eos_token)
                if done:
                    res = results[req.request_id]
                    res.tokens = slot_tokens[s][:req.max_new_tokens]
                    dt = time.perf_counter() - slot_t0[s]
                    res.tpot_s = dt / max(len(res.tokens) - 1, 1)
                    slot_req[s] = None
        return [results[r.request_id] for r in requests]
