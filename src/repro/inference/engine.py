"""Inference engine: unified training/inference via module reuse (paper §6).

The engine reuses the exact training modules — the KV cache is an
encapsulated component of each token mixer, so the engine only moves opaque
state pytrees. Supports:

  * prefill + single-token decode (``serve_step``): the function the decode
    dry-run shapes lower,
  * batched generation as ONE device program: a jitted ``lax.scan`` decode
    loop with fused on-device greedy/temperature sampling (PRNG key threaded
    through the carry) — a 256-token generation costs one dispatch + one
    host sync instead of 256,
  * continuous batching: a slot-based scheduler that admits new requests into
    finished slots mid-flight (Orca-style, §6). Admission is a single jitted
    ``admit_fn`` that prefills straight into the batch cache via per-leaf
    ``dynamic_update_slice`` on precomputed batch axes; prompts are padded to
    power-of-two length buckets so the number of compiles is O(log max_len),
    and the ``length`` argument keeps bucket padding out of every mixer's
    cache/recurrent state.

All jitted callables are built once and cached on the engine, so repeated
``generate``/``serve`` calls hit the jit trace cache instead of recompiling.
TTFT/TPOT benchmarks (paper Table 4) run on this engine; the decode-step
attention kernel is selected by ``MultiheadAttention.Config.decode_impl``
("ref" | "flash_decode") — a config knob, not a code change (§4.2).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import REQUIRED, ConfigBase, Required, config_class
from repro.core.module import Module, functional, no_context

__all__ = ["InferenceEngine", "Request", "GenerationResult"]

# Smallest admission bucket: prompts pad up to the next power of two >= this.
_MIN_BUCKET = 8


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    arrival_time: float = 0.0


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    tokens: List[int]
    ttft_s: float = 0.0  # time to first token
    tpot_s: float = 0.0  # mean time per output token


class InferenceEngine(Module):
    @config_class
    class Config(Module.Config):
        model: Required[ConfigBase] = REQUIRED  # a CausalLM config
        max_len: Required[int] = REQUIRED
        slots: int = 8  # concurrent sequences (continuous batching width)
        eos_token: int = -1  # -1: never stop early
        pad_token: int = 0

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._add_child("model", cfg.model)
        self._params = None
        # Jitted callables, built once per engine: repeated generate()/serve()
        # calls reuse the jit trace/compile caches instead of recompiling.
        self._jit_fns: Dict[Any, Callable] = {}

    # ----------------------------------------------------------------- setup

    @no_context
    def load(self, params: Any):
        self._params = params

    @no_context
    def init_cache(self, batch_size: Optional[int] = None):
        cfg = self.config
        B = batch_size or cfg.slots
        cache, _ = functional(self.model, state=self._params,
                              inputs=(B, cfg.max_len), method="init_states")
        return cache

    # ---------------------------------------------------------- pure serving

    @no_context
    def prefill_fn(self) -> Callable:
        """(params, cache, prompt_ids) -> (cache, last_logits)."""
        model = self.model

        def prefill(params, cache, prompt_ids):
            (cache, logits), _ = functional(
                model, state=params,
                inputs={"state": cache, "input_ids": prompt_ids},
                method="prefill")
            return cache, logits[:, -1]

        return prefill

    @no_context
    def serve_step_fn(self) -> Callable:
        """(params, cache, ids_step (B,1)) -> (cache, logits (B,V)).

        ONE new token against a full-length KV cache — the decode dry-run
        shape. The scan decode loop and continuous batching both build on it.
        """
        model = self.model

        def serve_step(params, cache, ids_step):
            (cache, logits), _ = functional(
                model, state=params,
                inputs={"state": cache, "ids_step": ids_step},
                method="extend_step")
            return cache, logits[:, -1]

        return serve_step

    def _jit(self, key, builder, **jit_kwargs) -> Callable:
        if key not in self._jit_fns:
            self._jit_fns[key] = jax.jit(builder(), **jit_kwargs)
        return self._jit_fns[key]

    # ------------------------------------------------------------ generation

    @no_context
    def _decode_loop_fn(self, max_new_tokens: int, greedy: bool) -> Callable:
        """(params, cache, logits, key, temperature) -> (cache, tokens (B,N)).

        The whole decode phase as one device program: sample (argmax or
        categorical at ``temperature``) fused with the model's extend_step
        inside a ``lax.scan`` — no per-token host round trip.
        """
        serve_step = self.serve_step_fn()

        def loop(params, cache, logits, key, temperature):
            def sample(logits, key):
                if greedy:
                    return jnp.argmax(logits, axis=-1), key
                key, sub = jax.random.split(key)
                return jax.random.categorical(
                    sub, logits / temperature, axis=-1), key

            def body(carry, _):
                cache, logits, key = carry
                nxt, key = sample(logits, key)
                cache, logits = serve_step(params, cache, nxt[:, None])
                return (cache, logits, key), nxt

            # N-1 scan steps + one final sample: the last sampled token
            # needs no extend_step, so no model forward is wasted on it.
            (cache, logits, key), toks = jax.lax.scan(
                body, (cache, logits, key), None, length=max_new_tokens - 1)
            last, _ = sample(logits, key)
            return cache, jnp.concatenate(
                [jnp.swapaxes(toks, 0, 1), last[:, None]], axis=1)

        return loop

    @no_context
    def generate(self, prompts: np.ndarray, *, max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Tuple[np.ndarray, Dict[str, float]]:
        """Batched generation: one prefill dispatch + one scan-decode
        dispatch. Returns (tokens (B, max_new_tokens), timing metrics)."""
        assert self._params is not None, "call load() first"
        B = prompts.shape[0]
        cache = self.init_cache(B)
        prefill = self._jit("prefill", self.prefill_fn)
        greedy = temperature <= 0
        loop = self._jit(
            ("decode_loop", max_new_tokens, greedy),
            lambda: self._decode_loop_fn(max_new_tokens, greedy),
            donate_argnums=(1,))

        t0 = time.perf_counter()
        cache, logits = prefill(self._params, cache, jnp.asarray(prompts))
        logits.block_until_ready()
        ttft = time.perf_counter() - t0

        key = jax.random.PRNGKey(seed)
        temp = jnp.asarray(temperature if not greedy else 1.0, jnp.float32)
        t1 = time.perf_counter()
        cache, tokens = loop(self._params, cache, logits, key, temp)
        tokens.block_until_ready()
        dt = time.perf_counter() - t1
        tpot = dt / max_new_tokens
        return np.asarray(tokens), {
            "ttft_s": ttft, "tpot_s": tpot,
            "throughput_tok_s": B * max_new_tokens / max(dt, 1e-9)}

    # ---------------------------------------------------- continuous batching

    @no_context
    def batch_axes(self):
        """Per-leaf batch-axis map: the axis where init_cache(1) and
        init_cache(slots) shapes differ (-1 = no batch axis / shared leaf).
        Caches are opaque pytrees; this is the only structural fact
        admission splicing needs."""
        cfg = self.config
        model = self.model

        def shapes(B):
            f = lambda: functional(model, state=self._params,  # noqa: E731
                                   inputs=(B, cfg.max_len), method="init_states")[0]
            return jax.eval_shape(f)

        s1, sN = shapes(1), shapes(cfg.slots)

        def axis(a, b):
            for i, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return i
            return -1  # no batch axis (shared leaf)

        return jax.tree.map(axis, s1, sN)

    def _bucket_len(self, n: int) -> int:
        """Power-of-two admission buckets: prompts of any length compile
        O(log n) prefill shapes. Buckets may exceed max_len — the ring cache
        keeps the last T valid tokens (and recurrent mixers consume the full
        prompt), same as batched generation with an over-long prompt."""
        b = _MIN_BUCKET
        while b < n:
            b *= 2
        return b

    @no_context
    def _admit_fn(self) -> Callable:
        """(params, batch_cache, padded_prompt (1,L), prompt_len, slot)
        -> (batch_cache, first_token).

        One jitted program per bucket L: prefills a fresh single-slot cache
        (bucket padding excluded via ``length``) and splices every leaf into
        the batch cache with ``dynamic_update_slice`` on its batch axis.
        ``prompt_len`` and ``slot`` are traced scalars — admitting into a
        different slot or with a different true length never recompiles.
        """
        cfg = self.config
        model = self.model
        axes = self.batch_axes()

        def admit(params, batch_cache, padded_prompt, prompt_len, slot):
            c1, _ = functional(model, state=params,
                               inputs=(1, cfg.max_len), method="init_states")
            (c1, logits), _ = functional(
                model, state=params,
                inputs={"state": c1, "input_ids": padded_prompt,
                        "length": prompt_len},
                method="prefill")
            last = jax.lax.dynamic_index_in_dim(
                logits, prompt_len - 1, axis=1, keepdims=False)  # (1, V)

            def splice(bc, c, ax):
                if ax < 0:
                    return bc
                return jax.lax.dynamic_update_slice_in_dim(
                    bc, c.astype(bc.dtype), slot, axis=ax)

            new_cache = jax.tree.map(splice, batch_cache, c1, axes)
            return new_cache, jnp.argmax(last[0], axis=-1).astype(jnp.int32)

        return admit

    @no_context
    def _serve_decode_fn(self) -> Callable:
        """(params, cache, ids_step (S,1)) -> (cache, next_tokens (S,)).

        Greedy argmax fused into the step so the host transfers S ints per
        step instead of the full (S, V) logits."""
        serve_step = self.serve_step_fn()

        def decode(params, cache, ids_step):
            cache, logits = serve_step(params, cache, ids_step)
            return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)

        return decode

    @no_context
    def serve(self, requests: List[Request]) -> List[GenerationResult]:
        """Slot-based continuous batching.

        All slots decode together each step; finished slots are refilled from
        the queue via the jitted bucketed ``admit_fn`` (no recompiles once
        the touched buckets are warm). Per-slot cache positions
        ("pos"/"index") make mid-flight admission exact. Model code is
        untouched — the cache is an opaque pytree (paper §6).

        Serving decodes greedily: ``Request.temperature`` is currently
        ignored (per-slot sampling inside the fused decode step is future
        work); use :meth:`generate` for temperature sampling.
        """
        assert self._params is not None
        cfg = self.config
        S = cfg.slots
        queue = sorted(requests, key=lambda r: r.arrival_time)
        results: Dict[int, GenerationResult] = {}

        admit_fn = self._jit("admit", self._admit_fn, donate_argnums=(1,))
        decode = self._jit("serve_decode", self._serve_decode_fn,
                           donate_argnums=(1,))
        params = self._params

        batch_cache = self.init_cache(S)
        slot_req: List[Optional[Request]] = [None] * S
        slot_tokens: List[List[int]] = [[] for _ in range(S)]
        slot_t0: List[float] = [0.0] * S

        def admit(slot: int, req: Request):
            nonlocal batch_cache
            n = len(req.prompt)
            L = self._bucket_len(n)
            padded = np.full((1, L), cfg.pad_token, np.int32)
            padded[0, :n] = req.prompt
            t0 = time.perf_counter()
            batch_cache, tok0 = admit_fn(
                params, batch_cache, jnp.asarray(padded),
                jnp.asarray(n, jnp.int32), jnp.asarray(slot, jnp.int32))
            tok0 = int(tok0)
            ttft = time.perf_counter() - t0
            results[req.request_id] = GenerationResult(req.request_id, [],
                                                       ttft_s=ttft)
            if tok0 == cfg.eos_token or req.max_new_tokens <= 1:
                # Done at the first token: don't occupy a decode slot.
                results[req.request_id].tokens = [tok0]
                return
            slot_req[slot] = req
            slot_tokens[slot] = [tok0]
            slot_t0[slot] = time.perf_counter()

        while queue or any(r is not None for r in slot_req):
            # Admit into free slots (an admission that finishes at its
            # first token leaves the slot free for the next request).
            for s in range(S):
                while slot_req[s] is None and queue:
                    admit(s, queue.pop(0))
            active = [s for s in range(S) if slot_req[s] is not None]
            if not active:
                break
            last = np.asarray(
                [[slot_tokens[s][-1] if slot_req[s] is not None else cfg.pad_token]
                 for s in range(S)], np.int32)
            batch_cache, nxt_dev = decode(params, batch_cache, jnp.asarray(last))
            nxt = np.asarray(nxt_dev)
            for s in active:
                req = slot_req[s]
                slot_tokens[s].append(int(nxt[s]))
                done = (len(slot_tokens[s]) >= req.max_new_tokens or
                        int(nxt[s]) == cfg.eos_token)
                if done:
                    res = results[req.request_id]
                    res.tokens = slot_tokens[s][:req.max_new_tokens]
                    dt = time.perf_counter() - slot_t0[s]
                    res.tpot_s = dt / max(len(res.tokens) - 1, 1)
                    slot_req[s] = None
        return [results[r.request_id] for r in requests]
