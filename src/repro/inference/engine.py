"""Inference engine: unified training/inference via module reuse (paper §6).

The engine reuses the exact training modules — the KV cache is an
encapsulated component of each token mixer, so the engine only moves opaque
state pytrees. Supports:

  * prefill + single-token decode (``serve_step``): the function the decode
    dry-run shapes lower,
  * batched generation as ONE device program: a jitted ``lax.scan`` decode
    loop with fused on-device greedy/temperature sampling (PRNG key threaded
    through the carry) — a 256-token generation costs one dispatch + one
    host sync instead of 256,
  * continuous batching: a slot-based scheduler that admits new requests into
    finished slots mid-flight (Orca-style, §6). Admission is a single jitted
    ``admit_fn`` that prefills straight into the batch cache via per-leaf
    ``dynamic_update_slice`` on precomputed batch axes; prompts are padded to
    power-of-two length buckets so the number of compiles is O(log max_len),
    and the ``length`` argument keeps bucket padding out of every mixer's
    cache/recurrent state.

All jitted callables are built once and cached on the engine, so repeated
``generate``/``serve`` calls hit the jit trace cache instead of recompiling.
TTFT/TPOT benchmarks (paper Table 4) run on this engine; the decode-step
attention kernel is resolved by the kernel registry from each layer's
``KernelConfig`` (op ``attention.decode``: Pallas flash-decode where capable,
ref otherwise) — a config knob, not a code change (§4.2).

The paged serving subsystem (``repro.serving``: page allocator, chunked
prefill scheduler, streaming gateway) layers on this engine's builders;
models configured with ``kv_cache_layout="paged"`` route ``serve()``
through it automatically.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import REQUIRED, ConfigBase, Required, config_class, visit_config
from repro.core.module import Module, functional, no_context

__all__ = ["InferenceEngine", "Request", "GenerationResult", "sample_tokens",
           "sample_one", "greedy_verify"]

# Smallest admission bucket: prompts pad up to the next power of two >= this.
_MIN_BUCKET = 8


def sample_tokens(logits: jax.Array, key: jax.Array, temperatures: jax.Array,
                  top_ks: jax.Array) -> jax.Array:
    """Per-slot sampling rule of the fused decode step.

    ``logits`` (S, V); ``temperatures`` (S,) with <= 0 meaning exact greedy
    argmax; ``top_ks`` (S,) with <= 0 meaning no top-k filtering. Rows are
    sampled with independent keys split from ``key`` so mixed greedy/sampled
    requests batch into one program.
    """
    S, V = logits.shape
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k = jnp.where(top_ks > 0, jnp.minimum(top_ks, V), V)  # (S,)
    sorted_desc = -jnp.sort(-logits, axis=-1)
    thresh = jnp.take_along_axis(sorted_desc, (k - 1)[:, None], axis=-1)
    filtered = jnp.where(logits >= thresh, logits, -jnp.inf)
    temps = jnp.where(temperatures > 0, temperatures, 1.0)[:, None]
    keys = jax.random.split(key, S)
    sampled = jax.vmap(jax.random.categorical)(keys, filtered / temps)
    return jnp.where(temperatures > 0, sampled.astype(jnp.int32), greedy)


def sample_one(logits: jax.Array, key: jax.Array, temperature: float,
               top_k: int) -> Tuple[int, jax.Array]:
    """Eager single-sequence first-token sampling (prefill/admission path):
    the same rule as :func:`sample_tokens`, returning (token, new_key)."""
    key, sub = jax.random.split(key)
    tok = sample_tokens(logits[None, :], sub,
                        jnp.asarray([temperature], jnp.float32),
                        jnp.asarray([top_k], jnp.int32))
    return int(tok[0]), key


def greedy_verify(logits: jax.Array, draft: jax.Array,
                  n_draft: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Greedy speculative-decoding acceptance rule (device-side).

    ``logits`` (K+1, V) are the model's outputs over the verify window
    ``[t_last, d_1 .. d_K]`` — position i's logits are the model's
    prediction for the token *after* d_i. ``draft`` (K,) holds the
    proposed tokens (entries past ``n_draft`` are ignored). Returns
    ``(tokens, n_accept)``: ``tokens`` (K+1,) is the greedy argmax at
    every position and ``n_accept`` the length of the longest draft
    prefix the model agrees with. Committing ``tokens[:n_accept + 1]``
    — the accepted drafts plus the model's own correction/extension —
    reproduces token-by-token greedy decoding exactly: each accepted
    token is by construction the argmax given all tokens before it.
    """
    g = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k = draft.shape[0]
    ok = (g[:k] == draft) & (jnp.arange(k) < n_draft)
    n_accept = jnp.sum(jnp.cumprod(ok.astype(jnp.int32)))
    return g, n_accept.astype(jnp.int32)


@dataclasses.dataclass
class Request:
    request_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    top_k: int = 0  # 0 = no top-k filtering (only applies when sampling)
    arrival_time: float = 0.0


@dataclasses.dataclass
class GenerationResult:
    request_id: int
    tokens: List[int]
    ttft_s: float = 0.0  # time to first token
    tpot_s: float = 0.0  # mean time per output token
    # Deadline-expired: tokens holds whatever was generated before the
    # scheduler cancelled the request (possibly nothing).
    timed_out: bool = False


class InferenceEngine(Module):
    @config_class
    class Config(Module.Config):
        model: Required[ConfigBase] = REQUIRED  # a CausalLM config
        max_len: Required[int] = REQUIRED
        slots: int = 8  # concurrent sequences (continuous batching width)
        eos_token: int = -1  # -1: never stop early
        pad_token: int = 0

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._add_child("model", cfg.model)
        self._params = None
        # Jitted callables, built once per engine: repeated generate()/serve()
        # calls reuse the jit trace/compile caches instead of recompiling.
        self._jit_fns: Dict[Any, Callable] = {}

    @no_context
    def uses_paged_cache(self) -> bool:
        """True if any attention layer in the model is configured with the
        paged KV layout (serving then routes through repro.serving)."""
        found = []

        def check(_, c):
            if getattr(c, "kv_cache_layout", None) == "paged":
                found.append(True)

        visit_config(self.config.model, check)
        return bool(found)

    @no_context
    def _check_paged_generate_capacity(self, batch_size: int):
        """generate()/prefill need full-residency identity page tables; a
        pool provisioned below that (the serving configuration) would
        silently drop every KV write. Fail loudly instead."""
        cfg = self.config
        bad = []

        def check(path, c):
            if getattr(c, "kv_cache_layout", None) != "paged" \
                    or c.num_pages is None:
                return  # num_pages=None sizes the pool to full residency
            need = 1 + batch_size * -(-cfg.max_len // c.page_size)
            if c.num_pages < need:
                bad.append(f"{path}: num_pages={c.num_pages} < {need}")

        visit_config(cfg.model, check)
        if bad:
            raise ValueError(
                f"paged KV pool is below full residency for batch "
                f"{batch_size} x max_len {cfg.max_len} — generate() would "
                f"drop KV writes through unmapped page tables. Use the "
                f"serving Scheduler/Gateway (which allocates tables on "
                f"demand) or raise num_pages: {bad[:3]}")

    # ----------------------------------------------------------------- setup

    @no_context
    def load(self, params: Any):
        self._params = params

    @no_context
    def init_cache(self, batch_size: Optional[int] = None):
        cfg = self.config
        B = batch_size or cfg.slots
        cache, _ = functional(self.model, state=self._params,
                              inputs=(B, cfg.max_len), method="init_states")
        return cache

    # ---------------------------------------------------------- pure serving

    @no_context
    def prefill_fn(self) -> Callable:
        """(params, cache, prompt_ids) -> (cache, last_logits)."""
        model = self.model

        def prefill(params, cache, prompt_ids):
            (cache, logits), _ = functional(
                model, state=params,
                inputs={"state": cache, "input_ids": prompt_ids},
                method="prefill")
            return cache, logits[:, -1]

        return prefill

    @no_context
    def serve_step_fn(self) -> Callable:
        """(params, cache, ids_step (B,1)) -> (cache, logits (B,V)).

        ONE new token against a full-length KV cache — the decode dry-run
        shape. The scan decode loop and continuous batching both build on it.
        """
        model = self.model

        def serve_step(params, cache, ids_step):
            (cache, logits), _ = functional(
                model, state=params,
                inputs={"state": cache, "ids_step": ids_step},
                method="extend_step")
            return cache, logits[:, -1]

        return serve_step

    def _jit(self, key, builder, **jit_kwargs) -> Callable:
        if key not in self._jit_fns:
            self._jit_fns[key] = jax.jit(builder(), **jit_kwargs)
        return self._jit_fns[key]

    # ------------------------------------------------------------ generation

    @no_context
    def _decode_loop_fn(self, max_new_tokens: int, greedy: bool) -> Callable:
        """(params, cache, logits, key, temperature) -> (cache, tokens (B,N)).

        The whole decode phase as one device program: sample (argmax or
        categorical at ``temperature``) fused with the model's extend_step
        inside a ``lax.scan`` — no per-token host round trip.
        """
        serve_step = self.serve_step_fn()

        def loop(params, cache, logits, key, temperature):
            def sample(logits, key):
                if greedy:
                    return jnp.argmax(logits, axis=-1), key
                key, sub = jax.random.split(key)
                return jax.random.categorical(
                    sub, logits / temperature, axis=-1), key

            def body(carry, _):
                cache, logits, key = carry
                nxt, key = sample(logits, key)
                cache, logits = serve_step(params, cache, nxt[:, None])
                return (cache, logits, key), nxt

            # N-1 scan steps + one final sample: the last sampled token
            # needs no extend_step, so no model forward is wasted on it.
            (cache, logits, key), toks = jax.lax.scan(
                body, (cache, logits, key), None, length=max_new_tokens - 1)
            last, _ = sample(logits, key)
            return cache, jnp.concatenate(
                [jnp.swapaxes(toks, 0, 1), last[:, None]], axis=1)

        return loop

    @no_context
    def generate(self, prompts: np.ndarray, *, max_new_tokens: int = 16,
                 temperature: float = 0.0, seed: int = 0
                 ) -> Tuple[np.ndarray, Dict[str, float]]:
        """Batched generation: one prefill dispatch + one scan-decode
        dispatch. Returns (tokens (B, max_new_tokens), timing metrics)."""
        assert self._params is not None, "call load() first"
        B = prompts.shape[0]
        self._check_paged_generate_capacity(B)
        cache = self.init_cache(B)
        prefill = self._jit("prefill", self.prefill_fn)
        greedy = temperature <= 0
        loop = self._jit(
            ("decode_loop", max_new_tokens, greedy),
            lambda: self._decode_loop_fn(max_new_tokens, greedy),
            donate_argnums=(1,))

        t0 = time.perf_counter()
        cache, logits = prefill(self._params, cache, jnp.asarray(prompts))
        logits.block_until_ready()
        ttft = time.perf_counter() - t0

        key = jax.random.PRNGKey(seed)
        temp = jnp.asarray(temperature if not greedy else 1.0, jnp.float32)
        t1 = time.perf_counter()
        cache, tokens = loop(self._params, cache, logits, key, temp)
        tokens.block_until_ready()
        dt = time.perf_counter() - t1
        tpot = dt / max_new_tokens
        return np.asarray(tokens), {
            "ttft_s": ttft, "tpot_s": tpot,
            "throughput_tok_s": B * max_new_tokens / max(dt, 1e-9)}

    # ---------------------------------------------------- continuous batching

    @no_context
    def batch_axes(self):
        """Per-leaf batch-axis map: the axis where init_cache shapes at two
        different batch sizes differ (-1 = no batch axis / shared leaf, e.g.
        a paged KV pool of fixed ``num_pages``). Caches are opaque pytrees;
        this is the only structural fact admission splicing needs.

        Detection compares B=1 against B=max(slots, 2): comparing 1 vs 1
        (a single-slot engine) would see identical shapes everywhere and
        silently mark every leaf shared — dropping the admission splice.
        """
        cfg = self.config
        model = self.model

        def shapes(B):
            f = lambda: functional(model, state=self._params,  # noqa: E731
                                   inputs=(B, cfg.max_len), method="init_states")[0]
            return jax.eval_shape(f)

        s1, sN = shapes(1), shapes(max(cfg.slots, 2))

        def axis(a, b):
            for i, (x, y) in enumerate(zip(a.shape, b.shape)):
                if x != y:
                    return i
            return -1  # no batch axis (shared leaf)

        return jax.tree.map(axis, s1, sN)

    def _bucket_len(self, n: int) -> int:
        """Power-of-two admission buckets: prompts of any length compile
        O(log n) prefill shapes. Buckets may exceed max_len — the ring cache
        keeps the last T valid tokens (and recurrent mixers consume the full
        prompt), same as batched generation with an over-long prompt."""
        b = _MIN_BUCKET
        while b < n:
            b *= 2
        return b

    @no_context
    def _admit_fn(self) -> Callable:
        """(params, batch_cache, padded_prompt (1,L), prompt_len, slot)
        -> (batch_cache, last_logits (V,)).

        One jitted program per bucket L: prefills a fresh single-slot cache
        (bucket padding excluded via ``length``) and splices every leaf into
        the batch cache with ``dynamic_update_slice`` on its batch axis.
        ``prompt_len`` and ``slot`` are traced scalars — admitting into a
        different slot or with a different true length never recompiles.
        """
        cfg = self.config
        model = self.model
        axes = self.batch_axes()

        def admit(params, batch_cache, padded_prompt, prompt_len, slot):
            c1, _ = functional(model, state=params,
                               inputs=(1, cfg.max_len), method="init_states")
            (c1, logits), _ = functional(
                model, state=params,
                inputs={"state": c1, "input_ids": padded_prompt,
                        "length": prompt_len},
                method="prefill")
            last = jax.lax.dynamic_index_in_dim(
                logits, prompt_len - 1, axis=1, keepdims=False)  # (1, V)

            def splice(bc, c, ax):
                if ax < 0:
                    return bc
                return jax.lax.dynamic_update_slice_in_dim(
                    bc, c.astype(bc.dtype), slot, axis=ax)

            new_cache = jax.tree.map(splice, batch_cache, c1, axes)
            return new_cache, last[0]

        return admit

    @no_context
    def _serve_decode_fn(self, sampling: bool = False) -> Callable:
        """Fused decode step for continuous batching.

        ``sampling=False``: (params, cache, ids_step (S,1)) ->
        (cache, next_tokens (S,)) — greedy argmax fused into the step so the
        host transfers S ints instead of the full (S, V) logits.

        ``sampling=True``: (params, cache, ids_step, key, temperatures (S,),
        top_ks (S,), active (S,) bool) -> (cache, next_tokens, new_key) —
        per-slot temperature/top-k sampling fused on device
        (:func:`sample_tokens`); rows with temperature <= 0 stay exact
        greedy. Inactive slots keep their pre-step state: every per-slot
        cache leaf is selected back to its old value, so a slot that is
        empty or mid-chunked-prefill is not advanced by the pad token fed
        in its row. (Shared page-pool leaves pass through: an inactive
        slot's write lands in its own pages at the position its next real
        chunk overwrites before attending — or is dropped outright if that
        page is unmapped — so pools self-heal.)
        """
        serve_step = self.serve_step_fn()

        if not sampling:
            def decode(params, cache, ids_step):
                cache, logits = serve_step(params, cache, ids_step)
                return cache, jnp.argmax(logits, axis=-1).astype(jnp.int32)

            return decode

        axes = self.batch_axes()

        def decode_sampling(params, cache, ids_step, key, temperatures,
                            top_ks, active):
            new_cache, logits = serve_step(params, cache, ids_step)

            def sel(new, old, ax):
                if ax < 0:
                    return new
                shape = [1] * new.ndim
                shape[ax] = active.shape[0]
                return jnp.where(active.reshape(shape), new, old)

            new_cache = jax.tree.map(sel, new_cache, cache, axes)
            key, sub = jax.random.split(key)
            toks = sample_tokens(logits, sub, temperatures, top_ks)
            return new_cache, toks, key

        return decode_sampling

    @no_context
    def serve(self, requests: List[Request], *, seed: int = 0
              ) -> List[GenerationResult]:
        """Slot-based continuous batching.

        All slots decode together each step; finished slots are refilled from
        the queue via the jitted bucketed ``admit_fn`` (no recompiles once
        the touched buckets are warm). Per-slot cache positions
        ("pos"/"index") make mid-flight admission exact. Model code is
        untouched — the cache is an opaque pytree (paper §6).

        Per-request ``temperature``/``top_k`` are honored slot-wise inside
        the fused decode step (:func:`sample_tokens`): requests with
        temperature 0 decode exact greedy while sampled requests share the
        same batch. Models with ``kv_cache_layout="paged"`` delegate to the
        iteration-level :class:`repro.serving.Scheduler` (chunked prefill +
        page allocation — the dense slot path here would drop pool writes).
        """
        assert self._params is not None
        if self.uses_paged_cache():
            from repro.serving.scheduler import Scheduler, ServeRequest

            sched = Scheduler(self, seed=seed)
            return sched.run([
                ServeRequest(request_id=r.request_id, prompt=r.prompt,
                             max_new_tokens=r.max_new_tokens,
                             temperature=r.temperature, top_k=r.top_k,
                             arrival_time=r.arrival_time)
                for r in requests])
        cfg = self.config
        S = cfg.slots
        # Stable FCFS: ties on arrival_time (the common case for batch
        # submission) keep request order instead of Python-sort whims.
        queue = sorted(requests, key=lambda r: (r.arrival_time, r.request_id))
        results: Dict[int, GenerationResult] = {}
        key = jax.random.PRNGKey(seed)

        admit_fn = self._jit("admit", self._admit_fn, donate_argnums=(1,))
        decode = self._jit("serve_decode_sampling",
                           lambda: self._serve_decode_fn(sampling=True),
                           donate_argnums=(1,))
        params = self._params

        batch_cache = self.init_cache(S)
        slot_req: List[Optional[Request]] = [None] * S
        slot_tokens: List[List[int]] = [[] for _ in range(S)]
        slot_t0: List[float] = [0.0] * S

        def admit(slot: int, req: Request):
            nonlocal batch_cache, key
            n = len(req.prompt)
            L = self._bucket_len(n)
            padded = np.full((1, L), cfg.pad_token, np.int32)
            padded[0, :n] = req.prompt
            t0 = time.perf_counter()
            batch_cache, logits = admit_fn(
                params, batch_cache, jnp.asarray(padded),
                jnp.asarray(n, jnp.int32), jnp.asarray(slot, jnp.int32))
            tok0, key = sample_one(logits, key, req.temperature, req.top_k)
            ttft = time.perf_counter() - t0
            results[req.request_id] = GenerationResult(req.request_id, [],
                                                       ttft_s=ttft)
            if tok0 == cfg.eos_token or req.max_new_tokens <= 1:
                # Done at the first token: don't occupy a decode slot. The
                # prefill was the whole per-token cost, so tpot = ttft
                # rather than a missing 0.0.
                results[req.request_id].tokens = [tok0]
                results[req.request_id].tpot_s = ttft
                return
            slot_req[slot] = req
            slot_tokens[slot] = [tok0]
            slot_t0[slot] = time.perf_counter()

        while queue or any(r is not None for r in slot_req):
            # Admit into free slots (an admission that finishes at its
            # first token leaves the slot free for the next request).
            for s in range(S):
                while slot_req[s] is None and queue:
                    admit(s, queue.pop(0))
            active = [s for s in range(S) if slot_req[s] is not None]
            if not active:
                break
            last = np.asarray(
                [[slot_tokens[s][-1] if slot_req[s] is not None else cfg.pad_token]
                 for s in range(S)], np.int32)
            temps = np.asarray(
                [slot_req[s].temperature if slot_req[s] is not None else 0.0
                 for s in range(S)], np.float32)
            topks = np.asarray(
                [slot_req[s].top_k if slot_req[s] is not None else 0
                 for s in range(S)], np.int32)
            occupied = np.asarray([slot_req[s] is not None for s in range(S)])
            batch_cache, nxt_dev, key = decode(
                params, batch_cache, jnp.asarray(last), key,
                jnp.asarray(temps), jnp.asarray(topks), jnp.asarray(occupied))
            nxt = np.asarray(nxt_dev)
            for s in active:
                req = slot_req[s]
                slot_tokens[s].append(int(nxt[s]))
                done = (len(slot_tokens[s]) >= req.max_new_tokens or
                        int(nxt[s]) == cfg.eos_token)
                if done:
                    res = results[req.request_id]
                    res.tokens = slot_tokens[s][:req.max_new_tokens]
                    dt = time.perf_counter() - slot_t0[s]
                    res.tpot_s = dt / max(len(res.tokens) - 1, 1)
                    slot_req[s] = None
        return [results[r.request_id] for r in requests]
