"""RWKV6 ("Finch") — attention-free token mixing with data-dependent decay.

The WKV6 core (chunked parallel form for train/prefill, O(1) recurrent state
for decode) lives in ``repro.kernels`` with ref oracle + Pallas kernel; this
module provides the surrounding projections (token-shift lerps, decay LoRA,
per-head group norm, output gate) and the standard token-mixer interface.

An RWKV block's channel-mix FFN is *also* stateful (token shift), so the
block implements the full interface itself rather than reusing
TransformerLayer — still pure composition.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import REQUIRED, ConfigBase, Required, config_class, maybe_set
from repro.core.module import no_context
from repro.core.utils import PartitionSpecLike, remat_name
from repro.kernels import ops as kernel_ops
from repro.layers.base import (
    BaseLayer,
    KernelConfig,
    ParameterSpec,
    fan_in_init,
    normal_init,
    ones_init,
    zeros_init,
)
from repro.layers.basic import LayerNorm

__all__ = ["RWKV6TimeMix", "RWKV6ChannelMix", "RWKV6Block"]


def _token_shift(x: jax.Array, prev: Optional[jax.Array] = None) -> jax.Array:
    """x_{t-1}; position 0 takes ``prev`` (zeros for a fresh sequence)."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    return jnp.concatenate([prev.astype(x.dtype), x[:, :-1]], axis=1)


class RWKV6TimeMix(BaseLayer):
    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        head_dim: int = 64
        decay_lora_dim: int = 64
        proj_weight_partition: PartitionSpecLike = ("data", "model")
        out_weight_partition: PartitionSpecLike = ("model", "data")
        hidden_partition: PartitionSpecLike = (("pod", "data"), None, "model")
        # Registry dispatch for the "wkv6" op (paper §4.2); wkv_chunk_size /
        # wkv_unroll tiling also lives on the shared KernelConfig.
        kernel: KernelConfig = KernelConfig()

    @property
    def _num_heads(self) -> int:
        return self.config.input_dim // self.config.head_dim

    def _create_layer_parameter_specs(self):
        cfg = self.config
        d, hd, H, r = cfg.input_dim, cfg.head_dim, self._num_heads, cfg.decay_lora_dim
        near_one = lambda: (lambda k, s, dt: jnp.full(s, 0.5, dt))  # noqa: E731
        return {
            # Token-shift lerp coefficients for r,k,v,w,g.
            "mu": ParameterSpec((5, d), cfg.param_dtype, near_one(),
                                weight_decay_scale=0.0),
            "r_proj": ParameterSpec((d, d), cfg.param_dtype, fan_in_init(),
                                    mesh_axes=cfg.proj_weight_partition),
            "k_proj": ParameterSpec((d, d), cfg.param_dtype, fan_in_init(),
                                    mesh_axes=cfg.proj_weight_partition),
            "v_proj": ParameterSpec((d, d), cfg.param_dtype, fan_in_init(),
                                    mesh_axes=cfg.proj_weight_partition),
            "g_proj": ParameterSpec((d, d), cfg.param_dtype, fan_in_init(),
                                    mesh_axes=cfg.proj_weight_partition),
            # Data-dependent decay: w = exp(-exp(w0 + tanh(x@w1)@w2)).
            "w0": ParameterSpec((d,), jnp.float32,
                                lambda k, s, dt: jnp.full(s, -1.0, dt),
                                weight_decay_scale=0.0),
            "w1": ParameterSpec((d, r), cfg.param_dtype, normal_init(0.02),
                                mesh_axes=("data", None)),
            "w2": ParameterSpec((r, d), cfg.param_dtype, normal_init(0.02),
                                mesh_axes=(None, "model")),
            # Per-head current-token bonus.
            "u": ParameterSpec((H, hd), jnp.float32, normal_init(0.5),
                               weight_decay_scale=0.0),
            # Per-head group norm on the wkv output.
            "ln_scale": ParameterSpec((d,), cfg.param_dtype, ones_init(),
                                      weight_decay_scale=0.0),
            "ln_bias": ParameterSpec((d,), cfg.param_dtype, zeros_init(),
                                     weight_decay_scale=0.0),
            "out_proj": ParameterSpec((d, d), cfg.param_dtype, fan_in_init(),
                                      mesh_axes=cfg.out_weight_partition),
        }

    def _projections(self, x: jax.Array, shift_prev: Optional[jax.Array]):
        cfg = self.config
        x = self._to_compute(x)
        B, S, d = x.shape
        H, hd = self._num_heads, cfg.head_dim
        xs = _token_shift(x, shift_prev)
        mu = self.state["mu"].astype(x.dtype)  # (5, d)
        mixed = [x + (xs - x) * mu[i] for i in range(5)]
        m_r, m_k, m_v, m_w, m_g = mixed
        r = (m_r @ self.state["r_proj"].astype(x.dtype)).reshape(B, S, H, hd)
        k = (m_k @ self.state["k_proj"].astype(x.dtype)).reshape(B, S, H, hd)
        v = (m_v @ self.state["v_proj"].astype(x.dtype)).reshape(B, S, H, hd)
        g = jax.nn.silu(m_g @ self.state["g_proj"].astype(x.dtype))
        lora = jnp.tanh(m_w.astype(jnp.float32) @ self.state["w1"].astype(jnp.float32))
        logw = self.state["w0"] + lora @ self.state["w2"].astype(jnp.float32)
        w = jnp.exp(-jnp.exp(logw)).reshape(B, S, H, hd)  # in (0,1)
        return r, k, v, w, g

    def _group_norm(self, y: jax.Array) -> jax.Array:
        """LayerNorm within each head."""
        cfg = self.config
        B, S, H, hd = y.shape
        yf = y.astype(jnp.float32)
        mean = jnp.mean(yf, axis=-1, keepdims=True)
        var = jnp.var(yf, axis=-1, keepdims=True)
        yn = (yf - mean) * jax.lax.rsqrt(var + 1e-5)
        yn = yn.reshape(B, S, H * hd)
        yn = yn * self.state["ln_scale"].astype(jnp.float32) + \
            self.state["ln_bias"].astype(jnp.float32)
        return yn

    def _wkv(self, r, k, v, w, state):
        return kernel_ops.wkv6(r, k, v, w, self.state["u"], state,
                               kernel=self.kernel_config,
                               needs_grad=self.is_training)

    def forward(self, x: jax.Array, positions: Optional[jax.Array] = None) -> jax.Array:
        x = self._to_compute(x)
        r, k, v, w, g = self._projections(x, None)
        out, _ = self._wkv(r, k, v, w, None)
        out = remat_name(out, "mixer_out")
        y = self._group_norm(out).astype(x.dtype) * g
        return y @ self.state["out_proj"].astype(x.dtype)

    @no_context
    def state_partition_specs(self, *_):
        b = self.config.hidden_partition[0] if self.config.hidden_partition else None
        return {"shift": (b, None, "model"), "wkv": (b, "model", None, None),
                "index": (b,)}

    def init_states(self, batch_size: int, max_len: int) -> Dict[str, Any]:
        cfg = self.config
        H, hd = self._num_heads, cfg.head_dim
        return {
            "shift": jnp.zeros((batch_size, 1, cfg.input_dim), jnp.bfloat16),
            "wkv": jnp.zeros((batch_size, H, hd, hd), jnp.float32),
            "index": jnp.zeros((batch_size,), jnp.int32),
        }

    def prefill(self, state, x, positions=None, length=None):
        x = self._to_compute(x)
        r, k, v, w, g = self._projections(x, state["shift"])
        if length is not None:
            # Bucket padding must leave the wkv state exact: an invalid step
            # with decay w=1 and key k=0 is the identity transition
            # (s <- 1*s + 0*v^T, zero bonus).
            length = jnp.asarray(length, jnp.int32)
            valid = (jnp.arange(x.shape[1]) < length)[None, :, None, None]
            k = jnp.where(valid, k, 0.0)
            w = jnp.where(valid, w, 1.0)
        out, wkv_state = self._wkv(r, k, v, w, state["wkv"])
        y = self._group_norm(out).astype(x.dtype) * g
        y = y @ self.state["out_proj"].astype(x.dtype)
        if length is None:
            shift = x[:, -1:]
            new_index = state["index"] + x.shape[1]
        else:
            shift = jax.lax.dynamic_slice_in_dim(x, length - 1, 1, axis=1)
            new_index = state["index"] + length
        new_state = {"shift": shift.astype(state["shift"].dtype),
                     "wkv": wkv_state, "index": new_index}
        return new_state, y

    def extend_step(self, state, x_step):
        x_step = self._to_compute(x_step)
        r, k, v, w, g = self._projections(x_step, state["shift"])
        out, wkv_state = kernel_ops.wkv6_decode(
            r, k, v, w, self.state["u"], state["wkv"],
            kernel=self.kernel_config)
        y = self._group_norm(out).astype(x_step.dtype) * g
        y = y @ self.state["out_proj"].astype(x_step.dtype)
        new_state = {"shift": x_step[:, -1:].astype(state["shift"].dtype),
                     "wkv": wkv_state, "index": state["index"] + x_step.shape[1]}
        return new_state, y


class RWKV6ChannelMix(BaseLayer):
    """RWKV's FFN — stateful via token shift."""

    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        hidden_dim: Required[int] = REQUIRED
        up_weight_partition: PartitionSpecLike = ("data", "model")
        down_weight_partition: PartitionSpecLike = ("model", "data")
        state_partition: PartitionSpecLike = (("pod", "data"), None, "model")

    @no_context
    def state_partition_specs(self, *_):
        return {"shift": self.config.state_partition}

    def _create_layer_parameter_specs(self):
        cfg = self.config
        d, h = cfg.input_dim, cfg.hidden_dim
        half = lambda: (lambda k, s, dt: jnp.full(s, 0.5, dt))  # noqa: E731
        return {
            "mu": ParameterSpec((2, d), cfg.param_dtype, half(), weight_decay_scale=0.0),
            "k_proj": ParameterSpec((d, h), cfg.param_dtype, fan_in_init(),
                                    mesh_axes=cfg.up_weight_partition),
            "v_proj": ParameterSpec((h, d), cfg.param_dtype, fan_in_init(),
                                    mesh_axes=cfg.down_weight_partition),
            "r_proj": ParameterSpec((d, d), cfg.param_dtype, fan_in_init(),
                                    mesh_axes=("data", "model")),
        }

    def _core(self, x, shift_prev):
        x = self._to_compute(x)
        mu = self.state["mu"].astype(x.dtype)
        xs = _token_shift(x, shift_prev)
        xk = x + (xs - x) * mu[0]
        xr = x + (xs - x) * mu[1]
        k = jnp.square(jax.nn.relu(xk @ self.state["k_proj"].astype(x.dtype)))
        k = remat_name(k, "ffn_hidden")
        r = jax.nn.sigmoid(xr @ self.state["r_proj"].astype(x.dtype))
        return r * (k @ self.state["v_proj"].astype(x.dtype))

    def forward(self, x, positions=None):
        return self._core(x, None)

    def init_states(self, batch_size, max_len):
        return {"shift": jnp.zeros((batch_size, 1, self.config.input_dim), jnp.bfloat16)}

    def prefill(self, state, x, positions=None, length=None):
        y = self._core(x, state["shift"])
        if length is None:
            shift = x[:, -1:]
        else:
            shift = jax.lax.dynamic_slice_in_dim(
                x, jnp.asarray(length, jnp.int32) - 1, 1, axis=1)
        return {"shift": shift.astype(state["shift"].dtype)}, y

    def extend_step(self, state, x_step):
        y = self._core(x_step, state["shift"])
        return {"shift": x_step[:, -1:].astype(state["shift"].dtype)}, y


class RWKV6Block(BaseLayer):
    """ln -> time_mix -> residual; ln -> channel_mix -> residual."""

    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        time_mix: RWKV6TimeMix.Config = RWKV6TimeMix.Config()
        channel_mix: RWKV6ChannelMix.Config = RWKV6ChannelMix.Config()
        norm: ConfigBase = LayerNorm.Config()
        activation_partition: PartitionSpecLike = (("pod", "data"), None, "model")

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        cfg = self.config

        def with_dim(c):
            c = c.clone()
            if "input_dim" in c.keys() and not c.input_dim:
                c.set(input_dim=cfg.input_dim)
            if "dtype_policy" in c.keys():
                maybe_set(c, dtype_policy=cfg.dtype_policy)
            return c

        self._add_child("ln1", with_dim(cfg.norm))
        self._add_child("time_mix", with_dim(cfg.time_mix))
        self._add_child("ln2", with_dim(cfg.norm))
        self._add_child("channel_mix", with_dim(cfg.channel_mix))

    @no_context
    def state_partition_specs(self, *_):
        return {"tm": self.time_mix.state_partition_specs(),
                "cm": self.channel_mix.state_partition_specs()}

    def forward(self, x, positions=None):
        x = self._to_compute(x)
        x = self._shard(x, self.config.activation_partition)
        x = x + self.time_mix(self.ln1(x), positions=positions)
        x = x + self.channel_mix(self.ln2(x))
        return self._shard(x, self.config.activation_partition)  # scan carry

    def init_states(self, batch_size, max_len):
        return {"tm": self.time_mix.init_states(batch_size, max_len),
                "cm": self.channel_mix.init_states(batch_size, max_len)}

    def prefill(self, state, x, positions=None, length=None):
        tm_state, h = self.time_mix.prefill(
            state["tm"], self.ln1(x), positions=positions, length=length)
        x = x + h
        cm_state, h2 = self.channel_mix.prefill(state["cm"], self.ln2(x),
                                                length=length)
        return {"tm": tm_state, "cm": cm_state}, x + h2

    def extend_step(self, state, x_step):
        tm_state, h = self.time_mix.extend_step(state["tm"], self.ln1(x_step))
        x = x_step + h
        cm_state, h2 = self.channel_mix.extend_step(state["cm"], self.ln2(x))
        return {"tm": tm_state, "cm": cm_state}, x + h2
