"""Feed-forward network with configurable (optionally gated) activations.

``hidden_dim`` may be an int or a ``config_for_function`` of the input dim
(the paper's ``scaled_hidden_dim(scale=8/3)`` partial-config idiom, §4.1).

``activation`` follows the paper's tuple idiom: ``("linear", "nn.silu")``
means two parallel input projections whose activated outputs are multiplied
(SwiGLU); a single string is a plain MLP.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.config import (
    REQUIRED,
    ConfigBase,
    FunctionConfigBase,
    Required,
    config_class,
    config_for_function,
    maybe_set,
)
from repro.core.utils import PartitionSpecLike, remat_name
from repro.layers.base import BaseLayer
from repro.layers.basic import Linear, get_activation

__all__ = ["FeedForward", "scaled_hidden_dim"]


def scaled_hidden_dim(scale: float = 4.0, *, round_to: int = 1) -> FunctionConfigBase:
    """Returns a config computing hidden_dim from input_dim at instantiation."""

    def fn(scale: float, round_to: int):
        def compute(input_dim: int) -> int:
            hidden = int(input_dim * scale)
            return ((hidden + round_to - 1) // round_to) * round_to

        return compute

    return config_for_function(fn).set(scale=scale, round_to=round_to)


class FeedForward(BaseLayer):
    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        # int, or a config_for_function(input_dim -> int).
        hidden_dim: Required[Union[int, FunctionConfigBase]] = REQUIRED
        output_dim: Optional[int] = None  # None -> input_dim
        activation: Union[str, Tuple[str, ...]] = "nn.gelu"
        bias: bool = False
        # Projection template (DotGeneral-swap point, paper §4.2).
        proj: ConfigBase = Linear.Config()
        up_weight_partition: PartitionSpecLike = ("data", "model")
        down_weight_partition: PartitionSpecLike = ("model", "data")
        hidden_partition: PartitionSpecLike = (("pod", "data"), None, "model")

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        cfg = self.config
        hidden = cfg.hidden_dim
        if isinstance(hidden, FunctionConfigBase):
            hidden = hidden.instantiate()(cfg.input_dim)
            cfg.set(hidden_dim=hidden)
        out_dim = cfg.output_dim if cfg.output_dim is not None else cfg.input_dim
        cfg.set(output_dim=out_dim)
        acts = cfg.activation if isinstance(cfg.activation, (tuple, list)) else (cfg.activation,)
        up = cfg.proj.clone().set(
            input_dim=cfg.input_dim, output_dim=hidden, bias=cfg.bias,
            weight_partition=cfg.up_weight_partition, param_dtype=cfg.param_dtype)
        maybe_set(up, dtype_policy=cfg.dtype_policy)
        for i in range(len(acts)):
            self._add_child(f"up_proj{i}" if len(acts) > 1 else "up_proj", up.clone())
        down = cfg.proj.clone().set(
            input_dim=hidden, output_dim=out_dim, bias=cfg.bias,
            weight_partition=cfg.down_weight_partition, param_dtype=cfg.param_dtype)
        maybe_set(down, dtype_policy=cfg.dtype_policy)
        self._add_child("down_proj", down)

    def forward(self, x: jax.Array) -> jax.Array:
        cfg = self.config
        x = self._to_compute(x)
        acts = cfg.activation if isinstance(cfg.activation, (tuple, list)) else (cfg.activation,)
        if len(acts) == 1:
            h = get_activation(acts[0])(self.up_proj(x))
        else:
            h = None
            for i, name in enumerate(acts):
                proj = getattr(self, f"up_proj{i}")(x)
                a = get_activation(name)(proj)
                h = a if h is None else h * a
        h = self._shard(h, cfg.hidden_partition)
        h = remat_name(h, "ffn_hidden")
        out = self.down_proj(h)
        return remat_name(out, "ffn_out")
