"""Model heads: CausalLM (train + serve) and MaskedLM (encoder, HuBERT-style).

The model consumes a *batch dict* so heterogeneous modalities stay config:
  input_ids         (B, S) int32            — text tokens
  labels            (B, S) int32            — next-token targets, -100 = ignore
  input_embeddings  (B, P, D) or (B, S, D)  — stub frontend outputs (VLM/audio)
  mask_positions    (B, S) bool             — MaskedLM corruption mask
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import REQUIRED, ConfigBase, Required, config_class, maybe_set
from repro.core.module import no_context
from repro.layers.base import BaseLayer, ParameterSpec, normal_init
from repro.layers.transformer import Decoder

__all__ = ["CausalLM", "MaskedLM", "cross_entropy"]

IGNORE_TARGET = -100


def cross_entropy(logits: jax.Array, labels: jax.Array, *, z_loss_scale: float = 0.0
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean CE over valid (label >= 0) positions, fp32, optional z-loss."""
    logits = logits.astype(jnp.float32)
    valid = labels != IGNORE_TARGET
    safe_labels = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    label_logits = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    nll = logz - label_logits
    if z_loss_scale > 0.0:
        nll = nll + z_loss_scale * jnp.square(logz)
    denom = jnp.maximum(jnp.sum(valid), 1)
    loss = jnp.sum(jnp.where(valid, nll, 0.0)) / denom
    acc = jnp.sum(jnp.where(valid, (jnp.argmax(logits, -1) == safe_labels), 0)) / denom
    return loss, {"accuracy": acc, "num_targets": denom}


class CausalLM(BaseLayer):
    """decoder + CE loss; aux losses (MoE balance) surface via the
    InvocationContext — this layer never references MoE."""

    @config_class
    class Config(BaseLayer.Config):
        decoder: Required[ConfigBase] = REQUIRED
        z_loss_scale: float = 0.0
        # Token-chunked CE: never materializes (B, S, V) logits — required to
        # fit 256k-vocab training at 1M tokens/step. None = single-shot.
        loss_chunk_size: Optional[int] = None
        # Unroll the chunk scan (AOT analysis mode: exact cost_analysis).
        loss_chunk_unroll: bool = False

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        decoder = cfg.decoder.clone()
        if "dtype_policy" in decoder.keys():
            maybe_set(decoder, dtype_policy=cfg.dtype_policy)
        self._add_child("decoder", decoder)

    def forward(self, batch: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
        cfg = self.config
        S = batch["labels"].shape[1]
        if cfg.loss_chunk_size and S % cfg.loss_chunk_size == 0 \
                and S > cfg.loss_chunk_size:
            return self._chunked_forward(batch)
        logits = self.decoder(
            batch.get("input_ids"),
            input_embeddings=batch.get("input_embeddings"),
            positions=batch.get("positions"),
        )
        loss, metrics = cross_entropy(
            logits, batch["labels"], z_loss_scale=self.config.z_loss_scale)
        self.add_summary("loss", loss)
        self.add_summary("accuracy", metrics["accuracy"])
        return loss, {"logits": logits, **metrics}

    def _chunked_forward(self, batch):
        """CE over sequence chunks: logits live one chunk at a time (fwd AND
        bwd via remat)."""
        cfg = self.config
        c = cfg.loss_chunk_size
        h = self.decoder.hidden(
            batch.get("input_ids"),
            input_embeddings=batch.get("input_embeddings"),
            positions=batch.get("positions"),
        )
        B, S, D = h.shape
        n = S // c
        hs = jnp.moveaxis(h.reshape(B, n, c, D), 1, 0)  # (n, B, c, D)
        labels = jnp.moveaxis(batch["labels"].reshape(B, n, c), 1, 0)
        decoder = self.decoder

        def body(carry, xs):
            nll_sum, correct, count = carry
            h_c, l_c = xs
            logits = decoder.head(h_c).astype(jnp.float32)
            valid = l_c != IGNORE_TARGET
            safe = jnp.where(valid, l_c, 0)
            logz = jax.nn.logsumexp(logits, axis=-1)
            lab = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
            nll = logz - lab
            if cfg.z_loss_scale > 0.0:
                nll = nll + cfg.z_loss_scale * jnp.square(logz)
            nll_sum = nll_sum + jnp.sum(jnp.where(valid, nll, 0.0))
            correct = correct + jnp.sum(
                jnp.where(valid, jnp.argmax(logits, -1) == safe, 0))
            count = count + jnp.sum(valid)
            return (nll_sum, correct, count), None

        body = jax.checkpoint(body, prevent_cse=False)
        (nll_sum, correct, count), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32),
                   jnp.zeros((), jnp.int32)), (hs, labels),
            unroll=cfg.loss_chunk_unroll)
        denom = jnp.maximum(count, 1)
        loss = nll_sum / denom
        acc = correct / denom
        self.add_summary("loss", loss)
        self.add_summary("accuracy", acc)
        return loss, {"logits": None, "accuracy": acc, "num_targets": denom}

    def predict(self, batch: Dict[str, Any]) -> jax.Array:
        return self.decoder(
            batch.get("input_ids"),
            input_embeddings=batch.get("input_embeddings"),
            positions=batch.get("positions"),
        )

    # --- serving ----------------------------------------------------------------

    @no_context
    def state_partition_specs(self, *_):
        return self.decoder.state_partition_specs()

    def init_states(self, batch_size: int, max_len: int):
        return self.decoder.init_states(batch_size, max_len)

    def prefill(self, state, input_ids=None, *, input_embeddings=None,
                length=None):
        """``length`` (optional scalar): number of real prompt tokens; the
        rest of ``input_ids`` is bucket padding that must not enter any
        layer's cache/recurrent state (continuous-batching admission)."""
        return self.decoder.prefill(
            state, input_ids, input_embeddings=input_embeddings, length=length)

    def extend_step(self, state, ids_step):
        return self.decoder.extend_step(state, ids_step)


class MaskedLM(BaseLayer):
    """Encoder-only masked-prediction model (HuBERT backbone).

    Frame embeddings from the (stubbed) conv frontend are corrupted at
    ``mask_positions`` with a learned vector; loss is CE at masked positions.
    """

    @config_class
    class Config(BaseLayer.Config):
        decoder: Required[ConfigBase] = REQUIRED  # configured bidirectional
        dim: Required[int] = REQUIRED

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        decoder = cfg.decoder.clone()
        if "dtype_policy" in decoder.keys():
            maybe_set(decoder, dtype_policy=cfg.dtype_policy)
        self._add_child("decoder", decoder)

    def _create_layer_parameter_specs(self):
        return {"mask_emb": ParameterSpec(
            (self.config.dim,), self.config.param_dtype, normal_init(0.02))}

    def forward(self, batch: Dict[str, Any]) -> Tuple[jax.Array, Dict[str, Any]]:
        x = batch["input_embeddings"]
        mask = batch["mask_positions"]
        x = jnp.where(mask[..., None], self.state["mask_emb"].astype(x.dtype), x)
        logits = self.decoder(None, input_embeddings=x)
        labels = jnp.where(mask, batch["labels"], IGNORE_TARGET)
        loss, metrics = cross_entropy(logits, labels)
        self.add_summary("loss", loss)
        return loss, {"logits": logits, **metrics}

    def predict(self, batch: Dict[str, Any]) -> jax.Array:
        return self.decoder(None, input_embeddings=batch["input_embeddings"])
