"""BaseLayer: parameterized modules with spec-driven init and sharding.

Parameters are declared as :class:`ParameterSpec` (shape, dtype, initializer,
``mesh_axes``) — the mesh_axes carry the *named-axis* partition spec that the
paper's config-based parallelism (§4.2) hinges on. The trainer and the AOT
dry-run consume the spec tree to build NamedShardings; layers never touch
devices.
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.config import REQUIRED, Required, config_class
from repro.core.module import Module
from repro.core.utils import PartitionSpecLike, maybe_shard

__all__ = [
    "ParameterSpec",
    "BaseLayer",
    "Initializer",
    "constant_init",
    "zeros_init",
    "ones_init",
    "normal_init",
    "fan_in_init",
    "uniform_scale_init",
]

Initializer = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


def zeros_init() -> Initializer:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> Initializer:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


def constant_init(value: float) -> Initializer:
    return lambda key, shape, dtype: jnp.full(shape, value, dtype)


def normal_init(stddev: float = 0.02) -> Initializer:
    return lambda key, shape, dtype: (jax.random.normal(key, shape) * stddev).astype(dtype)


def fan_in_init(scale: float = 1.0, fan_in_axes: Sequence[int] = (-2,)) -> Initializer:
    """Truncated-normal-ish fan-in init (std = scale / sqrt(fan_in))."""

    def init(key, shape, dtype):
        fan_in = 1
        for ax in fan_in_axes:
            fan_in *= shape[ax]
        std = scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape) * std).astype(dtype)

    return init


def uniform_scale_init(scale: float = 1.0) -> Initializer:
    def init(key, shape, dtype):
        fan_in = shape[0] if shape else 1
        bound = scale * math.sqrt(3.0 / max(fan_in, 1))
        return jax.random.uniform(key, shape, minval=-bound, maxval=bound).astype(dtype)

    return init


@dataclasses.dataclass
class ParameterSpec:
    """Declarative description of one parameter."""

    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    initializer: Optional[Initializer] = None
    # Named-axis partition spec, e.g. ("data", "model"). None = replicated.
    mesh_axes: PartitionSpecLike = None
    # Weight-decay / clipping hints for the learner.
    weight_decay_scale: float = 1.0

    def initialize(self, key: jax.Array) -> jax.Array:
        init = self.initializer or normal_init()
        return init(key, tuple(self.shape), self.dtype)


def _stable_hash(name: str) -> int:
    return zlib.crc32(name.encode("utf-8"))


class BaseLayer(Module):
    """Module with parameters."""

    @config_class
    class Config(Module.Config):
        # Parameter dtype. Compute dtype follows inputs; params are cast at
        # use-sites if a global policy requires it.
        param_dtype: Any = jnp.float32
        # Optional override of every own-param partition spec (layers define
        # per-param defaults in _create_layer_parameter_specs).
        param_partition_spec: Optional[Any] = None

    # --- parameter declaration (override in subclasses) ---------------------

    def _create_layer_parameter_specs(self) -> Dict[str, ParameterSpec]:
        return {}

    # --- recursive spec/init (structural: no InvocationContext needed) ------

    def create_parameter_specs_recursively(self) -> Dict[str, Any]:
        specs: Dict[str, Any] = {}
        own = self._create_layer_parameter_specs()
        for name, spec in own.items():
            if self.config.param_partition_spec is not None:
                spec = dataclasses.replace(spec, mesh_axes=self.config.param_partition_spec)
            if spec.dtype is None:
                spec = dataclasses.replace(spec, dtype=self.config.param_dtype)
            specs[name] = spec
        for child_name, child in self._children.items():
            if isinstance(child, BaseLayer):
                child_specs = child.create_parameter_specs_recursively()
                if child_specs:
                    specs[child_name] = child_specs
        return specs

    def initialize_parameters_recursively(self, prng_key: jax.Array) -> Dict[str, Any]:
        params: Dict[str, Any] = {}
        own = self._create_layer_parameter_specs()
        for name, spec in own.items():
            if spec.dtype is None:
                spec = dataclasses.replace(spec, dtype=self.config.param_dtype)
            sub_key = jax.random.fold_in(prng_key, _stable_hash(name))
            params[name] = spec.initialize(sub_key)
        for child_name, child in self._children.items():
            if isinstance(child, BaseLayer):
                sub_key = jax.random.fold_in(prng_key, _stable_hash(child_name))
                child_params = child.initialize_parameters_recursively(sub_key)
                if child_params:
                    params[child_name] = child_params
        return params

    # --- conveniences ---------------------------------------------------------

    def _shard(self, x: jax.Array, spec: PartitionSpecLike) -> jax.Array:
        return maybe_shard(x, spec)
