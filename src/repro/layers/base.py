"""BaseLayer: parameterized modules with spec-driven init and sharding.

Parameters are declared as :class:`ParameterSpec` (shape, dtype, initializer,
``mesh_axes``) — the mesh_axes carry the *named-axis* partition spec that the
paper's config-based parallelism (§4.2) hinges on. The trainer and the AOT
dry-run consume the spec tree to build NamedShardings; layers never touch
devices.

Mixed precision is a :class:`DtypePolicy` carried by every layer config:
inputs are cast to ``compute_dtype`` at module boundaries (layers already
cast their params to the input dtype at use-sites, so params follow), while
fp32 islands — norms, softmax, routing, the loss — keep their explicit
accumulation dtypes. Setting bf16-compute/fp32-master training for an entire
model is therefore one ``visit_config`` pass over the trainer config
(``trainer.mesh_rules.DtypePolicyModifier``), never a layer edit — the
paper's ~10-LoC cross-cutting-change mechanism (§4.2) applied to precision.

Kernel selection follows the same pattern: kernel-calling layers declare a
``kernel: KernelConfig`` field and dispatch through ``repro.kernels.ops``
into the capability-based registry; ``KernelModifier`` rewrites every
``KernelConfig`` in the tree from one mesh rule (§4.2 applied to kernels).
"""

from __future__ import annotations

import dataclasses
import math
import zlib
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.config import REQUIRED, ConfigBase, Required, config_class
from repro.core.module import Module
from repro.core.utils import PartitionSpecLike, maybe_shard
from repro.kernels.registry import DEFAULT_CONFIG as _DEFAULT_KERNEL_CONFIG
from repro.kernels.registry import KernelConfig

__all__ = [
    "ParameterSpec",
    "DtypePolicy",
    "bf16_policy",
    "KernelConfig",
    "BaseLayer",
    "Initializer",
    "constant_init",
    "zeros_init",
    "ones_init",
    "normal_init",
    "fan_in_init",
    "uniform_scale_init",
]


@config_class
class DtypePolicy(ConfigBase):
    """Per-layer mixed-precision policy (all fields None = current behaviour).

    ``param_dtype``: storage dtype of params that follow the layer's default
        param dtype (explicit fp32 islands like Mamba's ``A_log`` keep their
        declared dtype). None keeps each layer's ``param_dtype`` field.
    ``compute_dtype``: floating inputs are cast to this dtype at every module
        boundary; params follow via the existing ``astype(x.dtype)``
        use-site casts. None = compute follows inputs untouched.
    ``output_dtype``: dtype of model *outputs* (logits); applied by heads.
        None = leave in compute dtype.
    ``grad_dtype``: dtype gradients are accumulated in (grad-accumulation
        buffers; the trainer reads it via ``DtypePolicyModifier``). None =
        accumulate in the param dtype.
    ``fp8``: fp8 compute mode — a :class:`repro.quantization.fp8.Fp8Config`.
        GEMM-boundary layers (``_fp8_boundary = True``, e.g. Linear)
        fake-quantize their inputs to the e4m3 grid with per-tensor
        *delayed* scaling; the amax history rides in layer state. None =
        off. Set tree-wide by ``quantization.modifier.QuantizationModifier``.
    """

    param_dtype: Optional[Any] = None
    compute_dtype: Optional[Any] = None
    output_dtype: Optional[Any] = None
    grad_dtype: Optional[Any] = None
    fp8: Optional[Any] = None


def bf16_policy() -> DtypePolicy:
    """The production default: fp32 master params, bf16 compute, fp32 grad
    accumulation (grad_dtype=None -> param dtype)."""
    return DtypePolicy().set(compute_dtype=jnp.bfloat16)

Initializer = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


def zeros_init() -> Initializer:
    return lambda key, shape, dtype: jnp.zeros(shape, dtype)


def ones_init() -> Initializer:
    return lambda key, shape, dtype: jnp.ones(shape, dtype)


def constant_init(value: float) -> Initializer:
    return lambda key, shape, dtype: jnp.full(shape, value, dtype)


def normal_init(stddev: float = 0.02) -> Initializer:
    return lambda key, shape, dtype: (jax.random.normal(key, shape) * stddev).astype(dtype)


def fan_in_init(scale: float = 1.0, fan_in_axes: Sequence[int] = (-2,)) -> Initializer:
    """Truncated-normal-ish fan-in init (std = scale / sqrt(fan_in))."""

    def init(key, shape, dtype):
        fan_in = 1
        for ax in fan_in_axes:
            fan_in *= shape[ax]
        std = scale / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape) * std).astype(dtype)

    return init


def uniform_scale_init(scale: float = 1.0) -> Initializer:
    def init(key, shape, dtype):
        fan_in = shape[0] if shape else 1
        bound = scale * math.sqrt(3.0 / max(fan_in, 1))
        return jax.random.uniform(key, shape, minval=-bound, maxval=bound).astype(dtype)

    return init


@dataclasses.dataclass
class ParameterSpec:
    """Declarative description of one parameter."""

    shape: Tuple[int, ...]
    dtype: Any = jnp.float32
    initializer: Optional[Initializer] = None
    # Named-axis partition spec, e.g. ("data", "model"). None = replicated.
    mesh_axes: PartitionSpecLike = None
    # Weight-decay / clipping hints for the learner.
    weight_decay_scale: float = 1.0

    def initialize(self, key: jax.Array) -> jax.Array:
        init = self.initializer or normal_init()
        return init(key, tuple(self.shape), self.dtype)


def _stable_hash(name: str) -> int:
    return zlib.crc32(name.encode("utf-8"))



class BaseLayer(Module):
    """Module with parameters."""

    @config_class
    class Config(Module.Config):
        # Parameter dtype. Compute dtype follows inputs; params are cast at
        # use-sites if a global policy requires it.
        param_dtype: Any = jnp.float32
        # Optional override of every own-param partition spec (layers define
        # per-param defaults in _create_layer_parameter_specs).
        param_partition_spec: Optional[Any] = None
        # Mixed-precision policy (None = dtypes follow inputs / param_dtype).
        # Set on every layer in one pass by DtypePolicyModifier.
        dtype_policy: Optional[DtypePolicy] = None

    # GEMM layers opt into the fp8 module-boundary fake-quant (Linear sets
    # True); structural/norm/softmax layers keep full-precision boundaries,
    # which is what makes DtypePolicy.fp8 safe to set tree-wide.
    _fp8_boundary = False

    # --- parameter declaration (override in subclasses) ---------------------

    def _create_layer_parameter_specs(self) -> Dict[str, ParameterSpec]:
        return {}

    # --- kernel dispatch ----------------------------------------------------

    @property
    def kernel_config(self) -> KernelConfig:
        """The layer's :class:`KernelConfig` (kernel-calling layers declare a
        ``kernel`` field; others get the registry defaults). All kernel
        selection goes through this one sub-config — mesh rules rewrite it
        tree-wide via ``KernelModifier`` (paper §4.2), never layer code."""
        kcfg = getattr(self.config, "kernel", None)
        return kcfg if kcfg is not None else _DEFAULT_KERNEL_CONFIG

    # --- dtype policy -------------------------------------------------------

    def _resolve_param_spec_dtype(self, spec: ParameterSpec) -> ParameterSpec:
        """Applies cfg.param_dtype defaults + the policy's param_dtype.

        The policy only overrides specs that *follow* the layer param dtype;
        explicitly-pinned dtypes (fp32 islands like Mamba's ``A_log``) stay.
        """
        cfg = self.config
        if spec.dtype is None:
            spec = dataclasses.replace(spec, dtype=cfg.param_dtype)
        policy = cfg.dtype_policy
        if (policy is not None and policy.param_dtype is not None
                and spec.dtype == cfg.param_dtype
                and jnp.issubdtype(jnp.dtype(spec.dtype), jnp.floating)):
            spec = dataclasses.replace(spec, dtype=policy.param_dtype)
        return spec

    @property
    def compute_dtype(self) -> Optional[Any]:
        policy = self.config.dtype_policy
        return policy.compute_dtype if policy is not None else None

    def _fp8_config(self):
        """The active fp8 compute config, or None (off / layer opted out)."""
        policy = self.config.dtype_policy
        fp8 = getattr(policy, "fp8", None) if policy is not None else None
        return fp8 if (fp8 is not None and self._fp8_boundary) else None

    def _fp8_fake_quant(self, xs, fp8_cfg):
        """Delayed-scaling fake-quant of boundary inputs (+ amax rollup).

        Reads the layer's ``fp8_amax_history`` state (skips silently when
        absent — e.g. a checkpoint predating the policy) and, in training,
        emits the rolled history as a state update the train step folds
        back into the params.
        """
        from repro.quantization import fp8 as fp8_lib

        state = self.state
        history = state.get(fp8_lib.AMAX_HISTORY_KEY) \
            if isinstance(state, dict) else None
        if history is None:
            return xs
        out, amaxes = [], []
        for x in xs:
            if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
                xq, amax = fp8_lib.boundary_fake_quant(
                    x, history, margin=fp8_cfg.margin)
                out.append(xq)
                amaxes.append(amax)
            else:
                out.append(x)
        if amaxes and self.is_training:
            amax = amaxes[0] if len(amaxes) == 1 else jnp.max(jnp.stack(amaxes))
            self.add_state_update(
                fp8_lib.AMAX_HISTORY_KEY,
                fp8_lib.roll_amax_history(history, amax))
        return tuple(out)

    def _to_compute(self, *xs):
        """Casts floating arrays to the policy compute dtype (module-boundary
        input cast; a no-op without a policy). Non-float leaves pass through.
        With ``DtypePolicy.fp8`` set, GEMM-boundary layers additionally
        fake-quantize the cast inputs to the e4m3 grid here."""
        dt = self.compute_dtype
        if dt is not None:
            def cast(x):
                if (hasattr(x, "dtype")
                        and jnp.issubdtype(x.dtype, jnp.floating)
                        and x.dtype != jnp.dtype(dt)):
                    return x.astype(dt)
                return x

            xs = tuple(cast(x) for x in xs)
        fp8_cfg = self._fp8_config()
        if fp8_cfg is not None:
            xs = self._fp8_fake_quant(xs, fp8_cfg)
        return xs[0] if len(xs) == 1 else tuple(xs)

    def _to_output(self, x: jax.Array) -> jax.Array:
        """Casts a head/model output to the policy output dtype (if set)."""
        policy = self.config.dtype_policy
        if policy is None or policy.output_dtype is None:
            return x
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(policy.output_dtype)
        return x

    # --- recursive spec/init (structural: no InvocationContext needed) ------

    def create_parameter_specs_recursively(self) -> Dict[str, Any]:
        specs: Dict[str, Any] = {}
        own = self._create_layer_parameter_specs()
        for name, spec in own.items():
            if self.config.param_partition_spec is not None:
                spec = dataclasses.replace(spec, mesh_axes=self.config.param_partition_spec)
            specs[name] = self._resolve_param_spec_dtype(spec)
        specs.update(self._fp8_parameter_specs())
        for child_name, child in self._children.items():
            if isinstance(child, BaseLayer):
                child_specs = child.create_parameter_specs_recursively()
                if child_specs:
                    specs[child_name] = child_specs
        return specs

    def _fp8_parameter_specs(self) -> Dict[str, ParameterSpec]:
        """The delayed-scaling amax history, when fp8 is active: a tiny
        replicated fp32 param (weight-decay exempt, dtype pinned — it
        bypasses the policy's param_dtype override on purpose)."""
        fp8 = self._fp8_config()
        if fp8 is None:
            return {}
        from repro.quantization.fp8 import AMAX_HISTORY_KEY

        return {AMAX_HISTORY_KEY: ParameterSpec(
            shape=(int(fp8.amax_history_len),), dtype=jnp.float32,
            initializer=zeros_init(), mesh_axes=None,
            weight_decay_scale=0.0)}

    def initialize_parameters_recursively(self, prng_key: jax.Array) -> Dict[str, Any]:
        params: Dict[str, Any] = {}
        own = self._create_layer_parameter_specs()
        for name, spec in own.items():
            spec = self._resolve_param_spec_dtype(spec)
            sub_key = jax.random.fold_in(prng_key, _stable_hash(name))
            params[name] = spec.initialize(sub_key)
        for name, spec in self._fp8_parameter_specs().items():
            sub_key = jax.random.fold_in(prng_key, _stable_hash(name))
            params[name] = spec.initialize(sub_key)
        for child_name, child in self._children.items():
            if isinstance(child, BaseLayer):
                sub_key = jax.random.fold_in(prng_key, _stable_hash(child_name))
                child_params = child.initialize_parameters_recursively(sub_key)
                if child_params:
                    params[child_name] = child_params
        return params

    # --- conveniences ---------------------------------------------------------

    def _shard(self, x: jax.Array, spec: PartitionSpecLike) -> jax.Array:
        return maybe_shard(x, spec)
