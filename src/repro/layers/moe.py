"""Mixture-of-Experts FFN — the paper's flagship drop-in replacement (§2.1).

GSPMD/GShard-style capacity-based token-choice top-k routing with einsum
dispatch/combine, designed for expert parallelism over the "model" (or a
dedicated "expert") mesh axis. The load-balance and router-z auxiliary
losses are emitted through the InvocationContext (``add_module_output``),
so NO ancestor layer — TransformerLayer, Repeat, Decoder, CausalLM — knows
MoE exists. That is precisely the encapsulation property the paper measures
with LoC-complexity.

Interface-compatible with FeedForward: forward(x: (B,S,D)) -> (B,S,D).
"""

from __future__ import annotations

from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.config import (
    REQUIRED,
    FunctionConfigBase,
    Required,
    config_class,
    maybe_set,
)
from repro.core.utils import PartitionSpecLike, remat_name
from repro.layers.base import BaseLayer, ParameterSpec, fan_in_init, normal_init
from repro.layers.basic import get_activation
from repro.layers.ffn import FeedForward

__all__ = ["MoELayer", "ResidualMoE", "TopKRouter"]


class TopKRouter(BaseLayer):
    """Token-choice top-k router with capacity-aware position assignment.

    Returns (dispatch (G,S,E,C) bool-ish, combine (G,S,E,C) float) tensors.
    Encapsulates: gating nonlinearity, top-k normalization, capacity logic,
    aux losses. Swappable for other routing strategies by config.
    """

    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        num_experts: Required[int] = REQUIRED
        top_k: int = 2
        capacity_factor: float = 2.0
        # mixtral renormalizes the top-k gate weights to sum to 1.
        normalize_top_k: bool = True
        load_balance_weight: float = 0.01
        router_z_weight: float = 0.001
        gate_weight_partition: PartitionSpecLike = ("data", None)
        # (G, S, E, C) dispatch/combine sharding — set by the parent MoELayer
        # so the fp32 routing tensors are expert-sharded from birth.
        dispatch_partition: PartitionSpecLike = (("pod", "data"), None, "model", None)

    def _create_layer_parameter_specs(self):
        cfg = self.config
        return {
            "gate": ParameterSpec(
                shape=(cfg.input_dim, cfg.num_experts),
                dtype=cfg.param_dtype,
                initializer=normal_init(0.02),
                mesh_axes=cfg.gate_weight_partition,
            )
        }

    def forward(self, x: jax.Array, *, capacity: int) -> Tuple[jax.Array, jax.Array]:
        cfg = self.config
        G, S, D = x.shape
        E, K, C = cfg.num_experts, cfg.top_k, capacity
        logits = (x.astype(jnp.float32) @ self.state["gate"].astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)  # (G,S,E)

        top_vals, top_idx = jax.lax.top_k(probs, K)  # (G,S,K)
        if cfg.normalize_top_k:
            top_vals = top_vals / jnp.maximum(
                jnp.sum(top_vals, -1, keepdims=True), 1e-9)

        dp = tuple(cfg.dispatch_partition) if cfg.dispatch_partition else (None,) * 4
        gse = (dp[0], dp[1], dp[2])

        # Sequential capacity assignment: all k=0 choices first (GShard).
        dispatch = jnp.zeros((G, S, E, C), jnp.float32)
        combine = jnp.zeros((G, S, E, C), jnp.float32)
        counts = jnp.zeros((G, E), jnp.float32)  # tokens already at each expert
        frac_dispatched_first = None
        for k in range(K):
            mask_k = jax.nn.one_hot(top_idx[..., k], E, dtype=jnp.float32)  # (G,S,E)
            mask_k = self._shard(mask_k, gse)
            pos_k = jnp.cumsum(mask_k, axis=1) - 1.0 + counts[:, None, :]
            keep_k = (pos_k < C) * mask_k  # (G,S,E)
            counts = counts + jnp.sum(keep_k, axis=1)
            oh_pos = jax.nn.one_hot(pos_k.astype(jnp.int32), C, dtype=jnp.float32)
            oh_pos = self._shard(oh_pos, dp)
            disp_k = keep_k[..., None] * oh_pos  # (G,S,E,C)
            dispatch = self._shard(dispatch + disp_k, dp)
            combine = self._shard(
                combine + disp_k * top_vals[..., k][..., None, None], dp)
            if k == 0:
                frac_dispatched_first = jnp.mean(mask_k, axis=(0, 1))  # (E,)

        # --- aux losses, emitted without ancestor knowledge ------------------
        mean_prob = jnp.mean(probs, axis=(0, 1))  # (E,)
        load_balance = E * jnp.sum(frac_dispatched_first * mean_prob)
        z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
        aux = cfg.load_balance_weight * load_balance + cfg.router_z_weight * z_loss
        self.add_module_output("aux_loss", aux)
        self.add_summary("load_balance_loss", load_balance)
        self.add_summary("router_z_loss", z_loss)
        self.add_summary("expert_load_max", jnp.max(frac_dispatched_first) * E)
        dispatched_frac = jnp.sum(dispatch) / (G * S * K)
        self.add_summary("dispatched_fraction", dispatched_frac)  # 1 - drop rate
        return dispatch, combine


class MoELayer(BaseLayer):
    """Drop-in FFN replacement. Expert weights (E, D, H) shard E over the
    expert axis when divisible (expert parallelism); the dispatch einsums
    become all-to-alls under GSPMD."""

    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        hidden_dim: Required[Union[int, FunctionConfigBase]] = REQUIRED
        num_experts: Required[int] = REQUIRED
        top_k: int = 2
        capacity_factor: float = 2.0
        # GShard grouping: tokens are routed in groups of this size, bounding
        # the (G, g, E, C) dispatch tensors to O(tokens * g) instead of
        # O(tokens * S) when sequences are long (32k prefill!). None = one
        # group per sequence (legacy behaviour).
        group_size: Optional[int] = None
        activation: Union[str, Tuple[str, ...]] = ("linear", "nn.silu")
        router: TopKRouter.Config = TopKRouter.Config()
        # (E, D, H): shard experts over "expert"/"model" when divisible; the
        # config builders choose (see configs/common.py).
        up_weight_partition: PartitionSpecLike = ("model", "data", None)
        down_weight_partition: PartitionSpecLike = ("model", None, "data")
        # (G, S, E, C) dispatch activations.
        dispatch_partition: PartitionSpecLike = (("pod", "data"), None, "model", None)
        # (E, G, C, D) expert-major activations.
        expert_partition: PartitionSpecLike = ("model", ("pod", "data"), None, None)

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        cfg = self.config
        hidden = cfg.hidden_dim
        if isinstance(hidden, FunctionConfigBase):
            cfg.set(hidden_dim=hidden.instantiate()(cfg.input_dim))
        router = cfg.router.clone()
        router.set(input_dim=cfg.input_dim, num_experts=cfg.num_experts,
                   top_k=cfg.top_k, capacity_factor=cfg.capacity_factor,
                   dispatch_partition=cfg.dispatch_partition)
        maybe_set(router, dtype_policy=cfg.dtype_policy)
        self._add_child("router", router)

    def _create_layer_parameter_specs(self):
        cfg = self.config
        E, D, H = cfg.num_experts, cfg.input_dim, cfg.hidden_dim
        acts = cfg.activation if isinstance(cfg.activation, (tuple, list)) else (cfg.activation,)
        specs = {}
        for i in range(len(acts)):
            name = f"wi_{i}" if len(acts) > 1 else "wi"
            specs[name] = ParameterSpec(
                shape=(E, D, H), dtype=cfg.param_dtype,
                initializer=fan_in_init(fan_in_axes=(-2,)),
                mesh_axes=cfg.up_weight_partition)
        specs["wo"] = ParameterSpec(
            shape=(E, H, D), dtype=cfg.param_dtype,
            initializer=fan_in_init(fan_in_axes=(-2,)),
            mesh_axes=cfg.down_weight_partition)
        return specs

    def _capacity(self, S: int) -> int:
        cfg = self.config
        per_expert = (S * cfg.top_k) / cfg.num_experts
        return max(4, int(per_expert * cfg.capacity_factor + 0.5))

    def forward(self, x: jax.Array) -> jax.Array:
        cfg = self.config
        # Boundary cast: expert matmuls run in the compute dtype; the router
        # keeps its fp32 gating/aux-loss island.
        x = self._to_compute(x)
        B0, S0, D = x.shape
        g = cfg.group_size
        if g and S0 > g and S0 % g == 0:
            x = x.reshape(B0 * (S0 // g), g, D)
        B, S, D = x.shape
        C = self._capacity(S)
        acts = cfg.activation if isinstance(cfg.activation, (tuple, list)) else (cfg.activation,)

        dispatch, combine = self.router(x, capacity=C)
        dispatch = self._shard(dispatch.astype(jnp.bfloat16), cfg.dispatch_partition)
        combine = self._shard(combine.astype(x.dtype), cfg.dispatch_partition)
        dispatch = remat_name(dispatch, "moe_dispatch")

        # Dispatch tokens to experts: (E, G, C, D). Under expert parallelism
        # this einsum lowers to an all-to-all over the expert axis.
        xe = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), x)
        xe = self._shard(xe, cfg.expert_partition)

        # Per-expert FFN (optionally gated).
        if len(acts) == 1:
            h = get_activation(acts[0])(
                jnp.einsum("egcd,edh->egch", xe, self.state["wi"].astype(x.dtype)))
        else:
            h = None
            for i, name in enumerate(acts):
                w = self.state[f"wi_{i}"].astype(x.dtype)
                a = get_activation(name)(jnp.einsum("egcd,edh->egch", xe, w))
                h = a if h is None else h * a
        ye = jnp.einsum("egch,ehd->egcd", h, self.state["wo"].astype(x.dtype))
        ye = self._shard(ye, cfg.expert_partition)

        # Combine back to token order.
        y = jnp.einsum("gsec,egcd->gsd", combine, ye)
        if y.shape[0] != B0:
            y = y.reshape(B0, S0, D)
        return remat_name(y, "ffn_out")


class ResidualMoE(BaseLayer):
    """Arctic-style: a small dense FFN in parallel with the MoE FFN.

    Pure composition: both children keep their own encapsulated configs.
    """

    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        dense: FeedForward.Config = FeedForward.Config()
        moe: MoELayer.Config = MoELayer.Config()

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        dense = cfg.dense.clone()
        moe = cfg.moe.clone()
        for c in (dense, moe):
            if not c.input_dim:
                c.set(input_dim=cfg.input_dim)
            maybe_set(c, dtype_policy=cfg.dtype_policy)
        self._add_child("dense", dense)
        self._add_child("moe", moe)

    def forward(self, x: jax.Array) -> jax.Array:
        return self.dense(x) + self.moe(x)
