"""Layer library: strictly-encapsulated, config-composed building blocks."""

from repro.layers.attention import MultiheadAttention
from repro.layers.base import (BaseLayer, DtypePolicy, KernelConfig,
                               ParameterSpec, bf16_policy)
from repro.layers.basic import Dropout, Embedding, LayerNorm, Linear, RMSNorm
from repro.layers.causal_lm import CausalLM, MaskedLM, cross_entropy
from repro.layers.ffn import FeedForward, scaled_hidden_dim
from repro.layers.rope import LinearScaledRotaryEmbedding, RotaryEmbedding
from repro.layers.transformer import (
    Block,
    Decoder,
    Repeat,
    StackedTransformer,
    TransformerLayer,
)
