"""Basic layers: Linear, Embedding, Dropout, norms, activations."""

from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.config import REQUIRED, Required, config_class
from repro.core.utils import PartitionSpecLike
from repro.kernels import ops as kernel_ops
from repro.layers.base import (
    BaseLayer,
    KernelConfig,
    ParameterSpec,
    fan_in_init,
    normal_init,
    ones_init,
    zeros_init,
)

__all__ = [
    "get_activation",
    "Linear",
    "Embedding",
    "Dropout",
    "LayerNorm",
    "RMSNorm",
]


def _quick_gelu(x):
    return x * jax.nn.sigmoid(1.702 * x)


_ACTIVATIONS: Dict[str, Callable] = {
    "linear": lambda x: x,
    "nn.relu": jax.nn.relu,
    "nn.silu": jax.nn.silu,
    "nn.gelu": jax.nn.gelu,
    "nn.gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
    "nn.tanh": jnp.tanh,
    "nn.sigmoid": jax.nn.sigmoid,
    "quick_gelu": _quick_gelu,
    "nn.softplus": jax.nn.softplus,
    "nn.relu2": lambda x: jnp.square(jax.nn.relu(x)),
}


def get_activation(name: str) -> Callable:
    if name not in _ACTIVATIONS:
        raise KeyError(f"Unknown activation {name!r}; known: {sorted(_ACTIVATIONS)}")
    return _ACTIVATIONS[name]


class Linear(BaseLayer):
    """y = x @ W (+ b). Weight shape (input_dim, output_dim)."""

    # GEMM boundary: with DtypePolicy.fp8 set, inputs are fake-quantized
    # to the e4m3 grid in _to_compute (delayed per-tensor scaling).
    _fp8_boundary = True

    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        output_dim: Required[int] = REQUIRED
        bias: bool = True
        # Named-axis sharding of the weight; bias sharding is inferred from
        # the output axis (paper §4.2: "automatically infers the bias
        # sharding from the sharding of the model weights").
        weight_partition: PartitionSpecLike = None
        # Optional sharding constraint on outputs.
        output_partition: PartitionSpecLike = None

    def _create_layer_parameter_specs(self):
        cfg = self.config
        specs = {
            "weight": ParameterSpec(
                shape=(cfg.input_dim, cfg.output_dim),
                dtype=cfg.param_dtype,
                initializer=fan_in_init(),
                mesh_axes=cfg.weight_partition,
            )
        }
        if cfg.bias:
            out_axes = None
            if cfg.weight_partition is not None:
                out_axes = (cfg.weight_partition[-1],)
            specs["bias"] = ParameterSpec(
                shape=(cfg.output_dim,),
                dtype=cfg.param_dtype,
                initializer=zeros_init(),
                mesh_axes=out_axes,
                weight_decay_scale=0.0,
            )
        return specs

    def forward(self, x: jax.Array) -> jax.Array:
        x = self._to_compute(x)
        w = self.state["weight"].astype(x.dtype)
        y = x @ w
        if self.config.bias:
            y = y + self.state["bias"].astype(x.dtype)
        if self.config.output_partition is not None:
            y = self._shard(y, self.config.output_partition)
        return y


class Embedding(BaseLayer):
    """Token embedding with optional tied-head attend()."""

    @config_class
    class Config(BaseLayer.Config):
        num_embeddings: Required[int] = REQUIRED
        dim: Required[int] = REQUIRED
        weight_partition: PartitionSpecLike = ("model", "data")
        scale_by_sqrt_dim: bool = False  # gemma-style embedding scaling

    def _create_layer_parameter_specs(self):
        cfg = self.config
        return {
            "weight": ParameterSpec(
                shape=(cfg.num_embeddings, cfg.dim),
                dtype=cfg.param_dtype,
                initializer=normal_init(0.02),
                mesh_axes=cfg.weight_partition,
                weight_decay_scale=0.0,
            )
        }

    def forward(self, ids: jax.Array) -> jax.Array:
        w = self.state["weight"]
        out = jnp.take(w, ids, axis=0)
        if self.config.scale_by_sqrt_dim:
            out = out * jnp.sqrt(jnp.asarray(self.config.dim, out.dtype))
        return self._to_compute(out)

    def attend(self, x: jax.Array) -> jax.Array:
        """Tied LM head: logits = x @ E^T."""
        x = self._to_compute(x)
        w = self.state["weight"].astype(x.dtype)
        return x @ w.T


class Dropout(BaseLayer):
    @config_class
    class Config(BaseLayer.Config):
        rate: float = 0.0

    def forward(self, x: jax.Array) -> jax.Array:
        rate = self.config.rate
        if not self.is_training or rate == 0.0:
            return x
        keep = 1.0 - rate
        mask = jax.random.bernoulli(self.prng_key, p=keep, shape=x.shape)
        return jnp.where(mask, x / keep, jnp.zeros_like(x))


class LayerNorm(BaseLayer):
    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        eps: float = 1e-5
        use_bias: bool = True

    def _create_layer_parameter_specs(self):
        cfg = self.config
        specs = {
            "scale": ParameterSpec((cfg.input_dim,), cfg.param_dtype, ones_init(),
                                   weight_decay_scale=0.0)
        }
        if cfg.use_bias:
            specs["bias"] = ParameterSpec((cfg.input_dim,), cfg.param_dtype, zeros_init(),
                                          weight_decay_scale=0.0)
        return specs

    def forward(self, x: jax.Array) -> jax.Array:
        cfg = self.config
        x = self._to_compute(x)  # fp32 accumulation below is policy-invariant
        xf = x.astype(jnp.float32)
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + cfg.eps)
        y = y * self.state["scale"].astype(jnp.float32)
        if cfg.use_bias:
            y = y + self.state["bias"].astype(jnp.float32)
        return y.astype(x.dtype)


class RMSNorm(BaseLayer):
    """RMSNorm, fp32 accumulation; kernel selection via the registry."""

    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        eps: float = 1e-6
        # "unit_offset": gemma-style (1 + scale) parameterization.
        unit_offset: bool = False
        # Registry dispatch for the "rmsnorm" op (paper §4.2): "auto" picks
        # the Pallas row-tiled kernel on TPU inference and the autodiffable
        # ref path under training (the kernel is forward-only).
        kernel: KernelConfig = KernelConfig()

    def _create_layer_parameter_specs(self):
        cfg = self.config
        init = zeros_init() if cfg.unit_offset else ones_init()
        return {
            "scale": ParameterSpec((cfg.input_dim,), cfg.param_dtype, init,
                                   weight_decay_scale=0.0)
        }

    def forward(self, x: jax.Array) -> jax.Array:
        cfg = self.config
        x = self._to_compute(x)  # fp32 accumulation below is policy-invariant
        scale = self.state["scale"].astype(jnp.float32)
        if cfg.unit_offset:
            scale = scale + 1.0
        return kernel_ops.rmsnorm(x, scale, eps=cfg.eps,
                                  kernel=self.kernel_config,
                                  needs_grad=self.is_training)
