"""Multi-head / grouped-query attention with an encapsulated KV cache.

Token-mixer interface (shared with Mamba/RWKV so any of them is a drop-in
child of TransformerLayer — the paper's encapsulation claim, §6):

  forward(x, positions=None) -> y                       # full-sequence
  init_states(batch, max_len) -> state                  # empty cache
  prefill(x, positions=None) -> (state, y)              # fill cache
  extend_step(state, x_step) -> (state, y_step)         # decode step(s)

The KV cache layout (dense vs sliding-window ring buffer) is a private
detail of this layer: serving engines only see opaque state pytrees, which
is what lets paged/continuous-batching techniques integrate without touching
model code (paper §6).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import REQUIRED, Required, config_class, maybe_set
from repro.core.module import no_context
from repro.core.utils import PartitionSpecLike, remat_name
from repro.core.config import ConfigBase
from repro.kernels import ops as kernel_ops
from repro.layers.base import BaseLayer, KernelConfig, fan_in_init
from repro.layers.basic import Linear
from repro.layers.rope import BaseRotaryEmbedding, RotaryEmbedding
from repro.quantization import kv as kv_quant

__all__ = ["MultiheadAttention"]


class MultiheadAttention(BaseLayer):
    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        num_heads: Required[int] = REQUIRED
        num_kv_heads: Optional[int] = None  # None -> MHA
        head_dim: Optional[int] = None  # None -> input_dim // num_heads
        qkv_bias: bool = False
        out_bias: bool = False
        # Projection template: the DotGeneral-swap point (paper §4.2) — e.g.
        # QuantizedLinear replaces it via one config traversal.
        proj: ConfigBase = Linear.Config()
        # Swappable positional-embedding child; None disables RoPE.
        rope: Optional[BaseRotaryEmbedding.Config] = RotaryEmbedding.Config()
        causal: bool = True
        sliding_window: Optional[int] = None
        logit_softcap: Optional[float] = None
        # None -> 1/sqrt(head_dim); gemma2 overrides (query_pre_attn_scalar).
        query_scale: Optional[float] = None
        # Kernel selection + tiling for attention.fwd / attention.decode:
        # resolved per call by the kernel registry (capability predicates
        # pick Pallas flash / blockwise / ref per platform and feature set).
        # Mesh rules rewrite this tree-wide via KernelModifier (paper §4.2).
        # NOTE: the Pallas decode kernel assumes a replicated KV cache; the
        # layer reports sequence-sharded caches as a feature, so "auto"
        # resolves them to "ref" (whose logits_shard_fn keeps GSPMD in the
        # partial-softmax layout) and explicit "pallas" rejects with reason.
        kernel: KernelConfig = KernelConfig()
        # KV cache layout: "dense" (per-slot (B, T, Hkv, D) ring buffer) |
        # "paged" (shared pool of fixed-size pages + per-sequence page
        # tables, vLLM-style). Paged allocates KV on demand instead of
        # slots x max_len up front — the serving subsystem
        # (repro.serving) packs more concurrent sequences into the same
        # memory and evicts/restores them page-wise. Config choice, not a
        # code change (paper §4.2): engines only see opaque state pytrees.
        kv_cache_layout: str = "dense"
        # Tokens per physical page. On real TPUs use a multiple of the
        # sublane count (8 f32 / 16 bf16) for efficient pool tiling.
        page_size: int = 16
        # Physical pages in the shared pool (page 0 is reserved as the null
        # target of unmapped table entries and is never written). None ->
        # full residency: 1 + batch_size * ceil(max_len / page_size) pages,
        # which makes generate()-style whole-batch decoding work with the
        # identity page table that init_states installs when capacity
        # allows. The serving allocator sets this BELOW full residency and
        # owns the tables — that undercommit is where the >= 2x concurrency
        # at equal KV memory comes from.
        num_pages: Optional[int] = None
        # Named-axis shardings.
        qkv_weight_partition: PartitionSpecLike = ("data", "model")
        out_weight_partition: PartitionSpecLike = ("model", "data")
        # Activation sharding for (B, S, H*D) projections.
        hidden_partition: PartitionSpecLike = (("pod", "data"), None, "model")
        # KV cache sharding (B, T, Hkv, D).
        kv_cache_partition: PartitionSpecLike = (("pod", "data"), None, "model", None)
        kv_cache_dtype: Any = jnp.bfloat16

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        cfg = self.config
        if cfg.num_kv_heads is None:
            cfg.set(num_kv_heads=cfg.num_heads)
        if cfg.head_dim is None:
            cfg.set(head_dim=cfg.input_dim // cfg.num_heads)
        if cfg.num_heads % cfg.num_kv_heads != 0:
            raise ValueError(f"num_heads {cfg.num_heads} % num_kv_heads {cfg.num_kv_heads} != 0")
        if cfg.kv_cache_layout not in ("dense", "paged"):
            raise ValueError(f"Unknown kv_cache_layout {cfg.kv_cache_layout!r}")
        if cfg.kv_cache_layout == "paged" and cfg.sliding_window is not None:
            # The ring buffer IS the memory bound for sliding-window layers;
            # paging them would only add indirection.
            raise ValueError("kv_cache_layout='paged' does not support "
                             "sliding_window; keep the dense ring layout")
        # Quantized-pool format (int8 / fp8-e4m3 with per-slot scales in a
        # scale_pool leaf), or None for plain astype storage. Resolved once,
        # declaratively — the layer never branches on dtype names (that
        # logic is encapsulated in repro.quantization.kv), and an invalid
        # combination (int8 on a dense ring) fails here, at build time.
        self._kv_fmt = kv_quant.pool_format(cfg.kv_cache_dtype,
                                            layout=cfg.kv_cache_layout)
        proj = cfg.proj.clone().set(
            input_dim=cfg.input_dim,
            bias=cfg.qkv_bias,
            weight_partition=cfg.qkv_weight_partition,
            param_dtype=cfg.param_dtype,
        )
        maybe_set(proj, dtype_policy=cfg.dtype_policy)
        self._add_child("q_proj", proj.clone(output_dim=cfg.num_heads * cfg.head_dim))
        self._add_child("k_proj", proj.clone(output_dim=cfg.num_kv_heads * cfg.head_dim))
        self._add_child("v_proj", proj.clone(output_dim=cfg.num_kv_heads * cfg.head_dim))
        self._add_child(
            "o_proj",
            maybe_set(cfg.proj.clone().set(
                input_dim=cfg.num_heads * cfg.head_dim,
                output_dim=cfg.input_dim,
                bias=cfg.out_bias,
                weight_partition=cfg.out_weight_partition,
                param_dtype=cfg.param_dtype,
            ), dtype_policy=cfg.dtype_policy),
        )
        if cfg.rope is not None:
            rope_cfg = cfg.rope.clone()
            if not rope_cfg.dim:
                rope_cfg.set(dim=cfg.head_dim)
            self._add_child("rope", rope_cfg)

    # ------------------------------------------------------------------ utils

    def _project_qkv(self, x: jax.Array, positions: jax.Array):
        cfg = self.config
        x = self._to_compute(x)
        B, S, _ = x.shape
        q = self.q_proj(x)
        k = self.k_proj(x)
        v = self.v_proj(x)
        q = self._shard(q, cfg.hidden_partition)
        k = remat_name(k, "kv_proj")
        q = remat_name(q, "q_proj")
        q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
        k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
        v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
        if "rope" in self._children:
            q = self.rope.apply(q, positions)
            k = self.rope.apply(k, positions)
        return q, k, v

    def _kv_cache_replicated(self) -> bool:
        """Whether the KV cache is unsharded/replicated on the active mesh.

        Reported to the registry as a capability feature: the Pallas decode
        kernel has no shard_map plumbing yet, so a sharded cache would
        silently all-gather per decode step. "auto" resolves sharded caches
        to the ref path (whose logits_shard_fn keeps GSPMD in the
        partial-softmax layout); an explicit Pallas request fails resolution
        with this reason listed.
        """
        from repro.core.utils import current_mesh, resolve_spec

        cfg = self.config
        mesh = current_mesh()
        if mesh is None or cfg.kv_cache_partition is None:
            return True
        spec = resolve_spec(cfg.kv_cache_partition, mesh)

        def size(entry):
            names = entry if isinstance(entry, (tuple, list)) else (entry,)
            n = 1
            for name in names:
                if name is not None:
                    n *= mesh.shape[name]
            return n

        return not any(size(e) > 1 for e in tuple(spec))

    def _attend(self, q, k, v, *, q_positions, k_positions, decode=False,
                page_tables=None, scale_pool=None):
        cfg = self.config
        kwargs = dict(
            q_positions=q_positions,
            k_positions=k_positions,
            causal=cfg.causal,
            sliding_window=cfg.sliding_window,
            logit_softcap=cfg.logit_softcap,
            scale=cfg.query_scale,
        )
        if decode:
            logits_shard_fn = None
            if page_tables is None and cfg.kv_cache_partition is not None:
                kv_spec = tuple(cfg.kv_cache_partition)
                # logits (B, Hkv, G, S', T): batch + cache-seq axes from config.
                spec = (kv_spec[0], None, None, None, kv_spec[1])
                logits_shard_fn = lambda l: self._shard(l, spec)  # noqa: E731
            return kernel_ops.decode_attention(
                q, k, v, page_tables=page_tables, scale_pool=scale_pool,
                replicated_cache=self._kv_cache_replicated(),
                logits_shard_fn=logits_shard_fn,
                kernel=self.kernel_config, **kwargs)
        return kernel_ops.flash_attention(
            q, k, v, kernel=self.kernel_config, needs_grad=self.is_training,
            **kwargs)

    # --------------------------------------------------------------- forward

    def forward(self, x: jax.Array, positions: Optional[jax.Array] = None) -> jax.Array:
        cfg = self.config
        B, S, _ = x.shape
        if positions is None:
            positions = jnp.arange(S)
        q, k, v = self._project_qkv(x, positions)
        out = self._attend(q, k, v, q_positions=positions, k_positions=positions)
        out = remat_name(out, "attn_out")
        out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
        out = self._shard(out, cfg.hidden_partition)
        return self.o_proj(out)

    # ---------------------------------------------------------------- decode

    def _cache_len(self, max_len: int) -> int:
        cfg = self.config
        if cfg.sliding_window is not None:
            return min(max_len, cfg.sliding_window)
        return max_len

    def _paged_geometry(self, batch_size: int, max_len: int):
        """(page_size, logical pages per sequence, physical pool pages)."""
        cfg = self.config
        page = cfg.page_size
        n_logical = -(-max_len // page)
        num_pages = cfg.num_pages
        if num_pages is None:
            num_pages = 1 + batch_size * n_logical  # + the reserved null page
        return page, n_logical, num_pages

    @no_context
    def state_partition_specs(self, *_):
        """Named-axis shardings for the init_states pytree (used by launchers
        to build explicit in_shardings for serve_step)."""
        cfg = self.config
        kv = tuple(cfg.kv_cache_partition) if cfg.kv_cache_partition else (None,) * 4
        if cfg.kv_cache_layout == "paged":
            pool = (None, None, kv[2], kv[3])  # (P, page, Hkv, D)
            specs = {"k_pool": pool, "v_pool": pool, "pos_pool": (None, None),
                     "page_table": (kv[0], None), "index": (kv[0],)}
            if self._kv_fmt is not None:
                specs["scale_pool"] = (None, None, None)  # (P, page, 2)
            return specs
        return {"k": kv, "v": kv, "pos": (kv[0], kv[1]), "index": (kv[0],)}

    def init_states(self, batch_size: int, max_len: int) -> Dict[str, Any]:
        """Empty KV cache. ``pos`` tracks the absolute position in each slot
        (-1 = invalid), which makes ring-buffer masking trivial.

        Paged layout: a shared ``(num_pages, page_size, Hkv, D)`` pool, a
        per-page position pool, and per-sequence page tables. Page 0 is the
        reserved null page (unmapped table entries clamp to it on reads and
        are masked; writes through unmapped entries are dropped). When the
        pool is big enough for full residency the tables start as the
        identity layout so plain batched generation works out of the box;
        otherwise they start unmapped (-1) and a serving-side allocator owns
        them.
        """
        cfg = self.config
        if cfg.kv_cache_layout == "paged":
            page, n_logical, P = self._paged_geometry(batch_size, max_len)
            pool_shape = (P, page, cfg.num_kv_heads, cfg.head_dim)
            pool_spec = None
            if cfg.kv_cache_partition is not None:
                kv = tuple(cfg.kv_cache_partition)
                pool_spec = (None, None, kv[2], kv[3])
            if P >= 1 + batch_size * n_logical:
                table = 1 + jnp.arange(batch_size * n_logical, dtype=jnp.int32
                                       ).reshape(batch_size, n_logical)
            else:
                table = jnp.full((batch_size, n_logical), -1, jnp.int32)
            storage = (self._kv_fmt.storage_dtype if self._kv_fmt is not None
                       else cfg.kv_cache_dtype)
            state = {
                "k_pool": self._shard(jnp.zeros(pool_shape, storage),
                                      pool_spec),
                "v_pool": self._shard(jnp.zeros(pool_shape, storage),
                                      pool_spec),
                "pos_pool": jnp.full((P, page), -1, jnp.int32),
                "page_table": table,
                "index": jnp.zeros((batch_size,), jnp.int32),
            }
            if self._kv_fmt is not None:
                state["scale_pool"] = kv_quant.init_scale_pool(P, page)
            return state
        T = self._cache_len(max_len)
        shape = (batch_size, T, cfg.num_kv_heads, cfg.head_dim)
        cache = {
            "k": jnp.zeros(shape, cfg.kv_cache_dtype),
            "v": jnp.zeros(shape, cfg.kv_cache_dtype),
            # Per-row slot positions/index: continuous batching admits new
            # requests into individual slots mid-flight (paper §6).
            "pos": jnp.full((batch_size, T), -1, jnp.int32),
            "index": jnp.zeros((batch_size,), jnp.int32),
        }
        cache["k"] = self._shard(cache["k"], cfg.kv_cache_partition)
        cache["v"] = self._shard(cache["v"], cfg.kv_cache_partition)
        return cache

    def _paged_scatter(self, state: Dict[str, Any], k: jax.Array,
                       v: jax.Array, positions: jax.Array,
                       valid: jax.Array) -> Dict[str, Any]:
        """Write tokens at absolute ``positions`` (B, S) into the page pool
        through each sequence's page table row. Tokens that are invalid
        (bucket padding) or whose logical page is unmapped scatter out of
        bounds and are dropped — unmapped writes can never corrupt the null
        page or another sequence's pages.
        """
        cfg = self.config
        table = state["page_table"]  # (B, N)
        P, page = state["pos_pool"].shape
        # Positions beyond table capacity (no ring in the paged layout) are
        # dropped, like bucket padding.
        valid = valid & (positions >= 0) & (positions < table.shape[1] * page)
        logical = jnp.clip(positions // page, 0, table.shape[1] - 1)
        phys = jnp.take_along_axis(table, logical, axis=1)  # (B, S)
        flat = phys * page + positions % page
        oob = P * page
        flat = jnp.where(valid & (phys > 0), flat, oob)  # page 0 = null
        H, D = cfg.num_kv_heads, cfg.head_dim
        if self._kv_fmt is not None:
            # Quantize-on-write: per-token-slot scales scatter through the
            # same (OOB-dropping) flat index as the payload, so a dropped
            # write drops its scale too. Deterministic quantization is what
            # keeps prefix hits exact: a shared page holds bitwise the same
            # bytes a cold prefill would produce.
            k_st, v_st, scales = kv_quant.quantize_kv_write(k, v,
                                                            self._kv_fmt)
        else:
            k_st = k.astype(cfg.kv_cache_dtype)
            v_st = v.astype(cfg.kv_cache_dtype)
            scales = None
        new_k = state["k_pool"].reshape(oob, H, D).at[flat].set(
            k_st).reshape(P, page, H, D)
        new_v = state["v_pool"].reshape(oob, H, D).at[flat].set(
            v_st).reshape(P, page, H, D)
        new_pos = state["pos_pool"].reshape(oob).at[flat].set(
            positions.astype(jnp.int32)).reshape(P, page)
        pools = {"k_pool": new_k, "v_pool": new_v, "pos_pool": new_pos}
        if scales is not None:
            pools["scale_pool"] = state["scale_pool"].reshape(oob, 2).at[
                flat].set(scales).reshape(P, page, 2)
        return pools

    def prefill(self, state: Dict[str, Any], x: jax.Array,
                positions: Optional[jax.Array] = None,
                length: Optional[jax.Array] = None
                ) -> Tuple[Dict[str, Any], jax.Array]:
        """Runs the full forward over the prompt and fills the cache.

        ``length`` (optional scalar) marks only the first ``length`` tokens
        of ``x`` as real: trailing bucket padding is neither written to the
        cache (its scatter indices land out of bounds and are dropped) nor
        counted in ``index``. This is what lets the serving engine admit
        prompts through a small set of power-of-two padded shapes (one
        compile per bucket) without polluting the cache.
        """
        cfg = self.config
        B, S, _ = x.shape
        if positions is None:
            positions = jnp.arange(S)
        q, k, v = self._project_qkv(x, positions)
        out = self._attend(q, k, v, q_positions=positions, k_positions=positions)
        out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
        y = self.o_proj(out)

        length = jnp.asarray(S if length is None else length, jnp.int32)
        if cfg.kv_cache_layout == "paged":
            pos_b = jnp.broadcast_to(positions, (B, S))
            pools = self._paged_scatter(state, k, v, pos_b,
                                        valid=pos_b < length)
            return {**pools, "page_table": state["page_table"],
                    "index": jnp.broadcast_to(length, (B,))}, y
        T = state["k"].shape[1]
        if S > T:
            # Ring layout: keep the last T *valid* tokens.
            start = jnp.clip(length - T, 0, S - T)
            k_keep = jax.lax.dynamic_slice_in_dim(k, start, T, axis=1)
            v_keep = jax.lax.dynamic_slice_in_dim(v, start, T, axis=1)
            p_keep = jax.lax.dynamic_slice_in_dim(positions, start, T, axis=0)
        else:
            k_keep, v_keep, p_keep = k, v, positions
        valid = p_keep < length
        # Invalid tokens scatter to index T (out of bounds -> dropped), so
        # bucket padding never overwrites live ring slots.
        slots = jnp.where(valid, p_keep % T, T)
        new_k = state["k"].at[:, slots].set(k_keep.astype(cfg.kv_cache_dtype))
        new_v = state["v"].at[:, slots].set(v_keep.astype(cfg.kv_cache_dtype))
        new_pos = state["pos"].at[:, slots].set(p_keep.astype(jnp.int32)[None, :])
        new_state = {
            "k": self._shard(new_k, cfg.kv_cache_partition),
            "v": self._shard(new_v, cfg.kv_cache_partition),
            "pos": new_pos,
            "index": jnp.broadcast_to(length, (B,)),
        }
        return new_state, y

    def extend_step(self, state: Dict[str, Any], x_step: jax.Array
                    ) -> Tuple[Dict[str, Any], jax.Array]:
        """Decode S' >= 1 new tokens against the cache.

        S' > 1 with causal masking among the new tokens doubles as the
        *chunked-prefill* program: the serving scheduler feeds prompt chunks
        through this path so a long prompt never stalls in-flight decodes.
        """
        cfg = self.config
        B, S_new, _ = x_step.shape
        index = state["index"]  # (B,)
        positions = index[:, None] + jnp.arange(S_new)[None, :]  # (B, S')
        q, k, v = self._project_qkv(x_step, positions)

        if cfg.kv_cache_layout == "paged":
            pools = self._paged_scatter(
                state, k, v, positions, valid=jnp.ones_like(positions, bool))
            out = self._attend(
                q, pools["k_pool"], pools["v_pool"],
                q_positions=positions, k_positions=pools["pos_pool"],
                page_tables=state["page_table"],
                scale_pool=pools.get("scale_pool"), decode=True)
            out = out.reshape(B, S_new, cfg.num_heads * cfg.head_dim)
            return {**pools, "page_table": state["page_table"],
                    "index": index + S_new}, self.o_proj(out)

        T = state["k"].shape[1]
        slots = positions % T  # (B, S')
        rows = jnp.arange(B)[:, None]
        new_k = state["k"].at[rows, slots].set(k.astype(cfg.kv_cache_dtype))
        new_v = state["v"].at[rows, slots].set(v.astype(cfg.kv_cache_dtype))
        new_pos = state["pos"].at[rows, slots].set(positions.astype(jnp.int32))

        out = self._attend(
            q,
            new_k.astype(q.dtype),
            new_v.astype(q.dtype),
            q_positions=positions,
            k_positions=new_pos,
            decode=True,
        )
        out = out.reshape(B, S_new, cfg.num_heads * cfg.head_dim)
        y = self.o_proj(out)
        new_state = {
            "k": self._shard(new_k, cfg.kv_cache_partition),
            "v": self._shard(new_v, cfg.kv_cache_partition),
            "pos": new_pos,
            "index": index + S_new,
        }
        return new_state, y
