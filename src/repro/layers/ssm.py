"""Mamba-1 selective SSM token mixer (for the Jamba hybrid stack).

TPU adaptation: the recurrence h_t = Ā_t h_{t-1} + B̄_t x_t is evaluated with
``jax.lax.associative_scan`` (log-depth parallel prefix) instead of a CUDA
selective-scan kernel — the TPU-idiomatic mapping of the paper's
"hand-tuned kernels where compilers fall short" principle. Decode keeps an
O(1) state: (h, conv ring), which is why jamba runs the 524k-token shape.

Implements the token-mixer interface (drop-in for attention in
TransformerLayer — the hybrid stack is pure config).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import REQUIRED, Required, config_class
from repro.core.module import no_context
from repro.core.utils import PartitionSpecLike, remat_name
from repro.layers.base import BaseLayer, ParameterSpec, fan_in_init, normal_init, zeros_init

__all__ = ["MambaMixer"]


def _a_log_init():
    def init(key, shape, dtype):
        # S4D-real init: A = -(1..N) per channel.
        d_inner, n = shape
        a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (d_inner, 1))
        return jnp.log(a).astype(dtype)

    return init


def _dt_bias_init(dt_min=1e-3, dt_max=1e-1):
    def init(key, shape, dtype):
        # Sample dt uniformly in log space; store softplus^-1(dt).
        u = jax.random.uniform(key, shape)
        dt = jnp.exp(u * (math.log(dt_max) - math.log(dt_min)) + math.log(dt_min))
        return jnp.log(jnp.expm1(dt)).astype(dtype)

    return init


class MambaMixer(BaseLayer):
    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        state_dim: int = 16
        conv_width: int = 4
        expand: int = 2
        dt_rank: Optional[int] = None  # None -> ceil(input_dim / 16)
        in_weight_partition: PartitionSpecLike = ("data", "model")
        out_weight_partition: PartitionSpecLike = ("model", "data")
        hidden_partition: PartitionSpecLike = (("pod", "data"), None, "model")
        # Chunked selective scan: parallel (associative) within a chunk,
        # sequential across chunks, chunk bodies rematerialized — bounds the
        # fp32 (B, chunk, d_inner, N) working set instead of materializing
        # log-depth (B, S, d_inner, N) buffers.
        scan_chunk_size: int = 256
        scan_unroll_chunks: bool = False

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        cfg = self.config
        if cfg.dt_rank is None:
            cfg.set(dt_rank=-(-cfg.input_dim // 16))

    @property
    def _d_inner(self) -> int:
        return self.config.expand * self.config.input_dim

    def _create_layer_parameter_specs(self):
        cfg = self.config
        d, di, n, r, w = (cfg.input_dim, self._d_inner, cfg.state_dim,
                          cfg.dt_rank, cfg.conv_width)
        return {
            "in_proj": ParameterSpec((d, 2 * di), cfg.param_dtype, fan_in_init(),
                                     mesh_axes=cfg.in_weight_partition),
            "conv_w": ParameterSpec((w, di), cfg.param_dtype, fan_in_init(fan_in_axes=(0,)),
                                    mesh_axes=(None, "model")),
            "conv_b": ParameterSpec((di,), cfg.param_dtype, zeros_init(),
                                    mesh_axes=("model",), weight_decay_scale=0.0),
            "x_proj": ParameterSpec((di, r + 2 * n), cfg.param_dtype, fan_in_init(),
                                    mesh_axes=("model", None)),
            "dt_proj": ParameterSpec((r, di), cfg.param_dtype,
                                     fan_in_init(fan_in_axes=(0,)),
                                     mesh_axes=(None, "model")),
            "dt_bias": ParameterSpec((di,), cfg.param_dtype, _dt_bias_init(),
                                     mesh_axes=("model",), weight_decay_scale=0.0),
            "A_log": ParameterSpec((di, n), jnp.float32, _a_log_init(),
                                   mesh_axes=("model", None), weight_decay_scale=0.0),
            "D": ParameterSpec((di,), jnp.float32,
                               lambda k, s, dt: jnp.ones(s, dt),
                               mesh_axes=("model",), weight_decay_scale=0.0),
            "out_proj": ParameterSpec((di, d), cfg.param_dtype, fan_in_init(),
                                      mesh_axes=cfg.out_weight_partition),
        }

    # ------------------------------------------------------------------ core

    def _conv_full(self, x_in: jax.Array, conv_init: jax.Array) -> jax.Array:
        """Causal depthwise conv over (B, S, di), seeded with ``conv_init``
        (the previous W-1 inputs; zeros for a fresh sequence)."""
        W = self.config.conv_width
        x_pad = jnp.concatenate([conv_init.astype(x_in.dtype), x_in], axis=1)
        w = self.state["conv_w"].astype(x_in.dtype)  # (W, di)
        # Sum of shifted slices: cheap + layout-friendly for small W.
        S = x_in.shape[1]
        out = sum(x_pad[:, i:i + S] * w[i] for i in range(W))
        return out + self.state["conv_b"].astype(x_in.dtype)

    def _ssm_params(self, x_conv: jax.Array):
        cfg = self.config
        n, r = cfg.state_dim, cfg.dt_rank
        proj = x_conv @ self.state["x_proj"].astype(x_conv.dtype)
        dt_in, B_mat, C_mat = jnp.split(proj, [r, r + n], axis=-1)
        dt = dt_in @ self.state["dt_proj"].astype(x_conv.dtype)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + self.state["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(self.state["A_log"])  # (di, n)
        a_bar = jnp.exp(dt[..., None] * A)  # (B,S,di,n)
        bx = (dt * x_conv.astype(jnp.float32))[..., None] * B_mat.astype(jnp.float32)[..., None, :]
        return a_bar, bx, C_mat.astype(jnp.float32)

    @staticmethod
    def _combine(left, right):
        a_l, b_l = left
        a_r, b_r = right
        return a_l * a_r, a_r * b_l + b_r

    def _scan_chunk(self, h, xc, valid=None):
        """One chunk: derive SSM params from x_conv, parallel-prefix within
        the chunk, contract to y immediately (the (B,C,di,N) states never
        leave the chunk). ``valid`` (optional (1|B, C) bool) turns padding
        steps into identity transitions (decay 1, input 0) so bucket-padded
        prefill leaves the recurrent state exact."""
        a_bar, bx, C_mat = self._ssm_params(xc)
        if valid is not None:
            a_bar = jnp.where(valid[..., None, None], a_bar, 1.0)
            bx = jnp.where(valid[..., None, None], bx, 0.0)
        bx = bx.at[:, 0].add(a_bar[:, 0] * h)
        _, h_all = jax.lax.associative_scan(self._combine, (a_bar, bx), axis=1)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, C_mat)
        return h_all[:, -1], y

    def _run(self, x: jax.Array, h0: jax.Array, conv_init: jax.Array,
             valid: Optional[jax.Array] = None,
             length: Optional[jax.Array] = None):
        """Returns (y, h_final, conv_tail). With ``valid``/``length`` set,
        only the first ``length`` tokens update the recurrence and the conv
        tail is taken at the valid frontier (bucket-padded admission)."""
        cfg = self.config
        x = self._to_compute(x)
        xz = x @ self.state["in_proj"].astype(x.dtype)
        # Constrain BEFORE the split so neither half (nor their backward
        # cotangents) ever exists model-replicated.
        xz = self._shard(xz, cfg.hidden_partition)
        x_in, z = jnp.split(xz, 2, axis=-1)
        x_in = self._shard(x_in, cfg.hidden_partition)
        z = self._shard(z, cfg.hidden_partition)
        x_conv = jax.nn.silu(self._conv_full(x_in, conv_init))

        B, S, di = x_conv.shape
        C = cfg.scan_chunk_size
        if S % C != 0 or S <= C:
            h_final, y = self._scan_chunk(h0, x_conv, valid)
        else:
            n = S // C
            xs = jnp.moveaxis(x_conv.reshape(B, n, C, di), 1, 0)
            # Re-constrain after reshape/moveaxis: these xs are saved as scan
            # residuals for the whole backward — unconstrained they end up
            # model-replicated (2.1 GB/layer at jamba scale).
            hp = self.config.hidden_partition
            if hp:
                xs = self._shard(xs, (None,) + tuple(hp))
            if valid is not None:
                # Masked admission prefill goes through the same chunked
                # scan — long buckets must not materialize (B,S,di,N) states.
                Bv = valid.shape[0]
                vs = jnp.moveaxis(valid.reshape(Bv, n, C), 1, 0)
                body = jax.checkpoint(
                    lambda h, xv: self._scan_chunk(h, xv[0], xv[1]),
                    prevent_cse=False)
                h_final, ys = jax.lax.scan(body, h0, (xs, vs),
                                           unroll=cfg.scan_unroll_chunks)
            else:
                body = jax.checkpoint(self._scan_chunk, prevent_cse=False)
                h_final, ys = jax.lax.scan(body, h0, xs,
                                           unroll=cfg.scan_unroll_chunks)
            y = jnp.moveaxis(ys, 0, 1).reshape(B, S, di)

        y = y + self.state["D"] * x_conv.astype(jnp.float32)
        y = y.astype(x.dtype) * jax.nn.silu(z)
        y = remat_name(y, "mixer_out")
        out = y @ self.state["out_proj"].astype(x.dtype)

        W = cfg.conv_width
        tail_src = jnp.concatenate([conv_init.astype(x_in.dtype), x_in], axis=1)
        if W <= 1:
            conv_tail = tail_src[:, :0]
        elif length is None:
            conv_tail = tail_src[:, -(W - 1):]
        else:
            # Last W-1 inputs before the valid frontier: token p of x_in sits
            # at tail_src index (W-1)+p, so the window starts at ``length``.
            conv_tail = jax.lax.dynamic_slice_in_dim(tail_src, length, W - 1,
                                                     axis=1)
        return out, h_final, conv_tail

    # ------------------------------------------------------------- interface

    def forward(self, x: jax.Array, positions: Optional[jax.Array] = None) -> jax.Array:
        B = x.shape[0]
        h0 = jnp.zeros((B, self._d_inner, self.config.state_dim), jnp.float32)
        conv0 = jnp.zeros((B, self.config.conv_width - 1, self._d_inner), x.dtype)
        y, _, _ = self._run(x, h0, conv0)
        return y

    @no_context
    def state_partition_specs(self, *_):
        b = self.config.hidden_partition[0] if self.config.hidden_partition else None
        return {"h": (b, "model", None), "conv": (b, None, "model"), "index": (b,)}

    def init_states(self, batch_size: int, max_len: int) -> Dict[str, Any]:
        cfg = self.config
        return {
            "h": jnp.zeros((batch_size, self._d_inner, cfg.state_dim), jnp.float32),
            "conv": jnp.zeros((batch_size, cfg.conv_width - 1, self._d_inner),
                              jnp.bfloat16),
            "index": jnp.zeros((batch_size,), jnp.int32),
        }

    def prefill(self, state, x, positions=None, length=None):
        if length is None:
            y, h, conv = self._run(x, state["h"], state["conv"])
            new_index = state["index"] + x.shape[1]
        else:
            length = jnp.asarray(length, jnp.int32)
            valid = (jnp.arange(x.shape[1]) < length)[None, :]
            y, h, conv = self._run(x, state["h"], state["conv"],
                                   valid=valid, length=length)
            new_index = state["index"] + length
        return {"h": h, "conv": conv.astype(state["conv"].dtype),
                "index": new_index}, y

    def extend_step(self, state, x_step):
        """Sequential decode for S' >= 1 tokens (scan over steps)."""
        cfg = self.config
        x_step = self._to_compute(x_step)
        B, S_new, _ = x_step.shape
        x_in, z = jnp.split(x_step @ self.state["in_proj"].astype(x_step.dtype), 2, axis=-1)

        conv_w = self.state["conv_w"].astype(x_step.dtype)
        conv_b = self.state["conv_b"].astype(x_step.dtype)

        def step(carry, xt):
            h, conv = carry  # (B,di,n), (B,W-1,di)
            x_t, z_t = xt  # (B,di)
            window = jnp.concatenate([conv, x_t[:, None]], axis=1)  # (B,W,di)
            xc = jnp.einsum("bwd,wd->bd", window, conv_w) + conv_b
            xc = jax.nn.silu(xc)
            a_bar, bx, C_mat = self._ssm_params(xc[:, None])  # S=1
            a1, b1, c1 = a_bar[:, 0], bx[:, 0], C_mat[:, 0]
            h = a1 * h + b1
            y = jnp.einsum("bdn,bn->bd", h, c1) + self.state["D"] * xc.astype(jnp.float32)
            y = y.astype(x_t.dtype) * jax.nn.silu(z_t)
            new_conv = window[:, 1:].astype(conv.dtype)
            return (h, new_conv), y

        (h, conv), ys = jax.lax.scan(
            step,
            (state["h"], state["conv"].astype(x_step.dtype)),
            (jnp.moveaxis(x_in, 1, 0), jnp.moveaxis(z, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1) @ self.state["out_proj"].astype(x_step.dtype)
        return {"h": h, "conv": conv.astype(state["conv"].dtype),
                "index": state["index"] + S_new}, y
