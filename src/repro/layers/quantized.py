"""Compatibility shim: quantized layers moved to ``repro.quantization``.

The w8a8 ``QuantizedLinear`` / ``Int8ConfigModifier`` now live in
:mod:`repro.quantization.linear` (with the raw numerics in
:mod:`repro.quantization.numerics`), alongside the quantized paged-KV
formats and the fp8 train-compute path. This module re-exports the
original names so existing imports keep working.
"""

from repro.quantization.linear import (Int8ConfigModifier, QuantizedLinear,
                                       quantize_int8)

__all__ = ["QuantizedLinear", "Int8ConfigModifier", "quantize_int8"]
