"""Transformer composition: TransformerLayer, Block, Repeat (scan), Decoder.

Composition rules (the paper's modularity story):
  * ``TransformerLayer.self_attention`` is ANY token mixer (attention, Mamba,
    RWKV6) — they share the forward/init_states/prefill/extend_step
    interface, so hybrid models are pure config.
  * ``TransformerLayer.feed_forward`` is ANY FFN-compatible module (dense FFN,
    MoE, residual-MoE) — MoE is a drop-in replacement (§2.1).
  * ``Repeat`` stacks identical layers (or identical heterogeneous *blocks*)
    with ``lax.scan`` over stacked params — keeping HLO size O(1) in depth,
    which is what makes 72-layer × 512-chip AOT dry-runs tractable.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.config import REQUIRED, ConfigBase, Required, config_class, maybe_set
from repro.core.module import functional, no_context
from repro.core.utils import PartitionSpecLike, remat_name
from repro.layers.attention import MultiheadAttention
from repro.layers.base import BaseLayer, ParameterSpec
from repro.layers.basic import Dropout, Embedding, Linear, RMSNorm
from repro.layers.ffn import FeedForward

__all__ = ["TransformerLayer", "Block", "Repeat", "StackedTransformer", "Decoder"]


class TransformerLayer(BaseLayer):
    """Pre-norm residual layer: x + mixer(norm(x)); x + ffn(norm(x)).

    Optional post-norms (gemma2 'sandwich') via config flags.
    """

    @config_class
    class Config(BaseLayer.Config):
        input_dim: Required[int] = REQUIRED
        self_attention: ConfigBase = MultiheadAttention.Config()
        feed_forward: ConfigBase = FeedForward.Config()
        norm: ConfigBase = RMSNorm.Config()
        use_post_attention_norm: bool = False
        use_post_ffn_norm: bool = False
        residual_dropout: float = 0.0
        # AXLearn-style default: batch over (pod, data), embedding dim over
        # "model" — keeps scan-carry activations (the remat residuals) fully
        # sharded instead of model-axis-replicated.
        activation_partition: PartitionSpecLike = (("pod", "data"), None, "model")

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        cfg = self.config

        def with_dim(c, field="input_dim"):
            c = c.clone()
            if field in c.keys():
                cur = getattr(c, field)
                if not cur:
                    c.set(**{field: cfg.input_dim})
            if "dtype_policy" in c.keys():
                maybe_set(c, dtype_policy=cfg.dtype_policy)
            return c

        self._add_child("attn_norm", with_dim(cfg.norm))
        self._add_child("self_attention", with_dim(cfg.self_attention))
        self._add_child("ffn_norm", with_dim(cfg.norm))
        self._add_child("feed_forward", with_dim(cfg.feed_forward))
        if cfg.use_post_attention_norm:
            self._add_child("post_attn_norm", with_dim(cfg.norm))
        if cfg.use_post_ffn_norm:
            self._add_child("post_ffn_norm", with_dim(cfg.norm))
        if cfg.residual_dropout:
            self._add_child("dropout", Dropout.default_config().set(rate=cfg.residual_dropout))

    def _maybe_dropout(self, x):
        if self.config.residual_dropout:
            return self.dropout(x)
        return x

    def _ffn_block(self, x):
        cfg = self.config
        h = self.feed_forward(self.ffn_norm(x))
        if cfg.use_post_ffn_norm:
            h = self.post_ffn_norm(h)
        return x + self._maybe_dropout(h)

    # Residual-branch interface (the reversible decomposition): forward() is
    # exactly x + attn_branch(x) followed by x + ffn_branch(x). A two-stream
    # reversible stack (repro.memopt.reversible) calls the branches WITHOUT
    # the residual adds — their presence is what marks a layer invertible.

    def attn_branch(self, x, positions: Optional[jax.Array] = None):
        """F(x) = attn(norm(x)) — the attention residual branch alone."""
        cfg = self.config
        x = self._to_compute(x)
        x = self._shard(x, cfg.activation_partition)
        h = self.self_attention(self.attn_norm(x), positions=positions)
        if cfg.use_post_attention_norm:
            h = self.post_attn_norm(h)
        return self._shard(h, cfg.activation_partition)

    def ffn_branch(self, x):
        """G(x) = ffn(norm(x)) — the feed-forward residual branch alone."""
        cfg = self.config
        x = self._to_compute(x)
        x = self._shard(x, cfg.activation_partition)
        h = self.feed_forward(self.ffn_norm(x))
        if cfg.use_post_ffn_norm:
            h = self.post_ffn_norm(h)
        return self._shard(h, cfg.activation_partition)

    def forward(self, x: jax.Array, positions: Optional[jax.Array] = None) -> jax.Array:
        cfg = self.config
        x = self._to_compute(x)  # residual stream runs in the compute dtype
        x = self._shard(x, cfg.activation_partition)
        h = self.self_attention(self.attn_norm(x), positions=positions)
        if cfg.use_post_attention_norm:
            h = self.post_attn_norm(h)
        x = x + self._maybe_dropout(h)
        # Constrain the OUTPUT as well: it becomes the scan carry (= the
        # remat residual that lives for the whole backward pass) — without
        # this GSPMD may keep loop carries model-replicated.
        return self._shard(self._ffn_block(x), cfg.activation_partition)

    # decode interface — state is the mixer's (opaque) state
    @no_context
    def state_partition_specs(self, *_):
        return self.self_attention.state_partition_specs()

    def init_states(self, batch_size: int, max_len: int):
        return self.self_attention.init_states(batch_size, max_len)

    def prefill(self, state, x, positions=None, length=None):
        cfg = self.config
        x = self._to_compute(x)
        x = self._shard(x, cfg.activation_partition)
        state, h = self.self_attention.prefill(
            state, self.attn_norm(x), positions=positions, length=length)
        if cfg.use_post_attention_norm:
            h = self.post_attn_norm(h)
        x = x + h
        return state, self._ffn_block(x)

    def extend_step(self, state, x_step):
        cfg = self.config
        x_step = self._to_compute(x_step)
        state, h = self.self_attention.extend_step(state, self.attn_norm(x_step))
        if cfg.use_post_attention_norm:
            h = self.post_attn_norm(h)
        x = x_step + h
        return state, self._ffn_block(x)


class Block(BaseLayer):
    """A fixed heterogeneous sequence of layers (e.g. jamba's 7×mamba + 1×attn
    super-block, or gemma2's (local, global) pair). Blocks are the unit that
    ``Repeat`` scans over."""

    @config_class
    class Config(BaseLayer.Config):
        layers: Required[List[ConfigBase]] = REQUIRED
        # Nested remat: checkpoint each layer individually so the block's
        # backward recomputes ONE layer's working set at a time instead of
        # holding all of them live (crucial for 8-layer jamba super-blocks).
        remat_each_layer: bool = False

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._layer_names = []
        for i, layer_cfg in enumerate(cfg.layers):
            name = f"layer{i}"
            layer_cfg = layer_cfg.clone()
            if "dtype_policy" in layer_cfg.keys():
                maybe_set(layer_cfg, dtype_policy=cfg.dtype_policy)
            self._add_child(name, layer_cfg)
            self._layer_names.append(name)

    def forward(self, x, positions=None):
        ctx = self._ctx
        nested = self.config.remat_each_layer and ctx.is_training
        for name in self._layer_names:
            child = getattr(self, name)
            if not nested:
                x = child(x, positions=positions)
                continue
            key = None
            if ctx.prng_key is not None:
                import zlib

                key = jax.random.fold_in(
                    ctx.prng_key, zlib.crc32(name.encode()))

            def fn(params, x, child=child, key=key):
                out, col = functional(
                    child, state=params, inputs={"x": x, "positions": positions},
                    prng_key=key, is_training=True)
                return out, (col.summaries, col.module_outputs)

            x, (summaries, module_outputs) = jax.checkpoint(
                fn, prevent_cse=False)(ctx.state.get(name, {}), x)
            for k, v in summaries.items():
                ctx.add_summary(f"{name}/{k}", v)
            for k, v in module_outputs.items():
                ctx.add_module_output(f"{name}/{k}", v)
        return x

    @no_context
    def state_partition_specs(self, *_):
        return {n: getattr(self, n).state_partition_specs()
                for n in self._layer_names}

    def init_states(self, batch_size: int, max_len: int):
        return {n: getattr(self, n).init_states(batch_size, max_len)
                for n in self._layer_names}

    def prefill(self, state, x, positions=None, length=None):
        new_state = {}
        for n in self._layer_names:
            new_state[n], x = getattr(self, n).prefill(
                state[n], x, positions=positions, length=length)
        return new_state, x

    def extend_step(self, state, x_step):
        new_state = {}
        for n in self._layer_names:
            new_state[n], x_step = getattr(self, n).extend_step(state[n], x_step)
        return new_state, x_step


def _stack_spec(spec: ParameterSpec, num: int) -> ParameterSpec:
    axes = spec.mesh_axes
    new_axes = (None,) + tuple(axes) if axes is not None else None
    return ParameterSpec(
        shape=(num,) + tuple(spec.shape),
        dtype=spec.dtype,
        initializer=spec.initializer,
        mesh_axes=new_axes,
        weight_decay_scale=spec.weight_decay_scale,
    )


class Repeat(BaseLayer):
    """num_layers × layer, parameters stacked on a leading axis, lax.scan'd.

    Side outputs emitted by inner layers (summaries, MoE aux losses) are
    collected per-iteration by the scan and re-emitted stacked — ancestors
    remain oblivious, preserving encapsulation through the scan boundary.
    """

    @config_class
    class Config(BaseLayer.Config):
        layer: Required[ConfigBase] = REQUIRED
        num_layers: Required[int] = REQUIRED
        # None = no remat; otherwise a policy spec string resolved by
        # repro.trainer.remat.policy_from_spec (e.g. "full",
        # "save:attn_out,ffn_out", "offload:ffn_hidden").
        remat_policy: Optional[str] = "full"
        # lax.scan unroll factor. True = fully unroll — used by the AOT
        # dry-run so cost_analysis counts every layer (XLA tallies a while
        # body once), at the cost of larger HLO.
        scan_unroll: Any = 1
        # Reversible two-stream residual stack (repro.memopt.reversible):
        # the backward pass reconstructs activations from the layers'
        # invertible structure instead of saving them — O(1) activation
        # memory in depth, superseding remat_policy inside this stack.
        # Requires an invertible inner layer (attn_branch/ffn_branch, zero
        # residual dropout); training-side only (decode paths raise).
        reversible: bool = False

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        layer = cfg.layer.clone()
        if "dtype_policy" in layer.keys():
            maybe_set(layer, dtype_policy=self.config.dtype_policy)
        self._add_child("layer", layer)
        if cfg.reversible:
            from repro.memopt.reversible import validate_reversible

            validate_reversible(self.layer)  # fail at build, not in-step

    # --- stacked params ------------------------------------------------------

    def create_parameter_specs_recursively(self):
        inner = self.layer.create_parameter_specs_recursively()
        L = self.config.num_layers
        return {"layer": jax.tree.map(
            lambda s: _stack_spec(s, L), inner,
            is_leaf=lambda s: isinstance(s, ParameterSpec))}

    def initialize_parameters_recursively(self, prng_key):
        L = self.config.num_layers
        keys = jax.random.split(prng_key, L)
        init = jax.vmap(self.layer.initialize_parameters_recursively)
        return {"layer": init(keys)}

    # --- scan plumbing ---------------------------------------------------------

    def _scan(self, fn_name: str, carry_x, *, per_layer_state=None,
              positions=None, length=None):
        """Runs ``layer.<fn_name>`` over stacked params via lax.scan.

        carry: activations; xs: (params_i[, state_i][, key_i]);
        ys: (side outputs[, new_state_i]).
        """
        cfg = self.config
        ctx = self._ctx
        params = self.state["layer"]
        L = cfg.num_layers
        keys = None
        if ctx.prng_key is not None:
            keys = jax.random.split(ctx.prng_key, L)
        is_training = ctx.is_training

        def body(x, xs):
            params_i = xs["params"]
            key_i = xs.get("key")
            if fn_name == "forward":
                inputs = {"x": x}
            elif fn_name == "prefill":
                inputs = {"state": xs["state"], "x": x}
            else:  # extend_step
                inputs = {"state": xs["state"], "x_step": x}
            if positions is not None and fn_name in ("forward", "prefill"):
                inputs["positions"] = positions
            if length is not None and fn_name == "prefill":
                inputs["length"] = length
            out, collection = functional(
                self.layer,
                state=params_i,
                inputs=inputs,
                prng_key=key_i,
                is_training=is_training,
                method=fn_name,
            )
            side = {
                "summaries": collection.summaries,
                "module_outputs": collection.module_outputs,
                "state_updates": collection.state_updates,
            }
            if fn_name == "forward":
                return out, side
            new_state, y = out
            return y, {"side": side, "state": new_state}

        if cfg.remat_policy is not None and is_training and fn_name == "forward":
            from repro.trainer.remat import policy_from_spec

            policy = policy_from_spec(cfg.remat_policy)
            body = jax.checkpoint(body, policy=policy, prevent_cse=False)

        xs: Dict[str, Any] = {"params": params}
        if keys is not None:
            xs["key"] = keys
        if per_layer_state is not None:
            xs["state"] = per_layer_state
        return jax.lax.scan(body, carry_x, xs, unroll=cfg.scan_unroll)

    # --- public interface -------------------------------------------------------

    def forward(self, x, positions=None):
        if self.config.reversible:
            from repro.memopt.reversible import reversible_forward

            # Side outputs from inner layers are dropped here (documented
            # in repro.memopt.reversible): the custom_vjp boundary cannot
            # re-emit per-layer collections.
            return reversible_forward(self, x, positions=positions)
        y, side = self._scan("forward", x, positions=positions)
        self._reemit(side)
        return y

    def _check_not_reversible(self, method: str):
        if self.config.reversible:
            raise NotImplementedError(
                f"Repeat.{method} is not available on a reversible stack: "
                "reversible=True is a training/scoring-memory knob "
                "(forward-only); the incremental decode interface has no "
                "two-stream layout. Export/serve such models through "
                "forward(), or train with reversible=False when the "
                "checkpoint must serve through prefill/extend_step.")

    @no_context
    def state_partition_specs(self, *_):
        inner = self.layer.state_partition_specs()

        def rec(node):
            if isinstance(node, dict):
                return {k: rec(v) for k, v in node.items()}
            if node is None:
                return None
            return (None,) + tuple(node)  # stacked layer axis

        return rec(inner)

    def init_states(self, batch_size: int, max_len: int):
        self._check_not_reversible("init_states")
        proto, _ = functional(
            self.layer, state={}, inputs=(batch_size, max_len),
            is_training=False, method="init_states")
        L = self.config.num_layers
        return jax.tree.map(lambda a: jnp.stack([a] * L, axis=0)
                            if hasattr(a, "shape") else a, proto)

    def prefill(self, state, x, positions=None, length=None):
        self._check_not_reversible("prefill")
        y, ys = self._scan("prefill", x, per_layer_state=state,
                           positions=positions, length=length)
        self._reemit(ys["side"])
        return ys["state"], y

    def extend_step(self, state, x_step):
        self._check_not_reversible("extend_step")
        y, ys = self._scan("extend_step", x_step, per_layer_state=state)
        self._reemit(ys["side"])
        return ys["state"], y

    def _reemit(self, side: Dict[str, Dict[str, Any]]):
        """Re-emit per-layer (stacked) side outputs into the parent collection."""
        for key, value in side["summaries"].items():
            self._ctx.add_summary(f"stack/{key}", value)
        for key, value in side["module_outputs"].items():
            self._ctx.add_module_output(f"stack/{key}", value)
        # State updates (e.g. fp8 amax histories) re-emit under "layer/":
        # the scan stacks each update (L, ...), which is exactly the layout
        # of the stacked params under this Repeat's "layer" subtree, so the
        # trainer's fold-back addresses them without knowing about scan.
        for key, value in side.get("state_updates", {}).items():
            self._ctx.add_state_update(f"layer/{key}", value)


class StackedTransformer(BaseLayer):
    """Python-loop stack (unscanned) — used for small models and as the
    readability baseline; shares the Repeat interface."""

    @config_class
    class Config(BaseLayer.Config):
        layers: Required[List[ConfigBase]] = REQUIRED

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._names = []
        for i, c in enumerate(cfg.layers):
            n = f"layer{i}"
            c = c.clone()
            if "dtype_policy" in c.keys():
                maybe_set(c, dtype_policy=cfg.dtype_policy)
            self._add_child(n, c)
            self._names.append(n)

    def forward(self, x, positions=None):
        for n in self._names:
            x = getattr(self, n)(x, positions=positions)
        return x

    @no_context
    def state_partition_specs(self, *_):
        return {n: getattr(self, n).state_partition_specs() for n in self._names}

    def init_states(self, batch_size, max_len):
        return {n: getattr(self, n).init_states(batch_size, max_len) for n in self._names}

    def prefill(self, state, x, positions=None, length=None):
        out = {}
        for n in self._names:
            out[n], x = getattr(self, n).prefill(
                state[n], x, positions=positions, length=length)
        return out, x

    def extend_step(self, state, x_step):
        out = {}
        for n in self._names:
            out[n], x_step = getattr(self, n).extend_step(state[n], x_step)
        return out, x_step


class Decoder(BaseLayer):
    """Embedding -> stack -> final norm -> LM head (tied by default)."""

    @config_class
    class Config(BaseLayer.Config):
        vocab_size: Required[int] = REQUIRED
        dim: Required[int] = REQUIRED
        emb: ConfigBase = Embedding.Config()
        stack: Required[ConfigBase] = REQUIRED
        final_norm: ConfigBase = RMSNorm.Config()
        # None -> weight tying via emb.attend().
        lm_head: Optional[ConfigBase] = None
        logits_softcap: Optional[float] = None
        emb_dropout: float = 0.0
        # Compute dtype for the stack (bf16 = production mixed precision).
        activation_dtype: Any = jnp.float32
        logits_partition: PartitionSpecLike = (("pod", "data"), None, "model")

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        cfg = self.config
        self._add_child("emb", maybe_set(cfg.emb.clone(
            num_embeddings=cfg.vocab_size, dim=cfg.dim),
            dtype_policy=cfg.dtype_policy))
        self._add_child("stack", maybe_set(cfg.stack.clone(),
                                           dtype_policy=cfg.dtype_policy))
        fn = cfg.final_norm.clone()
        if "input_dim" in fn.keys() and not fn.input_dim:
            fn.set(input_dim=cfg.dim)
        maybe_set(fn, dtype_policy=cfg.dtype_policy)
        self._add_child("final_norm", fn)
        if cfg.lm_head is not None:
            self._add_child("lm_head", maybe_set(cfg.lm_head.clone(
                input_dim=cfg.dim, output_dim=cfg.vocab_size, bias=False),
                dtype_policy=cfg.dtype_policy))
        if cfg.emb_dropout:
            self._add_child("dropout", Dropout.default_config().set(rate=cfg.emb_dropout))

    def _embed(self, input_ids, input_embeddings):
        if input_embeddings is None:
            x = self.emb(input_ids)
        elif input_ids is None:
            x = input_embeddings
        else:
            # Multimodal prefix layout: media embeddings occupy positions
            # [0, P); text tokens fill the rest (phi-3-vision stub frontend).
            P = input_embeddings.shape[1]
            text = self.emb(input_ids)
            x = jnp.concatenate([input_embeddings.astype(text.dtype), text[:, P:]], axis=1)
        if self.config.emb_dropout:
            x = self.dropout(x)
        # The dtype policy (when set) wins over the legacy activation_dtype
        # field: the stack runs entirely in the policy compute dtype.
        if self.compute_dtype is not None:
            return x.astype(self.compute_dtype)
        return x.astype(self.config.activation_dtype)

    def _head(self, h):
        cfg = self.config
        h = self.final_norm(h)
        if cfg.lm_head is not None:
            logits = self.lm_head(h)
        else:
            logits = self.emb.attend(h)
        if cfg.logits_softcap:
            logits = cfg.logits_softcap * jnp.tanh(logits / cfg.logits_softcap)
        return self._shard(self._to_output(logits), cfg.logits_partition)

    def forward(self, input_ids=None, *, input_embeddings=None, positions=None):
        return self.head(self.hidden(
            input_ids, input_embeddings=input_embeddings, positions=positions))

    def hidden(self, input_ids=None, *, input_embeddings=None, positions=None):
        """Final-layer hidden states (pre-norm/head) — lets the model compute
        chunked losses without materializing full logits."""
        x = self._embed(input_ids, input_embeddings)
        if positions is None:
            positions = jnp.arange(x.shape[1])
        return self.stack(x, positions=positions)

    def head(self, h):
        return self._head(h)

    @no_context
    def state_partition_specs(self, *_):
        return self.stack.state_partition_specs()

    def init_states(self, batch_size: int, max_len: int):
        return self.stack.init_states(batch_size, max_len)

    def prefill(self, state, input_ids=None, *, input_embeddings=None,
                positions=None, length=None):
        x = self._embed(input_ids, input_embeddings)
        if positions is None:
            positions = jnp.arange(x.shape[1])
        state, h = self.stack.prefill(state, x, positions=positions, length=length)
        return state, self._head(h)

    def extend_step(self, state, ids_step):
        x = self.emb(ids_step)
        state, h = self.stack.extend_step(state, x)
        return state, self._head(h)
