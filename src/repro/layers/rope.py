"""Rotary position embeddings as a *swappable child module*.

The paper's flagship modularity example: RoPE variants integrate into any
model via config replacement, never by editing attention code. The attention
layer only knows the interface ``apply(x, positions) -> x`` — theta, scaling
strategy, partial-rotary etc. are encapsulated here.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import REQUIRED, Required, config_class
from repro.layers.base import BaseLayer

__all__ = ["BaseRotaryEmbedding", "RotaryEmbedding", "LinearScaledRotaryEmbedding"]


class BaseRotaryEmbedding(BaseLayer):
    """Interface: apply(x, positions) with x (B, S, H, D), positions (S,)."""

    @config_class
    class Config(BaseLayer.Config):
        dim: Required[int] = REQUIRED  # rotary dim (== head_dim typically)

    def apply(self, x: jax.Array, positions: jax.Array) -> jax.Array:
        raise NotImplementedError


def _rope_sin_cos(positions: jax.Array, dim: int, theta: float) -> tuple:
    # freqs: theta^(-2i/dim), i in [0, dim/2). positions: (S,) or (B, S).
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions.astype(jnp.float32)[..., None] * inv_freq  # (..., S, dim/2)
    return jnp.sin(angles), jnp.cos(angles)


def _apply_half_rotation(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """GPT-NeoX / Llama convention: rotate (x[:d/2], x[d/2:])."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if sin.ndim == 2:  # (S, d/2) shared across batch
        sin = sin[None, :, None, :]
        cos = cos[None, :, None, :]
    else:  # (B, S, d/2) per-row positions (continuous batching decode)
        sin = sin[:, :, None, :]
        cos = cos[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


class RotaryEmbedding(BaseRotaryEmbedding):
    """Standard RoPE (Su et al.)."""

    @config_class
    class Config(BaseRotaryEmbedding.Config):
        theta: float = 10000.0
        # Fraction of head_dim that is rotated (1.0 = full rotary).
        rotary_pct: float = 1.0

    def apply(self, x: jax.Array, positions: jax.Array) -> jax.Array:
        cfg = self.config
        rot_dim = int(cfg.dim * cfg.rotary_pct)
        rot_dim -= rot_dim % 2
        sin, cos = _rope_sin_cos(positions, rot_dim, cfg.theta)
        if rot_dim == x.shape[-1]:
            return _apply_half_rotation(x, sin, cos)
        x_rot, x_pass = x[..., :rot_dim], x[..., rot_dim:]
        return jnp.concatenate([_apply_half_rotation(x_rot, sin, cos), x_pass], axis=-1)


class LinearScaledRotaryEmbedding(RotaryEmbedding):
    """Position-interpolation RoPE variant — exists to demonstrate the O(1)
    integration claim (swap via replace_config; attention code untouched)."""

    @config_class
    class Config(RotaryEmbedding.Config):
        scaling_factor: float = 1.0

    def apply(self, x: jax.Array, positions: jax.Array) -> jax.Array:
        scaled = positions.astype(jnp.float32) / self.config.scaling_factor
        # Re-entrant same-module call: runs in the current context frame.
        return super().apply(x, scaled)
