"""Distributed launch layer: multi-process init, collectives, worker entry.

The bridge from "fault-tolerant process" to "fault-tolerant cluster"
(paper §5): N OS processes train the same job, synchronize gradients every
step, commit checkpoints through the checkpointer's cross-process barrier,
and can be killed/restarted — at a *different* world size — by the
:class:`~repro.runtime.supervisor.FleetSupervisor`.

Two coordination backends:

* ``"jax"`` — real clusters: :func:`initialize` calls
  ``jax.distributed.initialize(coordinator_address, num_processes,
  process_id)`` and collectives ride the jax runtime
  (``multihost_utils.process_allgather``). Reduction order across hosts is
  then backend-defined, so bitwise world-size invariance is NOT guaranteed;
  use a tolerance when comparing loss curves.
* ``"file"`` — the local test substrate: subprocess workers on one host
  rendezvous through a shared *coordination directory*
  (:class:`FileCollective`). Payload files are written atomically
  (tmp+rename, the same discipline as checkpoint shards), every collective
  is numbered, and a peer that dies surfaces as a
  :class:`DistributedTimeout` instead of a silent hang — the worker then
  exits non-zero and the fleet supervisor restarts the job.

The elastic numerics contract (why a P-process run can resume at P'≠P with
an *identical* loss curve): the global batch is decomposed into a FIXED
number of canonical microbatches ``grad_microbatches`` (independent of
world size; every admissible world size must divide it). Each process
computes per-microbatch gradients for its contiguous block with one shared
jitted program, all contributions are allgathered, and every process sums
them in canonical microbatch order 0..G-1 on the host. Same programs, same
data, same addition order ⇒ bitwise-identical updates at every world size.

Worker mode (what the fleet supervisor spawns)::

    python -m repro.launch.distributed \
        --builder repro.launch.distributed:build_tiny_fleet_config \
        --builder-kwargs '{"steps": 12}' \
        --coordinator-dir /tmp/coord --process-index 0 --process-count 2 \
        --grad-microbatches 2 --checkpoint-dir /tmp/ckpt --result r0.jsonl

Fault-injection flags (used by the supervisor's drills):
``--sigkill-at-step S`` raises SIGKILL against itself in the step hook of
step S (exact step boundary; if S just launched an async save, the write is
in flight — the mid-save kill); ``--sigterm-at-step S`` sets the preemption
event at step S (the SIGTERM drill, deterministic at a boundary);
``--kill-during-save-step S`` dies INSIDE ``_write_step`` of the save for
step S after leaving a torn tmp shard behind — the torn-commit scenario.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import sys
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.config import ConfigBase, config_class

__all__ = [
    "DistributedConfig",
    "DistributedTimeout",
    "FileCollective",
    "initialize",
    "worker_argv",
    "build_tiny_fleet_config",
]


class DistributedTimeout(RuntimeError):
    """A collective timed out waiting for peer processes (dead rank?)."""


@config_class
class DistributedConfig(ConfigBase):
    """Elastic multi-process runtime configuration (trainer sub-config).

    ``grad_microbatches`` is the canonical gradient decomposition G: the
    global batch is always split into G fixed microbatches regardless of
    world size (0 ⇒ G = process_count, which is NOT world-size invariant —
    set G explicitly to the LCM of every world size the job may run at if
    you need exact loss-curve continuity across resharding).
    """

    coordinator_dir: str = ""
    process_index: int = 0
    process_count: int = 1
    grad_microbatches: int = 0
    collective_timeout_s: float = 60.0
    backend: str = "file"  # "file" | "jax"
    coordinator_address: str = ""  # host:port, jax backend only


class FileCollective:
    """Filesystem rendezvous for same-host multi-process training.

    Every collective is a numbered *op*; all processes must issue the same
    ops in the same order (SPMD discipline). Rank ``p`` publishes its
    payload as ``op<k>_r<p>.npz`` via atomic tmp+rename (existence implies
    completeness), then waits for all ``process_count`` files. A rank
    starting op ``k`` has proven every rank finished reading op ``k-2``, so
    it deletes its own ``k-2`` file — the directory stays O(2N) files.
    """

    def __init__(self, directory: str, *, process_index: int,
                 process_count: int, timeout_s: float = 60.0):
        self.directory = directory
        self.process_index = process_index
        self.process_count = process_count
        self.timeout_s = timeout_s
        self._op = 0
        os.makedirs(directory, exist_ok=True)

    def _path(self, op: int, rank: int) -> str:
        return os.path.join(self.directory, f"op{op:08d}_r{rank}.npz")

    def allgather(self, payload: Dict[str, np.ndarray]
                  ) -> List[Dict[str, np.ndarray]]:
        """Gathers one flat ``{key: array}`` dict per rank, returned in rank
        order. Keys may differ across ranks (each contributes its own
        microbatches); values round-trip bitwise through ``.npz``."""
        op, self._op = self._op, self._op + 1
        stale = self._path(op - 2, self.process_index)
        if op >= 2 and os.path.exists(stale):
            os.remove(stale)
        mine = self._path(op, self.process_index)
        np.savez(mine + ".tmp.npz",
                 **{k: np.asarray(v) for k, v in payload.items()})
        os.replace(mine + ".tmp.npz", mine)
        deadline = time.monotonic() + self.timeout_s
        wanted = [self._path(op, r) for r in range(self.process_count)]
        while not all(os.path.exists(p) for p in wanted):
            if time.monotonic() > deadline:
                missing = [r for r, p in enumerate(wanted)
                           if not os.path.exists(p)]
                raise DistributedTimeout(
                    f"collective op {op} timed out after {self.timeout_s}s "
                    f"waiting for rank(s) {missing} (dead peer?)")
            time.sleep(0.002)
        out = []
        for p in wanted:
            with np.load(p) as z:
                out.append({k: z[k] for k in z.files})
        return out

    def barrier(self):
        self.allgather({})


class _JaxCollective:
    """Collectives over an initialized ``jax.distributed`` runtime (real
    clusters). Gather order is by process index; cross-host numerics are
    backend-defined (see module docstring)."""

    def __init__(self, process_index: int, process_count: int):
        self.process_index = process_index
        self.process_count = process_count

    def allgather(self, payload):
        from jax.experimental import multihost_utils

        # Each rank's keys differ; exchange via a jsonable key manifest +
        # stacked arrays would be heavy — gather the whole dict pickled.
        import pickle

        blob = np.frombuffer(pickle.dumps(payload), np.uint8)
        padded = np.zeros(int(np.max(multihost_utils.process_allgather(
            np.asarray([blob.size])))), np.uint8)
        padded[:blob.size] = blob
        sizes = multihost_utils.process_allgather(np.asarray([blob.size]))
        blobs = multihost_utils.process_allgather(padded)
        return [pickle.loads(blobs[r][:int(sizes[r][0])].tobytes())
                for r in range(self.process_count)]

    def barrier(self):
        from jax.experimental import multihost_utils

        multihost_utils.sync_global_devices("repro-barrier")


def initialize(cfg) -> Optional[object]:
    """Returns the collective for ``cfg`` (a :class:`DistributedConfig`).

    ``backend="jax"`` initializes the jax distributed runtime (idempotent
    across calls within a process); ``backend="file"`` needs only the
    coordination directory. World size 1 returns None — the elastic step
    path then skips the exchange entirely (lossless: npz round-trips are
    bitwise, so skipping I/O changes nothing).
    """
    if cfg.process_count <= 1:
        return None
    if cfg.backend == "jax":
        import jax

        if not getattr(jax.distributed, "is_initialized", lambda: False)():
            jax.distributed.initialize(
                coordinator_address=cfg.coordinator_address or None,
                num_processes=cfg.process_count,
                process_id=cfg.process_index)
        return _JaxCollective(cfg.process_index, cfg.process_count)
    if cfg.backend == "file":
        if not cfg.coordinator_dir:
            raise ValueError("file backend needs coordinator_dir")
        return FileCollective(cfg.coordinator_dir,
                              process_index=cfg.process_index,
                              process_count=cfg.process_count,
                              timeout_s=cfg.collective_timeout_s)
    raise ValueError(f"Unknown distributed backend {cfg.backend!r}")


# ---------------------------------------------------------------------------
# Worker entry (what the fleet supervisor / local launcher spawns)
# ---------------------------------------------------------------------------


def _resolve_builder(spec: str):
    """'module.path:function' -> the callable."""
    mod_name, _, fn_name = spec.partition(":")
    if not fn_name:
        raise ValueError(f"builder must be 'module:function', got {spec!r}")
    return getattr(importlib.import_module(mod_name), fn_name)


def build_tiny_fleet_config(*, steps: int = 12, checkpoint_every_n: int = 4,
                            vocab: int = 32, dim: int = 32, batch: int = 8,
                            seq: int = 16, seed: int = 1, lr: float = 1e-2,
                            streaming: bool = False):
    """The default worker config: the same tiny CausalLM the runtime tests
    train, with a resumable input. Fleet-agnostic — the worker applies
    :class:`~repro.trainer.mesh_rules.ElasticModifier` on top."""
    from repro.core.config import config_for_function
    from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
    from repro.trainer import optimizers as opt_lib
    from repro.trainer.trainer import SpmdTrainer

    layer = TransformerLayer.default_config().set(input_dim=dim)
    layer.self_attention.set(num_heads=4, num_kv_heads=2)
    layer.feed_forward.set(hidden_dim=2 * dim)
    model = CausalLM.default_config().set(
        decoder=Decoder.default_config().set(
            vocab_size=vocab, dim=dim,
            stack=Repeat.default_config().set(layer=layer, num_layers=2,
                                              remat_policy=None)))
    cfg = SpmdTrainer.default_config().set(
        name="fleet_worker", model=model, max_steps=steps, log_every_n=1,
        seed=seed, checkpoint_every_n=checkpoint_every_n)
    if streaming:
        from repro.data.streaming import StreamingTextInput

        cfg.input = StreamingTextInput.default_config().set(
            vocab_size=vocab, seq_len=seq, global_batch_size=batch,
            prefetch=0)
    else:
        cfg.input.set(task="lm", vocab_size=vocab, seq_len=seq,
                      global_batch_size=batch)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=lr)
    return cfg


def worker_argv(python: str, *, builder: str, builder_kwargs: dict,
                coordinator_dir: str, process_index: int, process_count: int,
                grad_microbatches: int, checkpoint_dir: str, result: str,
                steps: Optional[int] = None,
                collective_timeout_s: float = 60.0,
                trace: str = "",
                sigkill_at_step: Optional[int] = None,
                sigterm_at_step: Optional[int] = None,
                kill_during_save_step: Optional[int] = None) -> List[str]:
    """The exact argv the fleet supervisor spawns for one rank."""
    argv = [python, "-m", "repro.launch.distributed",
            "--builder", builder,
            "--builder-kwargs", json.dumps(builder_kwargs),
            "--coordinator-dir", coordinator_dir,
            "--process-index", str(process_index),
            "--process-count", str(process_count),
            "--grad-microbatches", str(grad_microbatches),
            "--checkpoint-dir", checkpoint_dir,
            "--result", result,
            "--collective-timeout", str(collective_timeout_s)]
    if steps is not None:
        argv += ["--steps", str(steps)]
    if trace:
        argv += ["--trace", trace]
    if sigkill_at_step is not None:
        argv += ["--sigkill-at-step", str(sigkill_at_step)]
    if sigterm_at_step is not None:
        argv += ["--sigterm-at-step", str(sigterm_at_step)]
    if kill_during_save_step is not None:
        argv += ["--kill-during-save-step", str(kill_during_save_step)]
    return argv


def _install_torn_save_kill(trainer, step: int):
    """Arms the torn-commit drill: the save for ``step`` writes a garbage
    tmp shard (a torn write, as a real SIGKILL mid-``np.savez`` would leave)
    and then SIGKILLs the process before the atomic rename."""
    import signal

    ckpt = trainer.checkpointer
    orig = ckpt._write_step

    def torn(save_step, staged, all_keys, aux, commit_timeout_s=None):
        if save_step == step:
            cfg = ckpt.config
            step_dir = os.path.join(cfg.directory, f"step_{save_step:08d}")
            os.makedirs(step_dir, exist_ok=True)
            tmp = os.path.join(
                step_dir, f"shard_{cfg.process_index}.npz.tmp.npz")
            with open(tmp, "wb") as f:
                f.write(b"torn-mid-write")
            os.kill(os.getpid(), signal.SIGKILL)
        return orig(save_step, staged, all_keys, aux,
                    commit_timeout_s=commit_timeout_s)

    ckpt._write_step = torn


def run_worker(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(prog="repro.launch.distributed")
    ap.add_argument("--builder",
                    default="repro.launch.distributed:build_tiny_fleet_config")
    ap.add_argument("--builder-kwargs", default="{}")
    ap.add_argument("--coordinator-dir", required=True)
    ap.add_argument("--process-index", type=int, required=True)
    ap.add_argument("--process-count", type=int, required=True)
    ap.add_argument("--grad-microbatches", type=int, default=0)
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--result", default="")
    ap.add_argument("--collective-timeout", type=float, default=60.0)
    ap.add_argument("--trace", default="",
                    help="Chrome trace-event JSON path for this rank "
                         "(pid lane = process index; the supervisor merges "
                         "the per-rank files into one fleet trace)")
    ap.add_argument("--backend", default="file")
    ap.add_argument("--coordinator-address", default="")
    ap.add_argument("--sigkill-at-step", type=int, default=None)
    ap.add_argument("--sigterm-at-step", type=int, default=None)
    ap.add_argument("--kill-during-save-step", type=int, default=None)
    args = ap.parse_args(argv)

    import signal

    from repro.checkpoint.checkpointer import Checkpointer
    from repro.runtime.goodput import GoodputMonitor
    from repro.runtime.signals import Preempted, install_preemption_handler
    from repro.trainer.mesh_rules import ElasticModifier

    cfg = _resolve_builder(args.builder)(**json.loads(args.builder_kwargs))
    if args.checkpoint_dir:
        if cfg.checkpointer is None:
            cfg.checkpointer = Checkpointer.default_config()
        cfg.checkpointer.set(directory=args.checkpoint_dir)
    cfg = ElasticModifier.default_config().set(
        coordinator_dir=args.coordinator_dir,
        process_index=args.process_index,
        process_count=args.process_count,
        grad_microbatches=args.grad_microbatches,
        collective_timeout_s=args.collective_timeout,
        backend=args.backend,
        coordinator_address=args.coordinator_address,
    ).instantiate().apply(cfg)

    if args.trace:
        from repro.observability.runtime import ObservabilityConfig

        # Per-rank span trace on the rank's own pid lane; wall-clock
        # timebase, so the supervisor's merge lands all ranks on one axis.
        cfg.observability = ObservabilityConfig(
            trace_path=args.trace, rank=args.process_index, mfu=False)

    trainer = cfg.instantiate()
    install_preemption_handler(trainer.preemption_event)
    if args.kill_during_save_step is not None:
        _install_torn_save_kill(trainer, args.kill_during_save_step)

    out = open(args.result, "w") if args.result else None

    def emit(record: dict):
        if out is not None:
            out.write(json.dumps(record) + "\n")
            out.flush()

    monitor = GoodputMonitor(
        sink=lambda e: emit({"kind": "event", **{
            k: v for k, v in e.items() if isinstance(
                v, (int, float, str, bool, type(None)))}}))

    def hook(*, step, state, metrics, trainer=trainer, **_):
        emit({"kind": "step", "step": step,
              "loss": float(metrics["loss"])})
        if args.sigterm_at_step is not None and step == args.sigterm_at_step:
            trainer.preemption_event.set()
        if args.sigkill_at_step is not None and step == args.sigkill_at_step:
            os.kill(os.getpid(), signal.SIGKILL)

    try:
        result = trainer.run(args.steps, monitor=monitor, step_hook=hook)
    except Preempted as e:
        emit({"kind": "preempted", "step": e.step,
              "committed": e.committed})
        if out is not None:
            out.close()
        return 143
    except BaseException as e:  # noqa: BLE001 — exit code is the contract
        emit({"kind": "error", "error": repr(e)})
        if out is not None:
            out.close()
        raise
    emit({"kind": "final",
          "input_state": result.get("input_state"),
          "goodput": result["goodput"],
          "num_params": result["num_params"]})
    if out is not None:
        out.close()
    return 0


if __name__ == "__main__":
    sys.exit(run_worker())
