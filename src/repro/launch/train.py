"""End-to-end training launcher: ``--arch`` selects any assigned architecture.

Usage (CPU-scale by default — reduced model unless --full):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b \
      --steps 200 --batch 8 --seq 64 --mesh 1x1

Mesh rules pick per-instance-type settings exactly as the paper's App. A.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax

from repro.configs import registry
from repro.core.config import config_for_function
from repro.trainer import optimizers as opt_lib
from repro.layers.base import bf16_policy
from repro.memopt.modifier import MemoryModifier
from repro.quantization.modifier import QuantizationModifier
from repro.trainer.mesh_rules import (
    DtypePolicyModifier,
    GradAccumModifier,
    KernelModifier,
    MeshShapeModifier,
    RematPolicyModifier,
    Zero1Modifier,
    apply_mesh_rules,
)
from repro.trainer.trainer import SpmdTrainer
from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.signals import Preempted, install_preemption_handler

# Paper App. A-style mesh rules: instance type -> config modifiers. The TPU
# rule is the whole production mixed-precision training recipe — bf16
# compute with fp32 masters, ZeRO-1 optimizer sharding, differentiable
# Pallas flash attention as the training kernel plus a per-hardware tiling
# table — in ~10 lines of config, zero model-code changes (§4.2). Kernel
# choices rewrite the one KernelConfig every kernel-calling layer shares;
# anything left on "auto" resolves per-platform via the kernel registry's
# capability predicates. Rules are anchored fullmatch: list specific
# instance types before broad families.
MESH_RULES = [
    # Low-precision variants ride the same recipe plus ONE extra modifier:
    # "-fp8" suffix = fp8 train compute (delayed-scaling fake-quant at
    # module boundaries, fp32 masters kept by ZeRO-1 as usual); "-w8a8"
    # suffix = int8 weight/activation GEMMs. Listed before the broad
    # family rule (fullmatch, first match wins).
    ("tpu-v5e-.*-fp8", [
        MeshShapeModifier.default_config().set(
            mesh_shape=(16, 16), mesh_axis_names=("data", "model")),
        RematPolicyModifier.default_config().set(policy="full"),
        KernelModifier.default_config().set(
            op_overrides={"attention.fwd": "pallas"},
            update={"block_q": 256, "block_k": 512}),
        DtypePolicyModifier.default_config().set(policy=bf16_policy()),
        Zero1Modifier.default_config(),
        QuantizationModifier.default_config().set(fp8=True),
    ]),
    # Memory-frugal variants: same recipe plus ONE MemoryModifier (paper
    # §4.2 applied to training memory). "-frugal" = bf16 Adam EMA buffers +
    # reversible residual stacks (2x smaller moments, O(1)-in-depth
    # activations); "-frugal-max" = Adafactor factored second moments +
    # reversible (optimizer state drops from 8 bytes/param to O(n+m) per
    # matrix). Both compose with the rule's ZeRO-1 + bf16 policy.
    ("tpu-v5e-.*-frugal-max", [
        MeshShapeModifier.default_config().set(
            mesh_shape=(16, 16), mesh_axis_names=("data", "model")),
        RematPolicyModifier.default_config().set(policy="full"),
        KernelModifier.default_config().set(
            op_overrides={"attention.fwd": "pallas"},
            update={"block_q": 256, "block_k": 512}),
        DtypePolicyModifier.default_config().set(policy=bf16_policy()),
        Zero1Modifier.default_config(),
        MemoryModifier.default_config().set(
            optimizer="adafactor", reversible=True),
    ]),
    ("tpu-v5e-.*-frugal", [
        MeshShapeModifier.default_config().set(
            mesh_shape=(16, 16), mesh_axis_names=("data", "model")),
        RematPolicyModifier.default_config().set(policy="full"),
        KernelModifier.default_config().set(
            op_overrides={"attention.fwd": "pallas"},
            update={"block_q": 256, "block_k": 512}),
        DtypePolicyModifier.default_config().set(policy=bf16_policy()),
        Zero1Modifier.default_config(),
        MemoryModifier.default_config().set(
            state_dtype="bf16", reversible=True),
    ]),
    ("tpu-v5e-.*-w8a8", [
        MeshShapeModifier.default_config().set(
            mesh_shape=(16, 16), mesh_axis_names=("data", "model")),
        RematPolicyModifier.default_config().set(policy="full"),
        KernelModifier.default_config().set(
            op_overrides={"attention.fwd": "pallas"},
            update={"block_q": 256, "block_k": 512}),
        DtypePolicyModifier.default_config().set(policy=bf16_policy()),
        Zero1Modifier.default_config(),
        QuantizationModifier.default_config().set(w8a8=True),
    ]),
    ("tpu-v5e-.*", [
        MeshShapeModifier.default_config().set(
            mesh_shape=(16, 16), mesh_axis_names=("data", "model")),
        RematPolicyModifier.default_config().set(policy="full"),
        KernelModifier.default_config().set(
            op_overrides={"attention.fwd": "pallas"},
            update={"block_q": 256, "block_k": 512}),  # v5e tiling table
        DtypePolicyModifier.default_config().set(policy=bf16_policy()),
        Zero1Modifier.default_config(),
    ]),
    ("cpu-.*", [
        MeshShapeModifier.default_config().set(
            mesh_shape=(1,), mesh_axis_names=("data",)),
        RematPolicyModifier.default_config().set(policy=None),
        KernelModifier.default_config().set(
            op_overrides={"attention.fwd": "ref"}),
    ]),
]


def build_trainer_config(arch: str, *, full: bool, steps: int, batch: int,
                         seq: int, lr: float, instance_type: str,
                         checkpoint_dir: str = ""):
    spec = registry.get_spec(arch)
    model_cfg = spec.make_model() if full else spec.make_smoke()
    cfg = SpmdTrainer.default_config().set(
        name="trainer", model=model_cfg, max_steps=steps, log_every_n=10,
        seed=0)
    task = {"audio": "audio", "vlm": "vlm"}.get(spec.modality, "lm")
    vocab = model_cfg.decoder.vocab_size
    dim = model_cfg.decoder.dim
    cfg.input.set(task=task, vocab_size=vocab, seq_len=seq,
                  global_batch_size=batch, model_dim=dim, num_patches=4)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        learning_rate=config_for_function(opt_lib.linear_warmup_cosine).set(
            peak_lr=lr, warmup_steps=max(steps // 20, 1), total_steps=steps),
        weight_decay=0.01)
    if checkpoint_dir:
        cfg.checkpointer = Checkpointer.default_config().set(
            directory=checkpoint_dir)
        cfg.checkpoint_every_n = max(steps // 4, 1)
    cfg = apply_mesh_rules(cfg, instance_type=instance_type, rules=MESH_RULES)
    return cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ALL_ARCHS)
    ap.add_argument("--full", action="store_true",
                    help="full paper-size config (needs a real cluster)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--instance-type", default="cpu-local")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    cfg = build_trainer_config(
        args.arch, full=args.full, steps=args.steps, batch=args.batch,
        seq=args.seq, lr=args.lr, instance_type=args.instance_type,
        checkpoint_dir=args.checkpoint_dir)
    trainer = cfg.instantiate()
    # Preemption wiring (§5): a scheduler SIGTERM sets the event; the loop
    # commits a synchronous emergency checkpoint at the next step boundary
    # and raises Preempted — restarting the same command resumes exactly.
    install_preemption_handler(trainer.preemption_event)
    try:
        result = trainer.run()
    except Preempted as e:
        print(f"[train] preempted at step {e.step}; "
              + ("emergency checkpoint committed — rerun to resume"
                 if e.committed else "no checkpointer configured"))
        sys.exit(143)  # 128 + SIGTERM, like a default-handled TERM
    print(f"[train] arch={args.arch} params={result['num_params']:,}")
    for row in result["history"]:
        print(f"[train] step={row['step']:>5} loss={row['loss']:.4f} "
              f"acc={row.get('accuracy', 0):.3f} "
              f"steps/s={row['steps_per_s']:.2f}")
    g = result["goodput"]
    buckets = " ".join(f"{k}={v:.2f}s"
                       for k, v in sorted(g["buckets"].items()))
    # Raw goodput on short runs is dominated by one-time compile/init;
    # steady excludes those startup buckets — the sustainable number.
    print(f"[train] goodput={g['goodput_fraction']:.3f} "
          f"steady={g['steady_goodput_fraction']:.3f} "
          f"wall={g['wall_s']:.2f}s {buckets}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"history": result["history"],
                       "goodput": result["goodput"]}, f, indent=1)


if __name__ == "__main__":
    main()
