"""§Perf hillclimbing harness: named config variants over the dry-run.

Each variant is a config-mutating function (the paper's modifier mechanism);
the harness lowers the SAME (arch × shape) with the variant applied and
records the deltas vs baseline. All changes are configuration — zero layer
code edits — which is itself the reproduction's point.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb \
      --arch mixtral-8x7b --shape train_4k --variant moe_c_shard
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json

import jax.numpy as jnp

from repro.configs import registry
from repro.core.config import visit_config
from repro.launch import dryrun


# --------------------------------------------------------------------------
# Variant library (hypotheses live in EXPERIMENTS.md §Perf)
# --------------------------------------------------------------------------


def moe_c_shard(model_cfg):
    """Shard the MoE capacity dim over "model" when experts can't divide it
    (mixtral E=8 on a 16-way axis): dispatch/combine (G,S,E,C) and expert
    activations (E,G,C,D) go from E-replicated to C-sharded."""

    def visit(path, cfg):
        if "dispatch_partition" in cfg.keys() and "num_experts" in cfg.keys():
            if cfg.num_experts and cfg.num_experts % 16 != 0:
                cfg.set(dispatch_partition=(("pod", "data"), None, None, "model"),
                        expert_partition=(None, ("pod", "data"), "model", None))

    visit_config(model_cfg, visit)


def moe_capacity_1(model_cfg):
    """capacity_factor 2.0 -> 1.0: halves dispatch/expert activation volume
    (and the all-to-all) at the cost of more dropped tokens."""

    def visit(path, cfg):
        if "capacity_factor" in cfg.keys():
            cfg.set(capacity_factor=1.0)

    visit_config(model_cfg, visit)


def remat_save_ffn(model_cfg):
    """Remat policy: save attention/mixer and FFN outputs instead of
    recomputing everything — trades HBM for recompute FLOPs."""

    def visit(path, cfg):
        if "remat_policy" in cfg.keys():
            cfg.set(remat_policy="save:attn_out,ffn_out,mixer_out")

    visit_config(model_cfg, visit)


def block_remat_each_layer(model_cfg):
    """Nested remat: checkpoint every layer inside a heterogeneous Block so
    block backward recomputes one layer at a time (jamba's 8-layer block)."""

    def visit(path, cfg):
        if "remat_each_layer" in cfg.keys():
            cfg.set(remat_each_layer=True)

    visit_config(model_cfg, visit)


def seq_parallel_activations(model_cfg):
    """Shard inter-layer activations on the SEQUENCE dim over "model"
    (sequence parallelism) instead of the embedding dim."""

    def visit(path, cfg):
        if "activation_partition" in cfg.keys():
            cfg.set(activation_partition=(("pod", "data"), "model", None))

    visit_config(model_cfg, visit)


def kv_cache_f8(model_cfg):
    """KV cache in fp8 (e4m3): halves decode cache bytes vs bf16 — the
    quantized-cache serving lever (beyond-paper for this shape). The dtype
    name resolves inside repro.quantization (dtype literals live there)."""
    from repro.quantization.modifier import set_kv_cache_dtype

    set_kv_cache_dtype(model_cfg, "fp8_e4m3")


def attn_chunk_2k(model_cfg):
    """Bigger blockwise-attention q-chunks (512 -> 2048): fewer scan steps /
    larger matmuls, at higher live-logits memory."""

    def visit(path, cfg):
        if "blockwise_chunk_size" in cfg.keys():
            cfg.set(blockwise_chunk_size=2048)

    visit_config(model_cfg, visit)


def mamba_chunk_512(model_cfg):
    def visit(path, cfg):
        if "scan_chunk_size" in cfg.keys():
            cfg.set(scan_chunk_size=512)

    visit_config(model_cfg, visit)


def grad_accum_4(model_cfg):
    """Marker variant — grad accumulation is a trainer field; handled in
    run_variant below."""


def params_bf16(model_cfg):
    """bf16 parameters (+ the trainer already uses bf16 moments for giants):
    halves FSDP all-gather and grad all-reduce bytes."""
    from repro.launch.dryrun import set_param_dtype

    set_param_dtype(model_cfg, jnp.bfloat16)


def moe_grouping(model_cfg):
    """GShard token grouping (4096/group): dispatch tensors go from
    O(tokens*S) to O(tokens*4096) — the long-sequence MoE fix."""

    def visit(path, cfg):
        if "group_size" in cfg.keys():
            cfg.set(group_size=4096)

    visit_config(model_cfg, visit)


VARIANTS = {
    "params_bf16": params_bf16,
    "moe_grouping": moe_grouping,
    "moe_c_shard": moe_c_shard,
    "moe_capacity_1": moe_capacity_1,
    "remat_save_ffn": remat_save_ffn,
    "block_remat_each_layer": block_remat_each_layer,
    "seq_parallel": seq_parallel_activations,
    "kv_cache_f8": kv_cache_f8,
    "attn_chunk_2k": attn_chunk_2k,
    "mamba_chunk_512": mamba_chunk_512,
}


def run_variant(arch: str, shape: str, variant: str, out_dir: str,
                mesh_kind: str = "single"):
    fns = [VARIANTS[v] for v in variant.split("+")] if variant else []

    def hook(model_cfg):
        for fn in fns:
            fn(model_cfg)

    dryrun.EXTRA_CONFIG_HOOK = hook if fns else None
    dryrun.run_one.variant_name = variant
    try:
        rec = dryrun.run_one(arch, shape, mesh_kind, out_dir)
    finally:
        dryrun.EXTRA_CONFIG_HOOK = None
        dryrun.run_one.variant_name = ""
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", required=True,
                    help="name or 'a+b' composition from VARIANTS")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()
    rec = run_variant(args.arch, args.shape, args.variant, args.out, args.mesh)
    if rec["status"] == "ok":
        m, r = rec["memory"], rec.get("roofline", {})
        print(f"[hillclimb] {args.arch} {args.shape} {args.variant}: "
              f"peak={m['peak_per_device']/2**30:.2f}GiB fits={m['fits']} "
              + (f"compute={r['compute_s']*1e3:.1f}ms mem={r['memory_s']*1e3:.1f}ms "
                 f"coll={r['collective_s']*1e3:.1f}ms dom={r['dominant']}"
                 if r else ""))
    else:
        print(f"[hillclimb] {rec['status']}: {rec.get('error', '')[:300]}")


if __name__ == "__main__":
    main()
