"""Roofline extraction from compiled AOT artifacts (assignment §Roofline).

Sources:
  * ``compiled.cost_analysis()``    -> HLO flops / bytes accessed (PER DEVICE:
    XLA analyzes the partitioned module — verified empirically; do not divide
    by chip count again).
  * ``compiled.as_text()``          -> collective ops; we sum the *result*
    buffer sizes of every all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute as the per-device collective byte count.
  * ``compiled.memory_analysis()``  -> per-device HBM footprint.

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

__all__ = ["HW", "parse_collectives", "roofline_terms", "RooflineReport"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 / chip
    hbm_bw: float = 819e9  # B/s / chip
    ici_bw: float = 50e9  # B/s / link (we charge 1 link: conservative)
    hbm_bytes: float = 16 * 1024 ** 3


V5E = HW()

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g. "bf16[16,1024]{1,0}" or "f32[]"
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _TYPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-device bytes produced by each collective kind."""
    out: Dict[str, Dict[str, float]] = {}
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        b = _type_bytes(type_str)
        slot = out.setdefault(kind, {"count": 0, "bytes": 0.0})
        slot["count"] += 1
        slot["bytes"] += b
    # -start/-done pairs both match; drop the -done duplicates by halving any
    # kind whose ops all appear twice is fragile — instead we matched both
    # start and done above only when they carry the result type; "-done"
    # lines re-state the type, so filter explicitly:
    return out


def parse_collectives_dedup(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Like parse_collectives but skips '-done' continuation ops."""
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue
        type_str, kind = m.group(1), m.group(2)
        b = _type_bytes(type_str)
        slot = out.setdefault(kind, {"count": 0, "bytes": 0.0})
        slot["count"] += 1
        slot["bytes"] += b
    return out


@dataclasses.dataclass
class RooflineReport:
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    collectives: Dict[str, Dict[str, float]]
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_global: Optional[float] = None
    useful_flops_ratio: Optional[float] = None
    peak_hbm_bytes: Optional[float] = None

    def to_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(*, cost: Dict[str, Any], hlo_text: str, chips: int,
                   model_flops_global: Optional[float] = None,
                   peak_hbm_bytes: Optional[float] = None,
                   hw: HW = V5E) -> RooflineReport:
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    colls = parse_collectives_dedup(hlo_text)
    coll_bytes = sum(v["bytes"] for v in colls.values())

    compute_s = flops / hw.peak_flops
    memory_s = bytes_acc / hw.hbm_bw
    collective_s = coll_bytes / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)

    ratio = None
    if model_flops_global:
        total_hlo = flops * chips
        ratio = model_flops_global / total_hlo if total_hlo > 0 else None

    return RooflineReport(
        flops_per_device=flops,
        bytes_per_device=bytes_acc,
        collective_bytes_per_device=coll_bytes,
        collectives=colls,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_global=model_flops_global,
        useful_flops_ratio=ratio,
        peak_hbm_bytes=peak_hbm_bytes,
    )
