"""Production mesh definitions (assignment spec).

A FUNCTION, not a module-level constant — importing this module never touches
jax device state (device count is locked at first jax init, so the dry-run
must set XLA_FLAGS before any jax usage).

Axis roles (see DESIGN.md §5):
  pod   — data parallelism across DCN (multi-pod only)
  data  — FSDP / batch within a pod (16)
  model — tensor/expert parallel within a pod (16)
"""

from __future__ import annotations

import jax

from repro.core.utils import make_mesh

__all__ = ["make_production_mesh", "SINGLE_POD_SHAPE", "MULTI_POD_SHAPE"]

SINGLE_POD_SHAPE = (16, 16)  # 256 chips of TPU v5e
MULTI_POD_SHAPE = (2, 16, 16)  # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False):
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for tests (requires xla_force_host_platform_device_count)."""
    return make_mesh((n_data, n_model), ("data", "model"))
