import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) needs 512 placeholder CPU devices so the
# production meshes can be built. This is the paper's AOT-compilation workflow
# (§4.2): lower + compile the EXACT train/serve codepath on a single host,
# catching sharding errors and OOMs before touching accelerators.

"""Multi-pod AOT dry-run launcher.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
      --shape train_4k --mesh single --out experiments/dryrun

For every (architecture x input-shape x mesh):
  * builds the trainer's train_step (train shapes) or the engine's
    prefill/serve_step (prefill/decode shapes),
  * jit(...).lower(ShapeDtypeStructs).compile() against the production mesh,
  * prints memory_analysis() (fits-check) and cost_analysis() (FLOPs/bytes),
  * extracts collective bytes from the optimized HLO,
  * writes a JSON record consumed by EXPERIMENTS.md §Dry-run / §Roofline.
"""

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.configs.common import SHAPES, MODEL_AXIS
from repro.core.config import config_for_function, visit_config
from repro.core.module import functional
from repro.core.utils import named_sharding, set_mesh
from repro.launch.analysis import V5E, roofline_terms
from repro.launch.mesh import make_production_mesh
from repro.layers.base import ParameterSpec
from repro.trainer import optimizers as opt_lib
from repro.trainer.trainer import SpmdTrainer

# Models whose optimizer state must be host-offloaded on v5e (paper §4.2);
# the CPU backend cannot compile memory-kind annotations, so the dry-run
# reports both raw and offload-adjusted HBM (see DESIGN.md).
GIANT_ARCHS = {"jamba-1.5-large-398b", "arctic-480b"}

LOSS_CHUNK = 512  # token-chunked CE: never materialize (B,S,V) logits

# Optional config hook applied to the model config in every builder (after
# standard surgery) — the hillclimb harness installs candidate changes here.
EXTRA_CONFIG_HOOK = None


def _apply_hook(model_cfg):
    if EXTRA_CONFIG_HOOK is not None:
        EXTRA_CONFIG_HOOK(model_cfg)


# --------------------------------------------------------------------------
# Config surgery (all config, no code — the paper's modifier mechanism)
# --------------------------------------------------------------------------


def set_param_dtype(model_cfg, dtype):
    def visit(path, cfg):
        if "param_dtype" in cfg.keys():
            cfg.set(param_dtype=dtype)

    visit_config(model_cfg, visit)


def apply_production_mode(model_cfg):
    """bf16 activations (production mixed precision); scans stay rolled."""

    def visit(path, cfg):
        if "activation_dtype" in cfg.keys():
            cfg.set(activation_dtype=jnp.bfloat16)

    visit_config(model_cfg, visit)


def apply_analysis_mode(model_cfg, seq_len: int, depth: int):
    """Cost-analysis variant: XLA tallies a while body ONCE (verified), so we
    (a) shrink the stack to ``depth`` layers/blocks and FULLY unroll it, and
    (b) unroll all inner scans (attention chunks, loss chunks, wkv chunks).
    Lowering depth=1 and depth=2 lets run_one() extrapolate every cost
    (affine in depth: per-layer ops + depth-proportional optimizer update +
    constant embedding/head) to the true L — two tiny compiles instead of one
    giant unrolled one. Pure config; no layer code knows about analysis mode.

    Returns the original depth L."""

    found = []

    def visit(path, cfg):
        if "num_layers" in cfg.keys() and "scan_unroll" in cfg.keys():
            found.append(cfg.num_layers)
            cfg.set(num_layers=depth, scan_unroll=True)
        if "loss_chunk_unroll" in cfg.keys():
            cfg.set(loss_chunk_unroll=True)
        if "activation_dtype" in cfg.keys():
            cfg.set(activation_dtype=jnp.bfloat16)
        if "blockwise_unroll" in cfg.keys():
            cfg.set(blockwise_unroll=True,
                    blockwise_chunk_size=max(seq_len // 8, 512))
        if "wkv_unroll" in cfg.keys():
            cfg.set(wkv_unroll=True, wkv_chunk_size=128)
        if "scan_unroll_chunks" in cfg.keys():
            cfg.set(scan_unroll_chunks=True,
                    scan_chunk_size=max(seq_len // 16, 256))

    visit_config(model_cfg, visit)
    assert len(found) == 1, f"expected exactly one Repeat stack, got {found}"
    return found[0]


def extrapolate_affine(c1: float, c2: float, L: int) -> float:
    """cost(L) for costs affine in depth, from cost(1) and cost(2)."""
    per_layer = c2 - c1
    return max(c1 + (L - 1) * per_layer, 0.0)


_WEIGHT_FIELDS = ("weight_partition", "param_partition_spec")


def _drop_batch_axes(spec):
    if spec is None:
        return None

    def drop(entry):
        if entry in ("pod", "data"):
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a not in ("pod", "data"))
            return kept if len(kept) > 1 else (kept[0] if kept else None)
        return entry

    return tuple(drop(e) for e in spec)


def adapt_for_batch1_decode(model_cfg):
    """long_500k (global_batch=1): batch axes can't shard a size-1 dim.
    Drop pod/data from ACTIVATION partitions (weights keep 2D sharding) and
    move the freed "data" axis onto the KV-cache sequence dim — the
    flash-decoding-style layout (GSPMD inserts the partial-softmax reduce)."""

    def visit(path, cfg):
        for key in cfg.keys():
            if not key.endswith("_partition"):
                continue
            if any(key.endswith(w) for w in _WEIGHT_FIELDS):
                continue
            setattr(cfg, key, _drop_batch_axes(getattr(cfg, key)))
        if "kv_cache_partition" in cfg.keys() and "num_kv_heads" in cfg.keys():
            nh = cfg.num_kv_heads or cfg.num_heads
            hd = cfg.head_dim
            heads_ax = "model" if (nh and nh % MODEL_AXIS == 0) else None
            dim_ax = "model" if heads_ax is None and hd and hd % MODEL_AXIS == 0 else None
            cfg.set(kv_cache_partition=(None, "data", heads_ax, dim_ax))
        if "state_partition" in cfg.keys():
            cfg.set(state_partition=_drop_batch_axes(cfg.state_partition))

    visit_config(model_cfg, visit)


# --------------------------------------------------------------------------
# Step builders
# --------------------------------------------------------------------------


def build_train_lowering(spec, shape: str, mesh, depth: Optional[int] = None):
    info = SHAPES[shape]
    model_cfg = spec.make_model()
    if "loss_chunk_size" in model_cfg.keys():
        model_cfg.set(loss_chunk_size=LOSS_CHUNK)
    giant = spec.arch_id in GIANT_ARCHS
    if giant:
        set_param_dtype(model_cfg, jnp.bfloat16)
    if depth is None:
        apply_production_mode(model_cfg)
    else:
        apply_analysis_mode(model_cfg, info["seq_len"], depth)
    _apply_hook(model_cfg)

    cfg = SpmdTrainer.default_config().set(name="trainer", model=model_cfg)
    cfg.input.set(task={"audio": "audio", "vlm": "vlm"}.get(spec.modality, "lm"),
                  vocab_size=spec.vocab_size, seq_len=info["seq_len"],
                  global_batch_size=info["global_batch"],
                  model_dim=spec.model_dim)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=1e-4, weight_decay=0.0,
        moment_dtype=jnp.bfloat16 if giant else jnp.float32)
    trainer = cfg.instantiate()
    trainer._mesh = mesh
    trainer.learner.build(trainer.param_specs())

    state_shapes = jax.eval_shape(trainer.init_state)
    state_sh = trainer.state_shardings(state_shapes, mesh)
    batch_specs = spec.input_specs(shape)
    batch_sh = trainer.batch_shardings(batch_specs, mesh)
    step = trainer.make_train_step()
    lowered = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    ).lower(state_shapes, batch_specs)

    # Offload-adjusted accounting: bytes that live in host RAM on TPU.
    offload_bytes = 0
    if giant:
        opt_leaves = jax.tree.leaves(state_shapes["opt_state"])
        offload_bytes = sum(
            int(l.size) * l.dtype.itemsize for l in opt_leaves if hasattr(l, "size"))
    return lowered, {"offloadable_bytes_global": offload_bytes}


def _model_and_params(spec, *, seq_len, depth=None):
    model_cfg = spec.make_model()
    set_param_dtype(model_cfg, jnp.bfloat16)  # serving runs bf16 weights
    if depth is None:
        apply_production_mode(model_cfg)
    else:
        apply_analysis_mode(model_cfg, seq_len, depth)
    _apply_hook(model_cfg)
    model = model_cfg.instantiate()
    p_specs = model.create_parameter_specs_recursively()
    param_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(tuple(s.shape), s.dtype), p_specs,
        is_leaf=lambda s: isinstance(s, ParameterSpec))
    return model_cfg, model, p_specs, param_shapes



def _state_shardings(model, mesh):
    """NamedShardings for the decode-state pytree from the layers' own
    state_partition_specs (config-driven, like everything else)."""
    specs = model.state_partition_specs()

    def rec(node):
        if isinstance(node, dict):
            return {k: rec(v) for k, v in node.items()}
        return named_sharding(node, mesh)

    return rec(specs)


def build_prefill_lowering(spec, shape: str, mesh, depth: Optional[int] = None):
    info = SHAPES[shape]
    B, S = info["global_batch"], info["seq_len"]
    model_cfg, model, p_specs, param_shapes = _model_and_params(
        spec, seq_len=S, depth=depth)
    param_sh = jax.tree.map(
        lambda s: named_sharding(s.mesh_axes, mesh), p_specs,
        is_leaf=lambda s: isinstance(s, ParameterSpec))
    batch_specs = spec.input_specs(shape)
    batch_sh = jax.tree.map(
        lambda x: named_sharding(
            (("pod", "data"),) + (None,) * (len(x.shape) - 1), mesh),
        batch_specs)

    if spec.modality == "audio":
        # Encoder-only: "prefill" is the batched encoder forward.
        def step(params, batch):
            out, _ = functional(model, state=params, inputs=(batch,),
                                method="predict")
            return out

        return jax.jit(step, in_shardings=(param_sh, batch_sh)).lower(
            param_shapes, batch_specs), {}

    cache_shapes = jax.eval_shape(
        lambda: functional(model, state=param_shapes, inputs=(B, S),
                           method="init_states")[0])
    cache_sh = _state_shardings(model, mesh)

    def step(params, cache, batch):
        (cache, logits), _ = functional(
            model, state=params,
            inputs={"state": cache, **{("input_ids" if k == "input_ids" else k): v
                                       for k, v in batch.items()}},
            method="prefill")
        return cache, logits[:, -1]

    lowered = jax.jit(
        step, in_shardings=(param_sh, cache_sh, batch_sh),
        donate_argnums=(1,),
    ).lower(param_shapes, cache_shapes, batch_specs)
    return lowered, {}


def build_decode_lowering(spec, shape: str, mesh, depth: Optional[int] = None):
    info = SHAPES[shape]
    B, S = info["global_batch"], info["seq_len"]
    model_cfg = spec.make_model()
    set_param_dtype(model_cfg, jnp.bfloat16)
    if depth is None:
        apply_production_mode(model_cfg)
    else:
        apply_analysis_mode(model_cfg, S, depth)
    if B == 1:
        adapt_for_batch1_decode(model_cfg)
    _apply_hook(model_cfg)
    model = model_cfg.instantiate()
    p_specs = model.create_parameter_specs_recursively()
    param_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(tuple(s.shape), s.dtype), p_specs,
        is_leaf=lambda s: isinstance(s, ParameterSpec))
    param_sh = jax.tree.map(
        lambda s: named_sharding(s.mesh_axes, mesh), p_specs,
        is_leaf=lambda s: isinstance(s, ParameterSpec))

    cache_shapes = jax.eval_shape(
        lambda: functional(model, state=param_shapes, inputs=(B, S),
                           method="init_states")[0])
    cache_sh = _state_shardings(model, mesh)
    ids_spec = spec.input_specs(shape)["ids_step"]
    batch_axes = (("pod", "data"),) if B > 1 else (None,)
    ids_sh = named_sharding(batch_axes + (None,), mesh)

    def serve_step(params, cache, ids_step):
        (cache, logits), _ = functional(
            model, state=params, inputs={"state": cache, "ids_step": ids_step},
            method="extend_step")
        return cache, logits[:, -1]

    lowered = jax.jit(
        serve_step, in_shardings=(param_sh, cache_sh, ids_sh),
        donate_argnums=(1,),
    ).lower(param_shapes, cache_shapes, ids_spec)
    return lowered, {}


# --------------------------------------------------------------------------
# Runner
# --------------------------------------------------------------------------


def stack_depth(model_cfg) -> int:
    found = []

    def visit(path, cfg):
        if "num_layers" in cfg.keys() and "scan_unroll" in cfg.keys():
            found.append(cfg.num_layers)

    visit_config(model_cfg, visit)
    assert len(found) == 1, found
    return found[0]


def _build(spec, shape, mesh, depth=None):
    info = SHAPES[shape]
    if info["kind"] == "train":
        return build_train_lowering(spec, shape, mesh, depth)
    if info["kind"] == "prefill":
        return build_prefill_lowering(spec, shape, mesh, depth)
    return build_decode_lowering(spec, shape, mesh, depth)


def run_one(arch: str, shape: str, mesh_kind: str, out_dir: str) -> Dict[str, Any]:
    """Three passes:
      1. PRODUCTION: full depth, rolled scans -> lower+compile (the required
         proof) + memory_analysis (fits-check). Both meshes.
      2+3. ANALYSIS (single-pod only): depth-1 and depth-2 unrolled variants;
         every cost/collective quantity is affine in depth, so cost(L) =
         cost(1) + (L-1)*(cost(2)-cost(1)) — exact without a giant unrolled
         compile (XLA tallies while bodies once; verified empirically).
    """
    spec = registry.get_spec(arch)
    info = SHAPES[shape]
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.size
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_kind, "chips": chips,
        "status": "skip", "family": spec.family,
    }
    if not spec.supports(shape):
        record["skip_reason"] = spec.skip_shapes[shape]
        os.makedirs(out_dir, exist_ok=True)
        with open(os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}.json"), "w") as f:
            json.dump(record, f, indent=1)
        return record

    t0 = time.time()
    try:
        with set_mesh(mesh):
            # ---- pass 1: production compile + memory ----------------------
            lowered, extra = _build(spec, shape, mesh, depth=None)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            del lowered, compiled

        L = stack_depth(spec.make_model())
        total, active = registry.param_counts(spec.make_model())
        tokens = info["global_batch"] * (info["seq_len"] if info["kind"] != "decode" else 1)
        mult = 6 if info["kind"] == "train" else 2
        model_flops = mult * active * tokens

        peak_hbm = (mem.argument_size_in_bytes + mem.output_size_in_bytes -
                    mem.alias_size_in_bytes + mem.temp_size_in_bytes)
        record.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            num_layers=L,
            params_total=total,
            params_active=active,
            model_flops_global=model_flops,
            memory=dict(
                argument_bytes=mem.argument_size_in_bytes,
                output_bytes=mem.output_size_in_bytes,
                alias_bytes=mem.alias_size_in_bytes,
                temp_bytes=mem.temp_size_in_bytes,
                peak_per_device=peak_hbm,
                hbm_limit=int(V5E.hbm_bytes),
                fits=bool(peak_hbm <= V5E.hbm_bytes),
                **extra,
            ),
        )
        if extra.get("offloadable_bytes_global"):
            adj = peak_hbm - extra["offloadable_bytes_global"] / chips
            record["memory"]["peak_per_device_offload_adjusted"] = adj
            record["memory"]["fits_with_offload"] = bool(adj <= V5E.hbm_bytes)

        # ---- passes 2+3: cost analysis via depth extrapolation -------------
        if not multi and not os.environ.get("DRYRUN_SKIP_ANALYSIS"):
            from repro.launch.analysis import parse_collectives_dedup

            costs, colls = [], []
            for depth in (1, 2):
                with set_mesh(mesh):
                    lowered, _ = _build(spec, shape, mesh, depth=depth)
                    comp = lowered.compile()
                    costs.append(comp.cost_analysis())
                    colls.append(parse_collectives_dedup(comp.as_text()))
                    del lowered, comp

            flops = extrapolate_affine(
                float(costs[0].get("flops", 0)), float(costs[1].get("flops", 0)), L)
            bytes_acc = extrapolate_affine(
                float(costs[0].get("bytes accessed", 0)),
                float(costs[1].get("bytes accessed", 0)), L)
            kinds = set(colls[0]) | set(colls[1])
            coll_ex = {}
            for kind in kinds:
                b1 = colls[0].get(kind, {}).get("bytes", 0.0)
                b2 = colls[1].get(kind, {}).get("bytes", 0.0)
                n1 = colls[0].get(kind, {}).get("count", 0)
                n2 = colls[1].get(kind, {}).get("count", 0)
                coll_ex[kind] = {
                    "bytes": extrapolate_affine(b1, b2, L),
                    "count": extrapolate_affine(n1, n2, L),
                }
            coll_bytes = sum(v["bytes"] for v in coll_ex.values())
            compute_s = flops / V5E.peak_flops
            memory_s = bytes_acc / V5E.hbm_bw
            collective_s = coll_bytes / V5E.ici_bw
            terms = {"compute": compute_s, "memory": memory_s,
                     "collective": collective_s}
            total_hlo = flops * chips
            record["roofline"] = dict(
                flops_per_device=flops,
                bytes_per_device=bytes_acc,
                collective_bytes_per_device=coll_bytes,
                collectives=coll_ex,
                compute_s=compute_s,
                memory_s=memory_s,
                collective_s=collective_s,
                dominant=max(terms, key=terms.get),
                model_flops_global=model_flops,
                useful_flops_ratio=(model_flops / total_hlo) if total_hlo else None,
                peak_hbm_bytes=peak_hbm,
            )
    except Exception as e:  # noqa: BLE001 — record and continue
        record.update(status="error", error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-2000:])
    finally:
        record["wall_s"] = round(time.time() - t0, 2)

    os.makedirs(out_dir, exist_ok=True)
    variant = getattr(run_one, "variant_name", "")
    suffix = f"__{variant}" if variant else ""
    record["variant"] = variant or "baseline"
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_kind}{suffix}.json")
    with open(path, "w") as f:
        json.dump(record, f, indent=1, default=str)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ALL_ARCHS + ["all"])
    ap.add_argument("--shape", required=True, choices=registry.SHAPE_NAMES + ["all"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = registry.ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = registry.SHAPE_NAMES if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_one(arch, shape, mesh_kind, args.out)
                status = rec["status"]
                msg = f"[dryrun] {arch:>22} {shape:>12} {mesh_kind:>6}: {status}"
                if status == "ok":
                    m = rec["memory"]
                    msg += (f"  peak={m['peak_per_device']/2**30:.2f}GiB"
                            f" fits={m['fits']}")
                    r = rec.get("roofline")
                    if r:
                        msg += (f" compute={r['compute_s']*1e3:.1f}ms"
                                f" mem={r['memory_s']*1e3:.1f}ms"
                                f" coll={r['collective_s']*1e3:.1f}ms"
                                f" dom={r['dominant']}")
                elif status == "error":
                    msg += f"  {rec['error'][:160]}"
                else:
                    msg += f"  ({rec['skip_reason'][:60]})"
                print(msg, flush=True)


if __name__ == "__main__":
    main()
