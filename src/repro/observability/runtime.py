"""Observability bundle: one config knob that wires registry + tracer +
profiler into a subsystem (trainer, serving gateway, fleet worker).

``ObservabilityConfig`` is a plain sub-config (like ``DistributedConfig``)
so any layer can carry it; :func:`build_observability` instantiates the
runtime objects. Everything degrades to no-ops: no config → subsystems run
exactly as before (and the compile-count tests prove instrumentation adds
zero retraces when it IS on).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

from repro.core.config import ConfigBase, config_class
from repro.observability.hardware import ProfilerWindow
from repro.observability.metrics import JsonlSink, MetricsRegistry
from repro.observability.tracing import Tracer

__all__ = ["ObservabilityConfig", "Observability", "build_observability"]


@config_class
class ObservabilityConfig(ConfigBase):
    """Where telemetry goes and what hardware hooks are armed.

    ``metrics_path``   — JSONL sink for the metrics/event stream ("" = in-
                         memory only; snapshots still come back in results).
    ``trace_path``     — Chrome trace-event JSON written at the end of each
                         run ("" = tracing off).
    ``profile_dir`` + ``profile_start_step``/``profile_stop_step`` — the
                         on-demand ``jax.profiler`` window (capture steps
                         N..M; -1 = off).
    ``rank``           — process index: the pid lane in merged fleet traces.
    ``mfu``            — compute compiled-step FLOPs once and gauge per-step
                         MFU (costs one extra lower+compile, off the step
                         path).
    ``peak_flops_per_device`` — MFU denominator override (0 = per-platform
                         default table).
    ``reservoir_size`` — histogram reservoir bound.
    """

    metrics_path: str = ""
    trace_path: str = ""
    profile_dir: str = ""
    profile_start_step: int = -1
    profile_stop_step: int = -1
    rank: int = 0
    mfu: bool = True
    peak_flops_per_device: float = 0.0
    reservoir_size: int = 512


class Observability:
    """Live telemetry objects for one process: ``registry``, ``tracer``
    (None when no trace_path), ``profiler``."""

    def __init__(self, cfg: ObservabilityConfig):
        self.config = cfg
        sinks = [JsonlSink(cfg.metrics_path)] if cfg.metrics_path else []
        self.registry = MetricsRegistry(sinks=sinks,
                                        reservoir_size=cfg.reservoir_size)
        self.tracer: Optional[Tracer] = None
        if cfg.trace_path:
            self.tracer = Tracer(pid=cfg.rank,
                                 process_name=f"rank {cfg.rank}")
        self.profiler = ProfilerWindow(cfg.profile_dir,
                                       start_step=cfg.profile_start_step,
                                       stop_step=cfg.profile_stop_step)

    def save_trace(self) -> Optional[str]:
        if self.tracer is not None and self.config.trace_path:
            return self.tracer.save(self.config.trace_path)
        return None

    def snapshot(self) -> Dict[str, Any]:
        return self.registry.snapshot()

    def close(self):
        self.profiler.close()
        self.save_trace()
        self.registry.close()


def build_observability(cfg: Optional[ObservabilityConfig]
                        ) -> Optional[Observability]:
    return Observability(cfg) if cfg is not None else None
