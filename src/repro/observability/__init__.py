"""Unified observability: metrics registry, span tracing, hardware hooks.

The one subsystem every layer reports through (paper §operations):

* :mod:`repro.observability.metrics` — counters / gauges / bounded-
  reservoir histograms behind a :class:`MetricsRegistry` with pluggable
  sinks (JSONL, in-memory) and a stable event schema the goodput monitor's
  sink adopts.
* :mod:`repro.observability.tracing` — span :class:`Tracer` emitting
  Chrome trace-event JSON (open in Perfetto), per-rank pid lanes, fleet
  merge + schema validation.
* :mod:`repro.observability.hardware` — compiled-step FLOPs → MFU,
  ``device.memory_stats()`` gauges, on-demand ``jax.profiler`` windows.
* :mod:`repro.observability.runtime` — ``ObservabilityConfig`` + the
  per-process bundle subsystems instantiate.

Instrumented call sites: ``SpmdTrainer`` (step/data-wait/ckpt-stall spans,
summary routing, MFU gauges), ``serving.scheduler``/``gateway`` (request
lifecycle spans, latency reservoirs, queue/pool gauges), and
``launch.distributed`` workers + ``FleetSupervisor`` (per-rank traces
merged into one fleet timeline, step-boundary straggler skew).
"""

from repro.observability.hardware import (
    PEAK_FLOPS_PER_DEVICE,
    ProfilerWindow,
    compiled_cost,
    device_memory_stats,
    estimate_mfu,
)
from repro.observability.metrics import (
    Counter,
    Gauge,
    Histogram,
    JsonlSink,
    MemorySink,
    MetricsRegistry,
)
from repro.observability.runtime import (
    Observability,
    ObservabilityConfig,
    build_observability,
)
from repro.observability.tracing import (
    Tracer,
    load_trace,
    merge_traces,
    validate_chrome_trace,
)

__all__ = [
    "PEAK_FLOPS_PER_DEVICE",
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "MemorySink",
    "MetricsRegistry",
    "Observability",
    "ObservabilityConfig",
    "ProfilerWindow",
    "Tracer",
    "build_observability",
    "compiled_cost",
    "device_memory_stats",
    "estimate_mfu",
    "load_trace",
    "merge_traces",
    "validate_chrome_trace",
]
