"""Span tracer emitting Chrome trace-event JSON (openable in Perfetto).

One :class:`Tracer` per process. Spans are *complete* events (``ph: "X"``)
with microsecond timestamps; rank identity is the Chrome ``pid`` so a
merged fleet trace renders one lane per rank (``merge_traces``). The
timeline a human opens in https://ui.perfetto.dev shows, per rank: the
step/data-wait/checkpoint-stall phases of every training step, and per
request: queued → prefill → decode lanes on the serving side.

All tracing is host-side and outside jit — a span is two ``time`` calls
and a dict append, so instrumenting the hot loop adds zero retraces and
microsecond-scale per-span cost (measured by ``bench_observability``).

Wall-clock (``time.time``) is the default timebase so traces from
different processes on one host merge onto a common axis. (Cross-host
merging would need a clock-sync offset per host; the local fleet substrate
shares one clock.)

``validate_chrome_trace`` is the schema gate used by tests and the fleet
supervisor: required keys per event, non-negative durations, and proper
span nesting per (pid, tid) lane — two spans on one lane either nest or
are disjoint, never partially overlap.
"""

from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Callable, Dict, Iterable, List, Optional, Union

__all__ = ["Tracer", "merge_traces", "validate_chrome_trace", "load_trace"]


def _jsonable(v: Any) -> Any:
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class Tracer:
    """Collects Chrome trace events for one process (= one pid lane)."""

    def __init__(self, *, pid: int = 0, process_name: Optional[str] = None,
                 time_fn: Callable[[], float] = time.time):
        self.pid = pid
        self._time = time_fn
        self.events: List[Dict[str, Any]] = []
        self._meta: List[Dict[str, Any]] = []
        if process_name is not None:
            self.set_process_name(process_name)

    # -------------------------------------------------------------- metadata

    def set_process_name(self, name: str):
        self._meta.append({"name": "process_name", "ph": "M", "pid": self.pid,
                           "tid": 0, "args": {"name": name}})

    def set_thread_name(self, tid: int, name: str):
        self._meta.append({"name": "thread_name", "ph": "M", "pid": self.pid,
                           "tid": tid, "args": {"name": name}})

    # ----------------------------------------------------------------- spans

    @contextlib.contextmanager
    def span(self, name: str, *, tid: int = 0, **args):
        """Wraps a block in a complete ("X") event. Nesting follows the
        call stack naturally: an inner span's [ts, ts+dur] lies inside the
        outer's, which is exactly what the Chrome viewer stacks."""
        t0 = self._time()
        try:
            yield
        finally:
            t1 = self._time()
            self.add_span(name, t0, t1, tid=tid, **args)

    def add_span(self, name: str, t_start_s: float, t_end_s: float, *,
                 tid: int = 0, **args):
        """A span with explicit endpoints — for lifecycles measured by
        timestamps already on hand (per-request queued/prefill/decode)."""
        self.events.append({
            "name": name, "ph": "X", "pid": self.pid, "tid": tid,
            "ts": t_start_s * 1e6,
            "dur": max(t_end_s - t_start_s, 0.0) * 1e6,
            "args": {k: _jsonable(v) for k, v in args.items()},
        })

    def instant(self, name: str, *, tid: int = 0, **args):
        self.events.append({
            "name": name, "ph": "i", "s": "t", "pid": self.pid, "tid": tid,
            "ts": self._time() * 1e6,
            "args": {k: _jsonable(v) for k, v in args.items()},
        })

    def counter(self, name: str, value: float, *, tid: int = 0):
        """A counter-track sample (queue depth, page-pool utilization) —
        Perfetto renders these as a line chart under the process."""
        self.events.append({
            "name": name, "ph": "C", "pid": self.pid, "tid": tid,
            "ts": self._time() * 1e6, "args": {"value": _jsonable(value)}})

    # ------------------------------------------------------------------- I/O

    def to_chrome(self) -> Dict[str, Any]:
        return {"traceEvents": self._meta + self.events,
                "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path


def load_trace(path: str) -> Dict[str, Any]:
    with open(path) as f:
        return json.load(f)


def merge_traces(traces: Iterable[Union[str, Dict[str, Any], Tracer]], *,
                 out_path: Optional[str] = None) -> Dict[str, Any]:
    """Merges per-rank traces into ONE Chrome trace object.

    Each input keeps its own pid (the rank), so the merged file renders one
    process lane per rank. Inputs may be paths, already-loaded trace dicts,
    or live :class:`Tracer` objects. Duplicate process_name metadata from
    restart attempts is deduped (the lane persists across restarts — a rank
    that died and came back continues on the same lane).
    """
    events: List[Dict[str, Any]] = []
    seen_meta = set()
    for t in traces:
        if isinstance(t, Tracer):
            obj = t.to_chrome()
        elif isinstance(t, str):
            obj = load_trace(t)
        else:
            obj = t
        for e in obj.get("traceEvents", []):
            if e.get("ph") == "M":
                key = (e.get("pid"), e.get("tid"), e.get("name"),
                       json.dumps(e.get("args", {}), sort_keys=True))
                if key in seen_meta:
                    continue
                seen_meta.add(key)
            events.append(e)
    merged = {"traceEvents": events, "displayTimeUnit": "ms"}
    if out_path is not None:
        with open(out_path, "w") as f:
            json.dump(merged, f)
    return merged


def validate_chrome_trace(obj: Dict[str, Any]) -> Dict[str, Any]:
    """Validates a Chrome trace-event object; raises ValueError on the
    first violation. Returns summary stats (span/lane counts) on success.

    Checks: top-level shape, per-event required keys, non-negative ts/dur,
    and well-formed nesting per (pid, tid) lane — sorted by start time,
    every span must either contain or be disjoint from the next (a partial
    overlap means the producer emitted garbage endpoints).
    """
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        raise ValueError("trace must be a dict with a 'traceEvents' list")
    events = obj["traceEvents"]
    if not isinstance(events, list):
        raise ValueError("'traceEvents' must be a list")
    lanes: Dict[Any, List[Dict[str, Any]]] = {}
    n_spans = 0
    for i, e in enumerate(events):
        for key in ("name", "ph", "pid", "tid"):
            if key not in e:
                raise ValueError(f"event {i} missing required key {key!r}: {e}")
        ph = e["ph"]
        if ph == "M":
            continue
        if "ts" not in e:
            raise ValueError(f"event {i} ({e['name']}) has no 'ts'")
        if ph == "X":
            if "dur" not in e or e["dur"] < 0:
                raise ValueError(
                    f"event {i} ({e['name']}) needs a non-negative 'dur'")
            n_spans += 1
            lanes.setdefault((e["pid"], e["tid"]), []).append(e)
    for (pid, tid), spans in lanes.items():
        spans = sorted(spans, key=lambda e: (e["ts"], -e["dur"]))
        stack: List[Dict[str, Any]] = []
        # Tolerance for float-microsecond rounding at shared boundaries.
        eps = 0.5
        for e in spans:
            end = e["ts"] + e["dur"]
            while stack and e["ts"] >= stack[-1]["ts"] + stack[-1]["dur"] - eps:
                stack.pop()
            if stack and end > stack[-1]["ts"] + stack[-1]["dur"] + eps:
                raise ValueError(
                    f"lane (pid={pid}, tid={tid}): span {e['name']!r} "
                    f"[{e['ts']}, {end}] partially overlaps enclosing "
                    f"{stack[-1]['name']!r}")
            stack.append(e)
    return {
        "num_events": len(events),
        "num_spans": n_spans,
        "pids": sorted({e["pid"] for e in events}),
        "lanes": len(lanes),
    }
