"""Metrics registry: counters, gauges, bounded-reservoir histograms, sinks.

The ONE place every layer reports numbers through (paper §operations):
the trainer's step summaries, the serving gateway's latency percentiles,
and the fleet workers' goodput streams all flow into a
:class:`MetricsRegistry` so a run has a single, uniformly-schemed telemetry
stream instead of per-subsystem ad-hoc lists.

Design constraints (enforced by ``tests/test_observability.py`` and
``benchmarks/bench_observability.py``):

* **Hot-path cost is a dict update.** ``Counter.inc`` / ``Gauge.set`` /
  ``Histogram.record`` touch only in-process state — no I/O, no locks, no
  string formatting. Sinks see data when :meth:`MetricsRegistry.flush` is
  called (the trainer flushes at its logging cadence) or when an *event*
  is recorded explicitly.
* **Bounded memory.** Histograms keep a fixed-size uniform reservoir
  (Vitter's algorithm R, deterministic RNG) plus exact count/sum/min/max —
  p50/p99 snapshots over millions of samples at O(reservoir) bytes. This
  is what fixed the serving gateway's unbounded TTFT/TPOT lists.
* **Stable event schema.** Every record emitted to a sink is one flat JSON
  object: ``{"schema": 1, "kind": ..., "name": ..., "t": ..., ...}`` with
  ``kind`` in {"counter", "gauge", "histogram", "event", "meta"}. The
  goodput monitor's structured events adopt the same schema through
  :meth:`MetricsRegistry.goodput_sink`.

Sinks: :class:`JsonlSink` (one JSON object per line, append-only, the
format the fleet supervisor and offline analysis read) and
:class:`MemorySink` (tests).
"""

from __future__ import annotations

import json
import random
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = [
    "SCHEMA_VERSION",
    "Counter",
    "Gauge",
    "Histogram",
    "JsonlSink",
    "MemorySink",
    "MetricsRegistry",
]

SCHEMA_VERSION = 1

# Fields every sink record carries (the stable part of the schema; kinds
# add their own value fields on top).
RECORD_BASE_FIELDS = ("schema", "kind", "name", "t")


def _dumps_line(r: Dict[str, Any]) -> str:
    """One JSONL line. Fast path: flat records of simple-keyed scalars
    (every record the registry itself builds) serialize with repr — ~3x
    faster than json.dumps on small dicts, which is the whole cost of a
    per-log-step flush. Anything else falls back to json.dumps."""
    parts = []
    for k, v in r.items():
        if '"' in k or "\\" in k:
            return json.dumps(r) + "\n"
        tv = type(v)  # EXACT types only: a np.float64 passes isinstance
        # float checks but reprs as "np.float64(...)" — not JSON.
        if tv is float or tv is int:
            if v != v or v in (float("inf"), float("-inf")):
                return json.dumps(r) + "\n"  # non-finite: let json handle
            parts.append(f'"{k}":{v!r}')
        elif tv is bool:
            parts.append(f'"{k}":{"true" if v else "false"}')
        elif v is None:
            parts.append(f'"{k}":null')
        elif tv is str and v.isprintable() and '"' not in v \
                and "\\" not in v:
            parts.append(f'"{k}":"{v}"')
        else:
            return json.dumps(r) + "\n"
    return "{" + ",".join(parts) + "}\n"


def _jsonable(v: Any) -> Any:
    """Scalars pass through; arrays/np scalars collapse to float; the rest
    is stringified — a sink line must always be loadable JSON."""
    if v is None or type(v) in (bool, int, float, str):
        return v
    try:
        return float(v)
    except (TypeError, ValueError):
        return str(v)


class JsonlSink:
    """Append-only JSONL file sink (one record per line).

    Records are serialized on arrival but the file write is buffered
    (``buffer_records`` lines) so a per-step flush costs string building,
    not syscalls; a crashed process loses at most the buffered tail. The
    trainer's every-exit ``registry.flush()`` + ``close()`` drain it."""

    def __init__(self, path: str, *, buffer_records: int = 64):
        self.path = path
        self.buffer_records = buffer_records
        self._f = open(path, "a")
        self._buf: List[str] = []

    def __call__(self, records: List[Dict[str, Any]]):
        self._buf.extend(_dumps_line(r) for r in records)
        if len(self._buf) >= self.buffer_records:
            self.flush()

    def flush(self):
        if self._buf:
            self._f.write("".join(self._buf))
            self._buf.clear()
            self._f.flush()

    def close(self):
        if self._f is not None:
            self.flush()
            self._f.close()
            self._f = None


class MemorySink:
    """Keeps every record in a list — the test sink."""

    def __init__(self):
        self.records: List[Dict[str, Any]] = []

    def __call__(self, records: List[Dict[str, Any]]):
        self.records.extend(records)

    def close(self):
        pass


class Counter:
    """Monotonic count (requests served, tokens emitted, retries)."""

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0):
        self.value += n

    def snapshot(self) -> Dict[str, Any]:
        return {"value": self.value}


class Gauge:
    """Last-write-wins instantaneous value (loss, queue depth, HBM bytes)."""

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None
        self.updates = 0

    def set(self, v: float):
        self.value = v
        self.updates += 1

    def snapshot(self) -> Dict[str, Any]:
        return {"value": self.value, "updates": self.updates}


class Histogram:
    """Bounded-reservoir distribution (latencies, span durations).

    Uniform reservoir sampling (algorithm R): after N records, each sample
    survives with probability ``size/N`` — percentiles stay statistically
    representative of the WHOLE stream at fixed memory, unlike a
    keep-everything list (which the serving gateway used to grow for the
    process lifetime) or a keep-last window (which forgets warm-up tails).
    min/max/sum/count are tracked exactly.
    """

    def __init__(self, name: str, *, reservoir_size: int = 512, seed: int = 0):
        if reservoir_size < 1:
            raise ValueError(f"reservoir_size must be >= 1, got {reservoir_size}")
        self.name = name
        self.reservoir_size = reservoir_size
        self._rng = random.Random(seed)
        self.values: List[float] = []
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def record(self, v: float):
        v = float(v)
        self.count += 1
        self.total += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v
        if len(self.values) < self.reservoir_size:
            self.values.append(v)
        else:
            j = self._rng.randrange(self.count)
            if j < self.reservoir_size:
                self.values[j] = v

    def percentile(self, p: float, *, _sorted: Optional[List[float]] = None,
                   ) -> float:
        xs = sorted(self.values) if _sorted is None else _sorted
        if not xs:
            return 0.0
        # Nearest-rank on the reservoir (matches np.percentile 'lower'
        # closely enough for telemetry; avoids importing numpy here).
        idx = min(int(round((p / 100.0) * (len(xs) - 1))), len(xs) - 1)
        return xs[idx]

    def snapshot(self) -> Dict[str, Any]:
        xs = sorted(self.values)  # one sort shared by all percentiles
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min if self.min is not None else 0.0,
            "max": self.max if self.max is not None else 0.0,
            "mean": (self.total / self.count) if self.count else 0.0,
            "p50": self.percentile(50, _sorted=xs),
            "p90": self.percentile(90, _sorted=xs),
            "p99": self.percentile(99, _sorted=xs),
            "reservoir_len": len(self.values),
        }


class MetricsRegistry:
    """Named instruments + pluggable sinks behind one stable schema.

    Instruments are get-or-create by name (``registry.counter("x")`` twice
    returns the same object), so call sites never coordinate registration.
    """

    def __init__(self, *, sinks: Optional[List[Callable]] = None,
                 reservoir_size: int = 512,
                 time_fn: Callable[[], float] = time.time):
        self._sinks: List[Callable] = list(sinks or [])
        self._reservoir_size = reservoir_size
        self._time = time_fn
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}
        # Versions at the last flush: flush() emits a DELTA stream (only
        # instruments that changed), so a steady gauge costs nothing per
        # logging interval.
        self._flushed: Dict[Any, float] = {}

    # ----------------------------------------------------------- instruments

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str,
                  reservoir_size: Optional[int] = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(
                name, reservoir_size=reservoir_size or self._reservoir_size)
        return h

    # ---------------------------------------------------------------- events

    def _record(self, kind: str, name: str, *, t: Optional[float] = None,
                **fields) -> Dict[str, Any]:
        return {"schema": SCHEMA_VERSION, "kind": kind, "name": name,
                "t": self._time() if t is None else t,
                **{k: _jsonable(v) for k, v in fields.items()}}

    def record_event(self, name: str, **fields):
        """A one-off structured event, emitted to sinks immediately (the
        streaming part of the schema — goodput buckets, faults, restarts)."""
        self._emit([self._record("event", name, **fields)])

    def goodput_sink(self) -> Callable[[dict], None]:
        """Adapter: pass as ``GoodputMonitor(sink=registry.goodput_sink())``
        and every wall-time bucket event lands in the unified stream as
        ``{"kind": "event", "name": "goodput/<bucket>", "dur_s": ...}``."""

        def sink(event: dict):
            meta = {k: v for k, v in event.items() if k != "bucket"}
            self.record_event(f"goodput/{event['bucket']}", **meta)

        return sink

    # ------------------------------------------------------------- reporting

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time view of every instrument (no sink I/O)."""
        return {
            "counters": {n: c.snapshot() for n, c in self._counters.items()},
            "gauges": {n: g.snapshot() for n, g in self._gauges.items()},
            "histograms": {n: h.snapshot()
                           for n, h in self._histograms.items()},
        }

    def flush(self):
        """Emit one record per instrument *changed since the last flush* to
        the sinks (the batched, non-hot-path half of the schema — a delta
        stream, so unchanging instruments cost nothing per interval)."""
        records = []
        now = self._time()  # one clock read per batch, not per record
        # Counter/gauge records are built inline (not via _record) — this
        # loop runs every trainer logging step, and the extra snapshot +
        # kwargs-merge dicts were a measurable slice of the step budget.
        for n, c in self._counters.items():
            if self._flushed.get(("c", n)) != c.value:
                self._flushed[("c", n)] = c.value
                records.append(
                    {"schema": SCHEMA_VERSION, "kind": "counter", "name": n,
                     "t": now, "value": _jsonable(c.value)})
        for n, g in self._gauges.items():
            if self._flushed.get(("g", n)) != g.updates:
                self._flushed[("g", n)] = g.updates
                records.append(
                    {"schema": SCHEMA_VERSION, "kind": "gauge", "name": n,
                     "t": now, "value": _jsonable(g.value),
                     "updates": g.updates})
        for n, h in self._histograms.items():
            if self._flushed.get(("h", n)) != h.count:
                self._flushed[("h", n)] = h.count
                records.append(self._record("histogram", n, t=now,
                                            **h.snapshot()))
        if records:
            self._emit(records)

    def drain(self):
        """:meth:`flush` plus a durability flush of every buffering sink —
        the run-exit path (a sink's write buffer does not survive process
        exit on its own)."""
        self.flush()
        for sink in self._sinks:
            f = getattr(sink, "flush", None)
            if f is not None:
                f()

    def _emit(self, records: List[Dict[str, Any]]):
        for sink in self._sinks:
            sink(records)

    def add_sink(self, sink: Callable):
        self._sinks.append(sink)

    def close(self):
        self.flush()
        for sink in self._sinks:
            close = getattr(sink, "close", None)
            if close is not None:
                close()
