"""Hardware-utilization hooks: MFU from XLA cost analysis, HBM gauges,
and an on-demand ``jax.profiler`` window.

MFU (model FLOPs utilization) is THE cross-hardware efficiency number
(Modalities/PaLM convention): achieved model FLOP/s over the chip's peak.
The numerator comes from the *compiled* train step's own
``cost_analysis()`` — what XLA will actually execute, including remat
recompute — so it needs no analytical per-arch FLOP formula and stays
correct under kernel/remat/dtype changes. The denominator is a
per-platform peak table, overridable per run (``peak_flops_per_device``)
because "the" peak depends on dtype and part number.

On this CPU container the absolute MFU is not meaningful as a hardware
number, but the plumbing (compiled-cost → per-step gauge → BENCH_train
column) is exactly what runs on an accelerator, and relative movement
still tracks regressions.
"""

from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional

import jax

__all__ = [
    "PEAK_FLOPS_PER_DEVICE",
    "compiled_cost",
    "device_memory_stats",
    "estimate_mfu",
    "peak_flops_for_platform",
    "ProfilerWindow",
]

# Representative bf16 peak FLOP/s per device. TPU matches the roofline
# constant the dry-run analysis already uses (v5e 197 TFLOP/s); GPU is an
# A100-class bf16 peak; CPU is a nominal AVX-class figure so the MFU
# column exists (and tracks relative changes) off-accelerator.
PEAK_FLOPS_PER_DEVICE: Dict[str, float] = {
    "tpu": 197e12,
    "gpu": 312e12,
    "cpu": 1e11,
}


def peak_flops_for_platform(platform: Optional[str] = None) -> float:
    platform = platform or jax.default_backend()
    return PEAK_FLOPS_PER_DEVICE.get(platform, PEAK_FLOPS_PER_DEVICE["cpu"])


def compiled_cost(compiled) -> Dict[str, Optional[float]]:
    """FLOPs + bytes-accessed of a compiled executable via XLA's own cost
    analysis (``None`` fields when the backend doesn't report them).
    ``cost_analysis()`` returns a dict on some backends and a one-element
    list of dicts on others; both are handled."""
    flops = bytes_accessed = None
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        if ca:
            flops = float(ca.get("flops", 0.0)) or None
            bytes_accessed = float(ca.get("bytes accessed", 0.0)) or None
    except Exception:  # noqa: BLE001 — backend without cost analysis
        pass
    return {"flops": flops, "bytes_accessed": bytes_accessed}


def estimate_mfu(flops_per_step: Optional[float], step_time_s: float, *,
                 num_devices: int = 1, platform: Optional[str] = None,
                 peak_flops_per_device: float = 0.0) -> Optional[float]:
    """Achieved model FLOP/s over aggregate peak; None when unmeasurable.

    ``flops_per_step`` is the GLOBAL compiled-step FLOPs (XLA reports the
    whole SPMD program); the denominator scales by ``num_devices``.
    """
    if not flops_per_step or step_time_s <= 0:
        return None
    peak = peak_flops_per_device or peak_flops_for_platform(platform)
    if peak <= 0:
        return None
    return flops_per_step / (step_time_s * peak * max(num_devices, 1))


def device_memory_stats(device=None) -> Dict[str, float]:
    """Per-device memory stats (peak HBM in ``peak_bytes_in_use`` on
    TPU/GPU). Empty dict on backends without memory stats (CPU)."""
    device = device or jax.local_devices()[0]
    try:
        stats = device.memory_stats()
    except Exception:  # noqa: BLE001
        stats = None
    if not stats:
        return {}
    return {k: float(v) for k, v in stats.items()
            if isinstance(v, (int, float))}


class ProfilerWindow:
    """On-demand ``jax.profiler`` capture of steps ``[start, stop]``.

    The trainer calls :meth:`on_step_start` / :meth:`on_step_end` at each
    step boundary; the window starts the trace before ``start`` executes
    and stops it after ``stop`` completes, writing a TensorBoard-loadable
    profile under ``logdir``. Inactive (both bounds < 0) it is two integer
    compares per step. Profiler failures (unsupported backend, busy
    session) degrade to a warning — profiling must never kill a run.
    """

    def __init__(self, logdir: str = "", *, start_step: int = -1,
                 stop_step: int = -1):
        if start_step >= 0 and stop_step < start_step:
            raise ValueError(
                f"profiler window stop_step {stop_step} precedes start_step "
                f"{start_step}")
        self.logdir = logdir
        self.start_step = start_step
        self.stop_step = stop_step
        self.active = False
        self.captured = False
        self.error: Optional[str] = None

    @property
    def enabled(self) -> bool:
        return bool(self.logdir) and self.start_step >= 0

    def on_step_start(self, step: int):
        if not self.enabled or self.active or self.captured:
            return
        if step >= self.start_step:
            try:
                jax.profiler.start_trace(self.logdir)
                self.active = True
            except Exception as e:  # noqa: BLE001
                self.error = repr(e)
                self.captured = True  # don't retry every step
                print(f"[observability] profiler start failed: {e}")

    def on_step_end(self, step: int):
        if self.active and step >= self.stop_step:
            self._stop()

    def _stop(self):
        try:
            jax.profiler.stop_trace()
        except Exception as e:  # noqa: BLE001
            self.error = repr(e)
            print(f"[observability] profiler stop failed: {e}")
        self.active = False
        self.captured = True

    def close(self):
        """Stop a still-open window (run ended early / preemption)."""
        if self.active:
            self._stop()


@contextlib.contextmanager
def profiler_window(logdir: str):
    """Imperative capture of an arbitrary block (notebooks, benches)."""
    w = ProfilerWindow(logdir, start_step=0, stop_step=0)
    w.on_step_start(0)
    try:
        yield w
    finally:
        w.close()
