"""Shared utilities: ambient mesh, sharding helpers, tree helpers, remat tags.

Config-based parallelism (paper §4.2) works by layers carrying partition
specs over *named axes*; at trace time the ambient mesh (set by the trainer /
dry-run launcher) resolves the names. Axis names absent from the active mesh
are dropped, so the same config runs on a 1-CPU test mesh and a 512-chip
production mesh unchanged.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "PartitionSpecLike",
    "make_mesh",
    "set_mesh",
    "current_mesh",
    "resolve_spec",
    "maybe_shard",
    "named_sharding",
    "remat_name",
    "flatten_tree",
    "unflatten_tree",
    "tree_bytes",
    "tree_param_count",
    "cast_floats",
    "safe_zip_trees",
]

# A partition spec expressed as a tuple of axis names (or tuples of names, or
# None) — e.g. (("pod", "data"), None, "model").
PartitionSpecLike = Optional[Sequence[Union[str, Tuple[str, ...], None]]]


def make_mesh(shape, axis_names) -> Mesh:
    """``jax.make_mesh`` with Auto axis types where this jax supports them
    (``jax.sharding.AxisType`` only exists in newer releases)."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(tuple(shape), tuple(axis_names))
    return jax.make_mesh(tuple(shape), tuple(axis_names),
                         axis_types=(axis_type.Auto,) * len(shape))


class _MeshHolder(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None


_MESH = _MeshHolder()


@contextlib.contextmanager
def set_mesh(mesh: Optional[Mesh]):
    """Sets the ambient mesh used to resolve named partition specs."""
    prev = _MESH.mesh
    _MESH.mesh = mesh
    try:
        if mesh is not None:
            with mesh:
                yield mesh
        else:
            yield None
    finally:
        _MESH.mesh = prev


def current_mesh() -> Optional[Mesh]:
    return _MESH.mesh


def resolve_spec(spec: PartitionSpecLike, mesh: Optional[Mesh] = None) -> PartitionSpec:
    """Converts an axis-name tuple to a PartitionSpec valid for ``mesh``.

    Axis names not present in the mesh are dropped (replicated), which lets
    one config serve heterogeneous meshes — the paper's mesh-rule mechanism
    relies on this.
    """
    mesh = mesh or current_mesh()
    if spec is None:
        return PartitionSpec()
    names = set(mesh.axis_names) if mesh is not None else set()

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            if not kept:
                return None
            return kept if len(kept) > 1 else kept[0]
        return entry if entry in names else None

    return PartitionSpec(*[keep(e) for e in spec])


def named_sharding(spec: PartitionSpecLike, mesh: Optional[Mesh] = None,
                   *, memory_kind: Optional[str] = None) -> Optional[NamedSharding]:
    mesh = mesh or current_mesh()
    if mesh is None:
        return None
    kwargs = {}
    if memory_kind is not None:
        kwargs["memory_kind"] = memory_kind
    return NamedSharding(mesh, resolve_spec(spec, mesh), **kwargs)


def maybe_shard(x: jax.Array, spec: PartitionSpecLike) -> jax.Array:
    """with_sharding_constraint against the ambient mesh; no-op without one."""
    mesh = current_mesh()
    if mesh is None or spec is None or mesh.size == 1:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, resolve_spec(spec, mesh)))


def remat_name(x: Any, name: str) -> Any:
    """Tags an activation as a named remat point (paper's tagged remat)."""
    return checkpoint_name(x, name)


# ----------------------------- tree helpers --------------------------------


def flatten_tree(tree: Any, *, sep: str = "/", prefix: str = "") -> Dict[str, Any]:
    """Flattens a nested dict tree to {path: leaf}."""
    out: Dict[str, Any] = {}

    def rec(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                rec(node[k], f"{path}{sep}{k}" if path else str(k))
        else:
            out[path] = node

    rec(tree, prefix)
    return out


def unflatten_tree(flat: Dict[str, Any], *, sep: str = "/") -> Any:
    root: Dict[str, Any] = {}
    for path, leaf in flat.items():
        parts = path.split(sep)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = leaf
    return root


def tree_bytes(tree: Any) -> int:
    leaves = jax.tree.leaves(tree)
    return sum(l.size * l.dtype.itemsize for l in leaves if hasattr(l, "size"))


def tree_param_count(tree: Any) -> int:
    return sum(l.size for l in jax.tree.leaves(tree) if hasattr(l, "size"))


def cast_floats(tree: Any, dtype) -> Any:
    def cast(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(cast, tree)


def safe_zip_trees(a: Any, b: Any):
    """Zips two trees with identical structure, yielding (leaf_a, leaf_b)."""
    la, ta = jax.tree.flatten(a)
    lb, tb = jax.tree.flatten(b)
    if ta != tb:
        raise ValueError(f"Tree structures differ: {ta} vs {tb}")
    return zip(la, lb)
