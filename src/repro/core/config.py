"""Hierarchical, strictly-encapsulated configuration system.

This is the paper's core contribution (AXLearn §4.1): every module is defined
by a Config object that composes *child* configs. Configs are plain Python,
can be partially specified, cloned, recursively traversed, and instantiated.

Key properties reproduced from the paper:

* **Strict encapsulation** — a parent config never flattens a child's fields;
  it holds the child config itself. Swapping a child implementation is a
  field assignment, never an edit to the parent class.
* **Partial specification** — fields may be ``REQUIRED`` or deferred
  (e.g. a ``FunctionSpec`` of the not-yet-known input dim) and filled in by
  the parent at instantiation time.
* **Traversal** — ``visit_config`` / ``replace_config`` walk the tree so a
  feature like MoE integrates into *any* experiment in O(1) LoC.
* **3rd-party interop** — ``config_for_function`` / ``config_for_class`` wrap
  arbitrary callables into configs.
"""

from __future__ import annotations

import copy
import dataclasses
import enum
import inspect
import re
import textwrap
from collections.abc import Callable
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type, TypeVar, Union

__all__ = [
    "REQUIRED",
    "Required",
    "RequiredFieldMissingError",
    "UnknownFieldError",
    "ConfigBase",
    "InstantiableConfig",
    "FunctionConfigBase",
    "ClassConfigBase",
    "config_class",
    "config_for_function",
    "config_for_class",
    "maybe_instantiate",
    "maybe_set",
    "visit_config",
    "update_configs_recursively",
    "replace_config",
    "config_to_dict",
    "similar_names",
]

T = TypeVar("T")


class RequiredFieldValue:
    """Sentinel for required-but-unset config fields."""

    _instance: Optional["RequiredFieldValue"] = None

    def __new__(cls):
        if cls._instance is None:
            cls._instance = super().__new__(cls)
        return cls._instance

    def __repr__(self):
        return "REQUIRED"

    def __bool__(self):
        return False

    def __deepcopy__(self, memo):
        return self


REQUIRED = RequiredFieldValue()
# A type alias used in annotations: Required[int] reads as "int, must be set".
Required = Union[T, RequiredFieldValue]


class RequiredFieldMissingError(ValueError):
    """Raised when instantiating a config with unset REQUIRED fields."""


class UnknownFieldError(AttributeError):
    """Raised when setting a field that is not declared on the config."""


def similar_names(name: str, candidates: Sequence[str], *, k: int = 3) -> List[str]:
    """Returns up to ``k`` candidates most similar to ``name`` (for error msgs)."""

    def score(c: str) -> Tuple[int, int]:
        common = len(set(name) & set(c))
        prefix = 0
        for a, b in zip(name, c):
            if a != b:
                break
            prefix += 1
        return (prefix, common)

    ranked = sorted(candidates, key=score, reverse=True)
    return list(ranked[:k])


@dataclasses.dataclass
class _FieldSpec:
    name: str
    annotation: Any
    default: Any


class ConfigBase:
    """Base class for all configs.

    Subclasses declare fields as class-level annotations (like dataclasses)::

        @config_class
        class Config(ConfigBase):
            input_dim: Required[int] = REQUIRED
            bias: bool = True

    Fields are instance attributes after construction; unknown attribute
    assignment raises (catching config typos — a production must-have).
    """

    _field_specs: Dict[str, _FieldSpec] = {}

    def __init__(self, **kwargs):
        # Materialize every declared field on the instance.
        for spec in type(self)._field_specs.values():
            object.__setattr__(self, spec.name, copy.deepcopy(spec.default))
        for k, v in kwargs.items():
            setattr(self, k, v)

    # --- field access -----------------------------------------------------

    def __setattr__(self, name: str, value: Any):
        if name.startswith("_"):
            object.__setattr__(self, name, value)
            return
        if name not in type(self)._field_specs:
            hints = similar_names(name, list(type(self)._field_specs))
            raise UnknownFieldError(
                f"{type(self).__qualname__} has no field {name!r}. "
                f"Did you mean one of {hints}?"
            )
        object.__setattr__(self, name, value)

    def keys(self) -> List[str]:
        return list(type(self)._field_specs)

    def items(self) -> List[Tuple[str, Any]]:
        return [(k, getattr(self, k)) for k in self.keys()]

    # --- mutation ---------------------------------------------------------

    def set(self, **kwargs) -> "ConfigBase":
        """Sets multiple fields; returns self for chaining."""
        for k, v in kwargs.items():
            setattr(self, k, v)
        return self

    def clone(self, **kwargs) -> "ConfigBase":
        """Returns a deep copy with optional field overrides."""
        cfg = copy.deepcopy(self)
        return cfg.set(**kwargs)

    # --- introspection ----------------------------------------------------

    def required_fields_missing(self) -> List[str]:
        return [k for k, v in self.items() if isinstance(v, RequiredFieldValue)]

    def debug_string(self, *, indent: int = 0) -> str:
        """Human-readable nested repr, used by golden-config tests."""
        lines = [f"{type(self).__qualname__}("]
        for k, v in sorted(self.items()):
            if isinstance(v, ConfigBase):
                sub = v.debug_string(indent=indent + 2)
                lines.append(f"  {k}={sub},")
            elif isinstance(v, (list, tuple)) and any(isinstance(e, ConfigBase) for e in v):
                inner = ", ".join(
                    e.debug_string(indent=indent + 4) if isinstance(e, ConfigBase) else repr(e)
                    for e in v
                )
                lines.append(f"  {k}=[{inner}],")
            else:
                lines.append(f"  {k}={v!r},")
        lines.append(")")
        return ("\n" + " " * indent).join(lines)

    def __repr__(self):
        return self.debug_string()

    def __eq__(self, other):
        if type(self) is not type(other):
            return False
        return dict(self.items()) == dict(other.items())


def _collect_field_specs(cls: type) -> Dict[str, _FieldSpec]:
    specs: Dict[str, _FieldSpec] = {}
    for klass in reversed(cls.__mro__):
        annotations = klass.__dict__.get("__annotations__", {})
        for name, annotation in annotations.items():
            if name.startswith("_"):
                continue
            default = klass.__dict__.get(name, REQUIRED)
            specs[name] = _FieldSpec(name=name, annotation=annotation, default=default)
    return specs


def config_class(cls: Type[T]) -> Type[T]:
    """Class decorator registering annotated fields as config fields."""
    if not issubclass(cls, ConfigBase):
        raise TypeError(f"@config_class requires a ConfigBase subclass, got {cls}.")
    cls._field_specs = _collect_field_specs(cls)
    return cls


# Ensure the base class itself has empty specs.
ConfigBase._field_specs = {}


class InstantiableConfig(ConfigBase):
    """A config that can be instantiated into an object."""

    def instantiate(self, **kwargs) -> Any:
        raise NotImplementedError(type(self))


def maybe_instantiate(value: Any, **kwargs) -> Any:
    if isinstance(value, InstantiableConfig):
        return value.instantiate(**kwargs)
    return value


def maybe_set(cfg: ConfigBase, **kwargs) -> ConfigBase:
    """Sets fields that exist AND are currently REQUIRED/None; skips others.

    Used for parent→child propagation of shared dims (e.g. input_dim) without
    clobbering explicit user settings — the mechanism behind partial configs.
    """
    for k, v in kwargs.items():
        if k in cfg.keys():
            cur = getattr(cfg, k)
            if isinstance(cur, RequiredFieldValue) or cur is None:
                setattr(cfg, k, v)
    return cfg


class _FunctionOrClassConfig(InstantiableConfig):
    """Shared machinery for config_for_function / config_for_class."""

    _fn: Optional[Callable] = None  # set per generated subclass

    def instantiate(self, **overrides) -> Any:
        fn = type(self)._fn
        assert fn is not None
        kwargs = {}
        for k, v in self.items():
            if isinstance(v, RequiredFieldValue):
                raise RequiredFieldMissingError(
                    f"Required field {k!r} of {type(self).__qualname__} "
                    f"(wrapping {fn!r}) is not set."
                )
            kwargs[k] = maybe_instantiate(v)
        kwargs.update(overrides)
        return fn(**kwargs)


class FunctionConfigBase(_FunctionOrClassConfig):
    pass


class ClassConfigBase(_FunctionOrClassConfig):
    pass


def _config_from_signature(
    fn: Callable, *, base: type, name: str
) -> Type[_FunctionOrClassConfig]:
    sig = inspect.signature(fn)
    annotations: Dict[str, Any] = {}
    defaults: Dict[str, Any] = {}
    for pname, param in sig.parameters.items():
        if param.kind in (inspect.Parameter.VAR_POSITIONAL, inspect.Parameter.VAR_KEYWORD):
            continue
        annotations[pname] = param.annotation if param.annotation is not sig.empty else Any
        defaults[pname] = param.default if param.default is not sig.empty else REQUIRED
    cls = type(name, (base,), {"__annotations__": annotations, **defaults, "_fn": fn})
    return config_class(cls)


def config_for_function(fn: Callable) -> FunctionConfigBase:
    """Builds a config whose fields mirror ``fn``'s signature (paper §4.1)."""
    cls = _config_from_signature(fn, base=FunctionConfigBase, name=f"config_for_function({fn.__name__})")
    return cls()


def config_for_class(cls_: type) -> ClassConfigBase:
    """Builds a config whose fields mirror ``cls_.__init__``'s signature."""
    init = cls_.__init__
    sig = inspect.signature(init)
    params = dict(sig.parameters)
    params.pop("self", None)
    fake = lambda **kw: cls_(**kw)  # noqa: E731
    fake.__signature__ = sig.replace(parameters=list(params.values()))
    fake.__name__ = cls_.__name__
    cfg_cls = _config_from_signature(fake, base=ClassConfigBase, name=f"config_for_class({cls_.__name__})")
    return cfg_cls()


# ---------------------------------------------------------------------------
# Traversal — the engine of O(1) LoC-complexity integrations.
# ---------------------------------------------------------------------------


def visit_config(cfg: Any, fn: Callable[[str, ConfigBase], None], *, path: str = "") -> None:
    """Depth-first visit of every ConfigBase reachable from ``cfg``.

    Visits nested configs inside lists/tuples/dicts too (hybrid stacks use
    per-layer config lists).
    """
    if isinstance(cfg, ConfigBase):
        fn(path, cfg)
        for k, v in cfg.items():
            visit_config(v, fn, path=f"{path}.{k}" if path else k)
    elif isinstance(cfg, (list, tuple)):
        for i, v in enumerate(cfg):
            visit_config(v, fn, path=f"{path}[{i}]")
    elif isinstance(cfg, dict):
        for k, v in cfg.items():
            visit_config(v, fn, path=f"{path}[{k!r}]")


def update_configs_recursively(
    cfg: Any,
    updates: Dict[str, Any],
    *,
    only_unset: bool = False,
    where: Optional[Callable[[str, "ConfigBase"], bool]] = None,
) -> int:
    """Sets ``field=value`` on every reachable config declaring that field.

    This is the engine behind cross-cutting config levers (dtype policy,
    kernel selection, remat policy): one call touches every module in an
    arbitrarily deep experiment tree — the paper's ~10-LoC-complexity
    mechanism, without writing a bespoke visitor each time.

    ``only_unset`` restricts to fields currently REQUIRED/None (parent →
    child propagation semantics); ``where(path, cfg)`` optionally filters
    target configs. ConfigBase values are cloned per site so sites never
    alias. Returns the number of configs updated.
    """
    count = 0

    def visit(path, node):
        nonlocal count
        if where is not None and not where(path, node):
            return
        hit = False
        for field, value in updates.items():
            if field not in node.keys():
                continue
            if only_unset:
                cur = getattr(node, field)
                if not (isinstance(cur, RequiredFieldValue) or cur is None):
                    continue
            setattr(node, field,
                    value.clone() if isinstance(value, ConfigBase) else value)
            hit = True
        if hit:
            count += 1

    visit_config(cfg, visit)
    return count


def replace_config(
    cfg: Any,
    *,
    target: Union[type, Callable[[ConfigBase], bool]],
    new_cfg: Union[ConfigBase, Callable[[ConfigBase], ConfigBase]],
    propagate: Sequence[str] = ("input_dim", "output_dim", "name"),
) -> int:
    """Recursively replaces any config matching ``target`` with ``new_cfg``.

    This is the paper's ~10-line snippet that integrates MoE into 1,000+
    experiments. ``target`` is a Module class (matches that module's Config),
    a Config class, or a predicate. ``new_cfg`` may be a template (cloned per
    site) or a callable old→new. Shared interface fields listed in
    ``propagate`` are carried over from the old config when unset on the new.

    Returns the number of replacements performed.
    """

    def matches(value: ConfigBase) -> bool:
        if isinstance(target, type):
            if issubclass(target, ConfigBase):
                return isinstance(value, target)
            # A Module class: match its Config type exactly (not subclasses —
            # strictness keeps replacements predictable).
            return getattr(target, "Config", None) is type(value) or isinstance(
                value, getattr(target, "Config", ())
            )
        return bool(target(value))

    count = 0

    def make_new(old: ConfigBase) -> ConfigBase:
        nonlocal count
        count += 1
        if callable(new_cfg) and not isinstance(new_cfg, ConfigBase):
            fresh = new_cfg(old)
        else:
            fresh = new_cfg.clone()
        for field in propagate:
            if field in fresh.keys() and field in old.keys():
                cur = getattr(fresh, field)
                if isinstance(cur, RequiredFieldValue) or cur is None:
                    setattr(fresh, field, getattr(old, field))
        return fresh

    def recurse(value: Any) -> Any:
        if isinstance(value, ConfigBase):
            if matches(value):
                return make_new(value)
            for k, v in value.items():
                new_v = recurse(v)
                if new_v is not v:
                    setattr(value, k, new_v)
            return value
        if isinstance(value, list):
            return [recurse(v) for v in value]
        if isinstance(value, tuple):
            return tuple(recurse(v) for v in value)
        if isinstance(value, dict):
            return {k: recurse(v) for k, v in value.items()}
        return value

    result = recurse(cfg)
    if result is not cfg and isinstance(cfg, ConfigBase):
        raise ValueError("Top-level config itself matched target; replace it at the call site.")
    return count


def config_to_dict(cfg: Any) -> Any:
    """Serializes a config tree to plain dicts (for golden-config tests)."""
    if isinstance(cfg, ConfigBase):
        out = {"__type__": type(cfg).__qualname__}
        fn = getattr(type(cfg), "_fn", None)
        if fn is not None:
            out["__fn__"] = getattr(fn, "__qualname__", repr(fn))
        for k, v in sorted(cfg.items()):
            out[k] = config_to_dict(v)
        return out
    if isinstance(cfg, (list, tuple)):
        return [config_to_dict(v) for v in cfg]
    if isinstance(cfg, dict):
        return {str(k): config_to_dict(v) for k, v in sorted(cfg.items(), key=lambda kv: str(kv[0]))}
    if isinstance(cfg, RequiredFieldValue):
        return "REQUIRED"
    if isinstance(cfg, enum.Enum):
        return f"{type(cfg).__name__}.{cfg.name}"
    if callable(cfg):
        return getattr(cfg, "__qualname__", repr(cfg))
    return cfg
