"""Module system with InvocationContext (AXLearn §4.3, Figure 3).

JAX programs must be purely functional, but neural nets are stateful. Rather
than forcing users to thread params/PRNG/summaries through every call, an
``InvocationContext`` is transparently pushed when a parent module invokes a
child, which:

  * routes the child's parameter subtree from the parent state,
  * splits the PRNG key deterministically by child name,
  * gives the child a place to emit summaries and module outputs (e.g. MoE
    load-balance losses) that are collected up the stack *without any
    ancestor layer knowing about them*.

The root entrypoint is :func:`functional` (the analogue of AXLearn's ``F``),
which runs a module method under a fresh root context and returns
``(outputs, OutputCollection)`` — a pure function suitable for jit/grad.

Contexts reference modules but not vice-versa, so arbitrary (even 3rd-party)
code can reach :func:`current_context` without holding a module reference.
"""

from __future__ import annotations

import dataclasses
import inspect
import threading
import zlib
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax

from repro.core.config import (
    REQUIRED,
    ConfigBase,
    InstantiableConfig,
    Required,
    config_class,
)

__all__ = [
    "Module",
    "InvocationContext",
    "OutputCollection",
    "current_context",
    "functional",
    "new_output_collection",
    "child_context",
]


def _stable_hash(name: str) -> int:
    """Deterministic across processes (unlike Python's hash)."""
    return zlib.crc32(name.encode("utf-8"))


@dataclasses.dataclass
class OutputCollection:
    """Side outputs emitted during an invocation.

    ``summaries``: scalar/tensor diagnostics keyed by module path.
    ``module_outputs``: auxiliary computation results (e.g. ``aux_loss``)
        keyed by module path; the learner aggregates matching keys.
    ``state_updates``: updated stateful tensors (e.g. KV caches) keyed by
        module path.
    """

    summaries: Dict[str, Any] = dataclasses.field(default_factory=dict)
    module_outputs: Dict[str, Any] = dataclasses.field(default_factory=dict)
    state_updates: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def update(self, other: "OutputCollection"):
        self.summaries.update(other.summaries)
        self.module_outputs.update(other.module_outputs)
        self.state_updates.update(other.state_updates)


def new_output_collection() -> OutputCollection:
    return OutputCollection()


class _ContextStack(threading.local):
    def __init__(self):
        self.stack: List["InvocationContext"] = []


_CONTEXT_STACK = _ContextStack()


def no_context(fn):
    """Marks a public Module method as structural: callable without an
    InvocationContext (it must not touch traced state/PRNG)."""
    fn._no_ctx = True
    return fn


def current_context() -> Optional["InvocationContext"]:
    stack = _CONTEXT_STACK.stack
    return stack[-1] if stack else None


@dataclasses.dataclass
class InvocationContext:
    """One frame of the invocation stack (paper Figure 3)."""

    module: "Module"
    state: Any
    path: str
    is_training: bool
    prng_key: Optional[jax.Array]
    output_collection: OutputCollection

    # --- stack management ---------------------------------------------------

    def __enter__(self) -> "InvocationContext":
        _CONTEXT_STACK.stack.append(self)
        return self

    def __exit__(self, *exc):
        popped = _CONTEXT_STACK.stack.pop()
        assert popped is self
        return False

    def child(
        self,
        module: "Module",
        *,
        state: Any = None,
        prng_key: Optional[jax.Array] = None,
        output_collection: Optional[OutputCollection] = None,
    ) -> "InvocationContext":
        """Creates the context for invoking ``module`` as a child of this one."""
        name = module.name
        if state is None:
            state = self.state.get(name, {}) if isinstance(self.state, dict) else {}
        if prng_key is None and self.prng_key is not None:
            prng_key = jax.random.fold_in(self.prng_key, _stable_hash(name))
        return InvocationContext(
            module=module,
            state=state,
            path=f"{self.path}/{name}" if self.path else name,
            is_training=self.is_training,
            prng_key=prng_key,
            # Shared root collection: children write under their own path, so
            # no merge step is needed and ancestors stay oblivious.
            output_collection=(
                output_collection if output_collection is not None else self.output_collection
            ),
        )

    # --- side-output API ----------------------------------------------------

    def add_summary(self, name: str, value: Any):
        self.output_collection.summaries[f"{self.path}/{name}" if self.path else name] = value

    def add_module_output(self, name: str, value: Any):
        self.output_collection.module_outputs[f"{self.path}/{name}" if self.path else name] = value

    def add_state_update(self, name: str, value: Any):
        self.output_collection.state_updates[f"{self.path}/{name}" if self.path else name] = value


def child_context(module: "Module", **overrides) -> InvocationContext:
    ctx = current_context()
    if ctx is None:
        raise RuntimeError(
            "No InvocationContext. Wrap the call with repro.core.module.functional()."
        )
    return ctx.child(module, **overrides)


class _AutoContextMeta(type):
    """Wraps public methods so child invocations push contexts transparently.

    User layer code therefore looks imperative (``self.ffn(x)``) while staying
    functional — the paper's key usability claim.
    """

    _NO_WRAP = {
        "default_config",
        "__init__",
        "__init_subclass__",
        # Structural methods: operate on configs/specs, not on traced state.
        "initialize_parameters_recursively",
        "create_parameter_specs_recursively",
    }

    def __new__(mcs, name, bases, namespace):
        for attr, value in list(namespace.items()):
            if attr.startswith("_") or attr in mcs._NO_WRAP:
                continue
            if inspect.isfunction(value):
                namespace[attr] = mcs._wrap(value)
        return super().__new__(mcs, name, bases, namespace)

    @staticmethod
    def _wrap(fn):
        if getattr(fn, "_no_ctx", False):
            return fn
        if getattr(fn, "_ctx_wrapped", False):
            return fn

        def wrapped(self, *args, **kwargs):
            ctx = current_context()
            if ctx is None:
                raise RuntimeError(
                    f"Calling {type(self).__name__}.{fn.__name__} outside an "
                    "InvocationContext; use repro.core.module.functional()."
                )
            if ctx.module is self:
                # Re-entrant call on the same module (e.g. forward calling a
                # sibling public method): stay in the current frame.
                return fn(self, *args, **kwargs)
            with ctx.child(self):
                return fn(self, *args, **kwargs)

        wrapped._ctx_wrapped = True
        wrapped.__name__ = fn.__name__
        wrapped.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
        wrapped.__doc__ = fn.__doc__
        wrapped._original = fn
        return wrapped


class Module(metaclass=_AutoContextMeta):
    """Base class of every component: layers, models, trainers, inputs.

    A Module is defined by its nested ``Config`` (strictly encapsulating its
    children's configs) and builds its children in ``__init__`` via
    ``_add_child``. Modules hold *no tensors* — parameters live in the state
    tree threaded by InvocationContexts.
    """

    @config_class
    class Config(InstantiableConfig):
        name: Optional[str] = None

        def instantiate(self, *, parent: Optional["Module"] = None) -> "Module":
            missing = self.required_fields_missing()
            if missing:
                raise ValueError(
                    f"Cannot instantiate {type(self).__qualname__}: required "
                    f"fields not set: {missing}"
                )
            module_cls = getattr(type(self), "_module_cls", None)
            assert module_cls is not None, type(self)
            return module_cls(self, parent=parent)

    def __init_subclass__(cls, **kwargs):
        super().__init_subclass__(**kwargs)
        # Bind the innermost Config class defined on (or inherited by) cls.
        cfg_cls = cls.__dict__.get("Config")
        if cfg_cls is not None:
            cfg_cls = config_class(cfg_cls)  # idempotent; collects declared fields
            cfg_cls._module_cls = cls
            cls.Config = cfg_cls
        else:
            # Subclass without its own Config: generate one inheriting the
            # parent's so default_config() instantiates the right class.
            parent_cfg = cls.Config

            cfg_cls = config_class(
                type("Config", (parent_cfg,), {"_module_cls": cls, "__qualname__": f"{cls.__qualname__}.Config"})
            )
            cls.Config = cfg_cls

    @classmethod
    def default_config(cls) -> "Module.Config":
        return cls.Config()

    def __init__(self, cfg: "Module.Config", *, parent: Optional["Module"] = None):
        self._config = cfg.clone()
        self._parent = parent
        self._children: Dict[str, "Module"] = {}
        if cfg.name is None:
            self._config.set(name=type(self).__name__.lower())

    # --- tree structure -----------------------------------------------------

    @property
    def config(self) -> "Module.Config":
        return self._config

    @property
    def name(self) -> str:
        return self._config.name

    @property
    def children(self) -> Dict[str, "Module"]:
        return dict(self._children)

    @property
    def path(self) -> str:
        if self._parent is None:
            return self.name
        return f"{self._parent.path}.{self.name}"

    def _add_child(self, name: str, child_cfg: InstantiableConfig) -> "Module":
        if name in self._children:
            raise ValueError(f"Duplicate child {name!r} in {self.path}.")
        child_cfg = child_cfg.clone()
        if "name" in child_cfg.keys():
            child_cfg.set(name=name)
        child = child_cfg.instantiate(parent=self)
        self._children[name] = child
        # Expose as attribute for the imperative style: self.ffn(x).
        object.__setattr__(self, name, child)
        return child

    # --- context plumbing (private: not auto-wrapped) ------------------------

    @property
    def _ctx(self) -> InvocationContext:
        ctx = current_context()
        if ctx is None or ctx.module is not self:
            raise RuntimeError(
                f"{self.path}: no active InvocationContext for this module."
            )
        return ctx

    @property
    def state(self) -> Any:
        return self._ctx.state

    @property
    def is_training(self) -> bool:
        return self._ctx.is_training

    @property
    def prng_key(self) -> jax.Array:
        key = self._ctx.prng_key
        if key is None:
            raise RuntimeError(f"{self.path}: no PRNG key available (inference mode?).")
        return key

    def parameters(self) -> Any:
        """The module's parameter subtree from the active context."""
        return self._ctx.state

    def add_summary(self, name: str, value: Any):
        self._ctx.add_summary(name, value)

    def add_module_output(self, name: str, value: Any):
        self._ctx.add_module_output(name, value)

    def add_state_update(self, name: str, value: Any):
        self._ctx.add_state_update(name, value)

    # --- default interface ----------------------------------------------------

    def forward(self, *args, **kwargs):
        raise NotImplementedError(type(self))

    def __call__(self, *args, **kwargs):
        ctx = current_context()
        if ctx is None:
            raise RuntimeError(
                f"Calling {type(self).__name__} outside an InvocationContext; "
                "use repro.core.module.functional()."
            )
        if ctx.module is self:
            return type(self).forward._original(self, *args, **kwargs) if hasattr(
                type(self).forward, "_original"
            ) else type(self).forward(self, *args, **kwargs)
        with ctx.child(self):
            fwd = type(self).forward
            fwd = getattr(fwd, "_original", fwd)
            return fwd(self, *args, **kwargs)

    def __repr__(self):
        return f"{type(self).__name__}({self.path})"


# Bind the base config to the base module class.
Module.Config._module_cls = Module


def functional(
    module: Module,
    *,
    state: Any,
    inputs: Union[Tuple, Dict[str, Any]],
    prng_key: Optional[jax.Array] = None,
    is_training: bool = False,
    method: str = "forward",
) -> Tuple[Any, OutputCollection]:
    """Purely-functional invocation of a module method (AXLearn's ``F``).

    Returns ``(outputs, output_collection)``. Safe to wrap in jit/grad.
    """
    collection = new_output_collection()
    ctx = InvocationContext(
        module=module,
        state=state,
        path="",
        is_training=is_training,
        prng_key=prng_key,
        output_collection=collection,
    )
    fn = getattr(type(module), method)
    fn = getattr(fn, "_original", fn)
    with ctx:
        if isinstance(inputs, dict):
            outputs = fn(module, **inputs)
        else:
            outputs = fn(module, *inputs)
    return outputs, collection
