"""Paged KV-cache management: page allocator + host-side cache surgery.

KV memory is a shared pool of fixed-size pages per attention layer (see
``MultiheadAttention.Config.kv_cache_layout = "paged"``); sequences hold
*page tables* instead of dense ``slots x max_len`` rows. This module owns
the host-side resource management:

  * :class:`BlockAllocator` — a refcounted free-list over physical page
    ids with double-free/double-decref/leak guards. One allocator serves
    every layer: each layer has its own pool of identical geometry, so a
    single page id names the same page in all of them. Refcounts are what
    make shared-prefix pages safe: every sequence mapping a shared page
    holds one reference, and the page returns to the free list only when
    the last holder lets go.
  * :class:`PrefixIndex` — a hash-addressed index of immutable, fully
    written KV pages keyed by the page-granular rolling hash of the token
    chain they cache. Admission consults it to map a new sequence's page
    table directly onto already-prefilled pages (skipping prefill);
    divergence mid-page is resolved by copy-on-write.
  * :class:`PagedCacheManager` — structure-aware surgery on the engine's
    (otherwise opaque) cache pytree: writing page-table rows, clearing
    recycled pages, copy-on-write page forks, extracting a sequence's
    pages + per-slot rows to host memory (eviction), and re-splicing them
    into freshly allocated pages (restore) — no re-prefill.

Leaf-name contract (how an opaque pytree becomes pageable): attention's
paged cache exposes ``k_pool``/``v_pool`` (page axis at ``ndim-4``),
``pos_pool`` (page axis at ``ndim-2``) and ``page_table`` (batch axis at
``ndim-2``); any leading axes (e.g. ``Repeat``'s stacked-layer axis) are
carried transparently. Everything else (dense KV rows, Mamba/RWKV
recurrent state) is handled purely through its batch axis — recurrent
mixers keep their O(1) state and bypass paging entirely.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BlockAllocator", "PagedCacheManager", "PrefixIndex"]

# Page axis of a pool leaf, keyed by leaf name, expressed as trailing rank:
# k_pool/v_pool are (..., P, page, Hkv, D) -> page axis at ndim-4;
# pos_pool is (..., P, page) -> ndim-2; scale_pool (quantized KV: one fp32
# scale per token slot per k|v) is (..., P, page, 2) -> ndim-3. Because the
# scale rows share the physical-page axis, every page op below — COW
# forks, evict/restore, prefix sharing — moves them atomically with the
# KV payload by construction.
_POOL_PAGE_AXIS = {"k_pool": -4, "v_pool": -4, "pos_pool": -2,
                   "scale_pool": -3}
NULL_PAGE = 0  # reserved: unmapped table entries clamp here on reads


class BlockAllocator:
    """Refcounted free-list allocator over physical KV pages.

    Page 0 (the null page) is reserved and never handed out. ``alloc``
    returns ``None`` (rather than raising) when the pool cannot satisfy the
    request — the scheduler turns that into preemption, not failure.

    Shared-prefix pages are refcounted: ``alloc`` hands pages out at
    refcount 1, each additional sharer ``incref``s, and ``decref`` drops
    one reference, returning the page to the free list only when the last
    holder releases it. A freed page keeps its contents (the prefix index
    may still name it for future cache hits); ``revive`` pulls such a
    cached-free page back off the free list when a new sequence matches
    its content. Consumers must treat *reallocated* pages as garbage —
    the serving scheduler resets a page's positions at allocation time
    and drops any prefix-index entry naming it.
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(f"need >= 2 pages (1 usable), got {num_pages}")
        self._free: List[int] = list(range(num_pages - 1, 0, -1))  # pop() -> 1
        self._ref: Dict[int, int] = {}
        self.num_pages = num_pages

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return len(self._ref)

    @property
    def capacity(self) -> int:
        """Allocatable pages (the null page is not)."""
        return self.num_pages - 1

    def refcount(self, page: int) -> int:
        """Live references on a page (0 = free or never allocated)."""
        return self._ref.get(page, 0)

    def alloc(self, n: int) -> Optional[List[int]]:
        """n fresh page ids at refcount 1, or None if fewer than n are
        free."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._ref[p] = 1
        return pages

    def incref(self, page: int):
        """Add a sharer to an in-use page."""
        if page not in self._ref:
            raise ValueError(f"incref of unallocated page {page}")
        self._ref[page] += 1

    def revive(self, page: int):
        """Reclaim a cached-free page — one whose last holder released it
        but whose contents a prefix-index hit wants back — from the free
        list, at refcount 1."""
        if page in self._ref:
            raise ValueError(f"revive of in-use page {page}; incref instead")
        try:
            self._free.remove(page)
        except ValueError:
            raise ValueError(f"revive of page {page} not on the free list")
        self._ref[page] = 1

    def decref(self, page: int) -> bool:
        """Drop one reference; returns True iff this freed the page. Raises
        on a page with no live references — the double-decref guard."""
        r = self._ref.get(page)
        if r is None:
            raise ValueError(f"decref of unallocated page {page}")
        if r > 1:
            self._ref[page] = r - 1
            return False
        del self._ref[page]
        self._free.append(page)
        return True

    def decref_all(self, pages: List[int]) -> List[int]:
        """decref each page; returns the subset actually freed."""
        return [p for p in pages if self.decref(p)]

    def free(self, pages: List[int]):
        """Hard-free exclusively owned pages. Raises on double-free, on a
        page this allocator never handed out, or on a *shared* page
        (refcount > 1) — freeing a page out from under its other sharers
        is exactly the bug the guard exists for; use ``decref``."""
        for p in pages:
            r = self._ref.get(p)
            if r is None:
                raise ValueError(f"free of unallocated page {p}")
            if r > 1:
                raise ValueError(
                    f"free of shared page {p} (refcount {r}); use decref")
        for p in pages:
            self.decref(p)


_ROOT_HASH = 0x9E3779B9  # chain hash of the empty prefix
_HASH_MOD = (1 << 61) - 1


def _chain_hash(parent: int, tokens: Tuple[int, ...]) -> int:
    """Page-granular rolling hash: fold one page of token ids into the
    parent chain's hash. Collisions are tolerable — every index hit is
    confirmed against the stored token ids before any page is shared."""
    h = parent
    for t in tokens:
        h = (h * 1000003 + 2654435761 * (int(t) + 1)) % _HASH_MOD
    return h


@dataclasses.dataclass
class _PrefixEntry:
    page: int  # physical page caching this chain's last page of tokens
    parent: int  # chain hash of the preceding pages (_ROOT_HASH at depth 0)
    tokens: Tuple[int, ...]  # this page's token ids — the exact-match guard


class PrefixIndex:
    """Hash-addressed index of immutable, fully written KV pages.

    Each entry maps the rolling chain hash of ``pages[0..i]`` of some
    sequence's prompt to the physical page caching page ``i``. A new
    prompt walks its own chain through the index: every hit is a page of
    prefill it can skip by mapping the existing page (shared, refcounted);
    the first miss may still be a *partial* match — a published page whose
    tokens share a proper prefix with the prompt's next page — which the
    scheduler resolves by copy-on-write.

    The index never owns pages. The scheduler increfs/revives matched
    pages through the allocator, and must call :meth:`forget_pages`
    whenever pages are (re)allocated fresh — reallocation invalidates
    whatever chain a page used to cache.
    """

    def __init__(self, page_size: int):
        self.page_size = int(page_size)
        self._by_hash: Dict[int, _PrefixEntry] = {}
        self._by_page: Dict[int, int] = {}  # physical page -> chain hash
        self._children: Dict[int, set] = {}  # parent hash -> chain hashes

    def __len__(self) -> int:
        return len(self._by_hash)

    def publish(self, parent: int, tokens: Tuple[int, ...], page: int) -> int:
        """Register ``page`` as caching ``tokens`` at the end of chain
        ``parent``; returns the extended chain hash. First publisher wins:
        an existing entry for the same chain is kept (its page is the one
        other sequences may already share)."""
        if len(tokens) != self.page_size:
            raise ValueError(f"publish of a non-full page ({len(tokens)} "
                             f"tokens, page_size {self.page_size})")
        h = _chain_hash(parent, tokens)
        if h not in self._by_hash:
            self._by_hash[h] = _PrefixEntry(page, parent,
                                            tuple(int(t) for t in tokens))
            self._by_page[page] = h
            self._children.setdefault(parent, set()).add(h)
        return h

    def match(self, prompt: np.ndarray) -> Tuple[List[int], int,
                                                 Optional[Tuple[int, int]]]:
        """Longest cached chain covering a proper prefix of ``prompt``.

        At most ``len(prompt) - 1`` tokens match — the final prompt token
        must always go through prefill so its next-token logits exist.
        Returns ``(full_pages, chain_hash, partial)`` where ``full_pages``
        are physical pages caching whole prompt pages, ``chain_hash`` is
        the chain after them (the publish cursor for the matching
        sequence), and ``partial`` is an optional ``(donor_page, j)``:
        a published page whose first ``j`` tokens extend the match, to be
        copy-on-write forked by the caller.
        """
        ps = self.page_size
        limit = len(prompt) - 1
        pages: List[int] = []
        h = _ROOT_HASH
        m = 0
        while (m + 1) * ps <= limit:
            toks = tuple(int(t) for t in prompt[m * ps:(m + 1) * ps])
            e = self._by_hash.get(_chain_hash(h, toks))
            if e is None or e.parent != h or e.tokens != toks:
                break
            pages.append(e.page)
            h = _chain_hash(h, toks)
            m += 1
        # Partial match: among published children of the matched chain,
        # the page sharing the longest proper token-prefix with the
        # prompt's next page.
        rest = [int(t) for t in prompt[m * ps:limit]]
        best: Optional[Tuple[int, int]] = None
        if rest:
            for ch in self._children.get(h, ()):
                e = self._by_hash.get(ch)
                if e is None:
                    continue
                j = 0
                for a, b in zip(e.tokens, rest):
                    if a != b:
                        break
                    j += 1
                if j >= 1 and (best is None or j > best[1]):
                    best = (e.page, j)
        return pages, h, best

    def forget_pages(self, pages: List[int]):
        """Drop any entries naming these physical pages (they were just
        reallocated — their cached content is about to be overwritten)."""
        for p in pages:
            h = self._by_page.pop(p, None)
            if h is None:
                continue
            e = self._by_hash.pop(h, None)
            if e is not None:
                kids = self._children.get(e.parent)
                if kids is not None:
                    kids.discard(h)
                    if not kids:
                        del self._children[e.parent]


@dataclasses.dataclass
class _LeafInfo:
    name: str  # last dict key on the leaf's path
    batch_axis: int  # -1 = shared leaf (no per-slot rows)
    page_axis: int  # -1 = not a pool leaf


class PagedCacheManager:
    """Host-side surgery on a (possibly paged) engine cache pytree.

    Built once from a template cache and the engine's per-leaf batch-axis
    map; every operation takes and returns a full cache pytree (leaves are
    device arrays; ops dispatch eagerly — these are rare control-plane
    events, not the decode hot path).
    """

    def __init__(self, template_cache: Any, batch_axes: Any):
        leaves, self._treedef = jax.tree_util.tree_flatten(template_cache)
        axes_leaves = jax.tree_util.tree_flatten(batch_axes)[0]
        paths = jax.tree_util.tree_flatten_with_path(template_cache)[0]
        self._info: List[_LeafInfo] = []
        self.page_size = self.num_pages = self.n_logical = None
        # Storage dtype of the KV pools (observability: surfaces quantized
        # caches in gateway metrics without any dtype branching here).
        self.pool_dtype: Optional[str] = None
        for (path, leaf), ax in zip(paths, axes_leaves):
            name = ""
            for entry in reversed(path):
                key = getattr(entry, "key", None)
                if isinstance(key, str):
                    name = key
                    break
            page_axis = -1
            if name in _POOL_PAGE_AXIS:
                page_axis = leaf.ndim + _POOL_PAGE_AXIS[name]
                if name == "pos_pool":
                    self.num_pages, self.page_size = leaf.shape[-2:]
                elif name == "k_pool":
                    self.pool_dtype = str(leaf.dtype)
            if name == "page_table":
                self.n_logical = leaf.shape[-1]
            self._info.append(_LeafInfo(name, int(ax), page_axis))

    @property
    def is_paged(self) -> bool:
        return self.num_pages is not None

    # ------------------------------------------------------------- helpers

    def _map(self, cache, fn):
        """fn(leaf, info) -> leaf over the flat cache."""
        leaves = jax.tree_util.tree_flatten(cache)[0]
        out = [fn(leaf, info) for leaf, info in zip(leaves, self._info)]
        return jax.tree_util.tree_unflatten(self._treedef, out)

    @staticmethod
    def _set_rows(leaf, axis, idx, vals):
        moved = jnp.moveaxis(leaf, axis, 0)
        return jnp.moveaxis(moved.at[idx].set(vals), 0, axis)

    # -------------------------------------------------------- page tables

    def write_table_row(self, cache, slot: int, row: np.ndarray):
        """Install a sequence's page table row (same ids in every layer)."""
        row = jnp.asarray(row, jnp.int32)

        def fn(leaf, info):
            if info.name != "page_table":
                return leaf
            return self._set_rows(leaf, leaf.ndim - 2, slot, row)

        return self._map(cache, fn)

    def clear_tables(self, cache):
        """Unmap every sequence (allocator-managed mode: init_states may
        have installed full-residency identity tables)."""
        def fn(leaf, info):
            if info.name != "page_table":
                return leaf
            return jnp.full_like(leaf, -1)

        return self._map(cache, fn)

    def reset_pages(self, cache, pages: List[int]):
        """Invalidate recycled pages' positions so a later partial fill
        can't expose a previous tenant's tokens to the mask."""
        if not pages:
            return cache
        idx = jnp.asarray(pages, jnp.int32)

        def fn(leaf, info):
            if info.name != "pos_pool":
                return leaf
            return self._set_rows(leaf, info.page_axis, idx, -1)

        return self._map(cache, fn)

    def set_index(self, cache, slot: int, value: int):
        """Set one slot's decode position counter (``index`` leaves) — a
        sequence admitted onto matched prefix pages starts mid-stream."""
        def fn(leaf, info):
            if info.name != "index":
                return leaf
            return self._set_rows(leaf, info.batch_axis, slot,
                                  jnp.asarray(value, leaf.dtype))

        return self._map(cache, fn)

    def copy_page(self, cache, src: int, dst: int, valid: int):
        """Copy-on-write fork: duplicate physical page ``src`` into ``dst``
        keeping only the first ``valid`` token slots' positions — the
        shared prefix. The rest are invalidated so the donor's later
        tokens can never leak into the borrower's attention mask."""
        def fn(leaf, info):
            if info.page_axis < 0:
                return leaf
            moved = jnp.moveaxis(leaf, info.page_axis, 0)
            row = moved[src]
            if info.name == "pos_pool":
                keep = jnp.arange(row.shape[-1]) < valid
                row = jnp.where(keep, row, -1)
            return jnp.moveaxis(moved.at[dst].set(row), 0, info.page_axis)

        return self._map(cache, fn)

    # ---------------------------------------------------- evict / restore

    def extract_slot(self, cache, slot: int) -> List[Optional[np.ndarray]]:
        """Host copy of one sequence's per-slot rows (recurrent state, dense
        KV rows, index — everything with a batch axis except the page
        table, which the allocator rebuilds on restore)."""
        leaves = jax.tree_util.tree_flatten(cache)[0]
        out = []
        for leaf, info in zip(leaves, self._info):
            if info.batch_axis < 0 or info.name == "page_table":
                out.append(None)
            else:
                out.append(np.asarray(jnp.take(leaf, slot,
                                               axis=info.batch_axis)))
        return out

    def splice_slot(self, cache, slot: int, rows: List[Optional[np.ndarray]]):
        def fn_pair():
            leaves = jax.tree_util.tree_flatten(cache)[0]
            out = []
            for leaf, info, row in zip(leaves, self._info, rows):
                if row is None:
                    out.append(leaf)
                else:
                    out.append(self._set_rows(leaf, info.batch_axis, slot,
                                              jnp.asarray(row)))
            return out

        return jax.tree_util.tree_unflatten(self._treedef, fn_pair())

    def extract_pages(self, cache, pages: List[int]) -> List[Optional[np.ndarray]]:
        """Host copy of the given physical pages from every pool leaf —
        the KV payload of an evicted sequence."""
        idx = jnp.asarray(pages, jnp.int32)
        leaves = jax.tree_util.tree_flatten(cache)[0]
        out = []
        for leaf, info in zip(leaves, self._info):
            if info.page_axis < 0:
                out.append(None)
            else:
                out.append(np.asarray(jnp.take(leaf, idx, axis=info.page_axis)))
        return out

    def insert_pages(self, cache, pages: List[int],
                     payload: List[Optional[np.ndarray]]):
        """Write evicted page contents into freshly allocated pages —
        restore is a re-splice, not a re-prefill."""
        idx = jnp.asarray(pages, jnp.int32)
        leaves = jax.tree_util.tree_flatten(cache)[0]
        out = []
        for leaf, info, content in zip(leaves, self._info, payload):
            if content is None:
                out.append(leaf)
            else:
                moved = jnp.moveaxis(leaf, info.page_axis, 0)
                vals = jnp.moveaxis(jnp.asarray(content), info.page_axis, 0)
                out.append(jnp.moveaxis(moved.at[idx].set(vals), 0,
                                        info.page_axis))
        return jax.tree_util.tree_unflatten(self._treedef, out)
