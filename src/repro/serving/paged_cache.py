"""Paged KV-cache management: page allocator + host-side cache surgery.

KV memory is a shared pool of fixed-size pages per attention layer (see
``MultiheadAttention.Config.kv_cache_layout = "paged"``); sequences hold
*page tables* instead of dense ``slots x max_len`` rows. This module owns
the host-side resource management:

  * :class:`BlockAllocator` — a free-list over physical page ids with
    double-free/leak guards. One allocator serves every layer: each layer
    has its own pool of identical geometry, so a single page id names the
    same page in all of them.
  * :class:`PagedCacheManager` — structure-aware surgery on the engine's
    (otherwise opaque) cache pytree: writing page-table rows, clearing
    recycled pages, extracting a sequence's pages + per-slot rows to host
    memory (eviction), and re-splicing them into freshly allocated pages
    (restore) — no re-prefill.

Leaf-name contract (how an opaque pytree becomes pageable): attention's
paged cache exposes ``k_pool``/``v_pool`` (page axis at ``ndim-4``),
``pos_pool`` (page axis at ``ndim-2``) and ``page_table`` (batch axis at
``ndim-2``); any leading axes (e.g. ``Repeat``'s stacked-layer axis) are
carried transparently. Everything else (dense KV rows, Mamba/RWKV
recurrent state) is handled purely through its batch axis — recurrent
mixers keep their O(1) state and bypass paging entirely.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BlockAllocator", "PagedCacheManager"]

# Page axis of a pool leaf, keyed by leaf name, expressed as trailing rank:
# k_pool/v_pool are (..., P, page, Hkv, D) -> page axis at ndim-4;
# pos_pool is (..., P, page) -> ndim-2.
_POOL_PAGE_AXIS = {"k_pool": -4, "v_pool": -4, "pos_pool": -2}
NULL_PAGE = 0  # reserved: unmapped table entries clamp here on reads


class BlockAllocator:
    """Free-list allocator over physical KV pages.

    Page 0 (the null page) is reserved and never handed out. ``alloc``
    returns ``None`` (rather than raising) when the pool cannot satisfy the
    request — the scheduler turns that into preemption, not failure.
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(f"need >= 2 pages (1 usable), got {num_pages}")
        self._free: List[int] = list(range(num_pages - 1, 0, -1))  # pop() -> 1
        self._in_use: set = set()
        self.num_pages = num_pages

    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_in_use(self) -> int:
        return len(self._in_use)

    @property
    def capacity(self) -> int:
        """Allocatable pages (the null page is not)."""
        return self.num_pages - 1

    def alloc(self, n: int) -> Optional[List[int]]:
        """n fresh page ids, or None if fewer than n are free."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._in_use.update(pages)
        return pages

    def free(self, pages: List[int]):
        """Return pages to the free list. Raises on double-free or on a page
        this allocator never handed out — the invariant the churn test
        leans on."""
        for p in pages:
            if p not in self._in_use:
                raise ValueError(f"free of unallocated page {p}")
            self._in_use.remove(p)
            self._free.append(p)


@dataclasses.dataclass
class _LeafInfo:
    name: str  # last dict key on the leaf's path
    batch_axis: int  # -1 = shared leaf (no per-slot rows)
    page_axis: int  # -1 = not a pool leaf


class PagedCacheManager:
    """Host-side surgery on a (possibly paged) engine cache pytree.

    Built once from a template cache and the engine's per-leaf batch-axis
    map; every operation takes and returns a full cache pytree (leaves are
    device arrays; ops dispatch eagerly — these are rare control-plane
    events, not the decode hot path).
    """

    def __init__(self, template_cache: Any, batch_axes: Any):
        leaves, self._treedef = jax.tree_util.tree_flatten(template_cache)
        axes_leaves = jax.tree_util.tree_flatten(batch_axes)[0]
        paths = jax.tree_util.tree_flatten_with_path(template_cache)[0]
        self._info: List[_LeafInfo] = []
        self.page_size = self.num_pages = self.n_logical = None
        for (path, leaf), ax in zip(paths, axes_leaves):
            name = ""
            for entry in reversed(path):
                key = getattr(entry, "key", None)
                if isinstance(key, str):
                    name = key
                    break
            page_axis = -1
            if name in _POOL_PAGE_AXIS:
                page_axis = leaf.ndim + _POOL_PAGE_AXIS[name]
                if name == "pos_pool":
                    self.num_pages, self.page_size = leaf.shape[-2:]
            if name == "page_table":
                self.n_logical = leaf.shape[-1]
            self._info.append(_LeafInfo(name, int(ax), page_axis))

    @property
    def is_paged(self) -> bool:
        return self.num_pages is not None

    # ------------------------------------------------------------- helpers

    def _map(self, cache, fn):
        """fn(leaf, info) -> leaf over the flat cache."""
        leaves = jax.tree_util.tree_flatten(cache)[0]
        out = [fn(leaf, info) for leaf, info in zip(leaves, self._info)]
        return jax.tree_util.tree_unflatten(self._treedef, out)

    @staticmethod
    def _set_rows(leaf, axis, idx, vals):
        moved = jnp.moveaxis(leaf, axis, 0)
        return jnp.moveaxis(moved.at[idx].set(vals), 0, axis)

    # -------------------------------------------------------- page tables

    def write_table_row(self, cache, slot: int, row: np.ndarray):
        """Install a sequence's page table row (same ids in every layer)."""
        row = jnp.asarray(row, jnp.int32)

        def fn(leaf, info):
            if info.name != "page_table":
                return leaf
            return self._set_rows(leaf, leaf.ndim - 2, slot, row)

        return self._map(cache, fn)

    def clear_tables(self, cache):
        """Unmap every sequence (allocator-managed mode: init_states may
        have installed full-residency identity tables)."""
        def fn(leaf, info):
            if info.name != "page_table":
                return leaf
            return jnp.full_like(leaf, -1)

        return self._map(cache, fn)

    def reset_pages(self, cache, pages: List[int]):
        """Invalidate recycled pages' positions so a later partial fill
        can't expose a previous tenant's tokens to the mask."""
        if not pages:
            return cache
        idx = jnp.asarray(pages, jnp.int32)

        def fn(leaf, info):
            if info.name != "pos_pool":
                return leaf
            return self._set_rows(leaf, info.page_axis, idx, -1)

        return self._map(cache, fn)

    # ---------------------------------------------------- evict / restore

    def extract_slot(self, cache, slot: int) -> List[Optional[np.ndarray]]:
        """Host copy of one sequence's per-slot rows (recurrent state, dense
        KV rows, index — everything with a batch axis except the page
        table, which the allocator rebuilds on restore)."""
        leaves = jax.tree_util.tree_flatten(cache)[0]
        out = []
        for leaf, info in zip(leaves, self._info):
            if info.batch_axis < 0 or info.name == "page_table":
                out.append(None)
            else:
                out.append(np.asarray(jnp.take(leaf, slot,
                                               axis=info.batch_axis)))
        return out

    def splice_slot(self, cache, slot: int, rows: List[Optional[np.ndarray]]):
        def fn_pair():
            leaves = jax.tree_util.tree_flatten(cache)[0]
            out = []
            for leaf, info, row in zip(leaves, self._info, rows):
                if row is None:
                    out.append(leaf)
                else:
                    out.append(self._set_rows(leaf, info.batch_axis, slot,
                                              jnp.asarray(row)))
            return out

        return jax.tree_util.tree_unflatten(self._treedef, fn_pair())

    def extract_pages(self, cache, pages: List[int]) -> List[Optional[np.ndarray]]:
        """Host copy of the given physical pages from every pool leaf —
        the KV payload of an evicted sequence."""
        idx = jnp.asarray(pages, jnp.int32)
        leaves = jax.tree_util.tree_flatten(cache)[0]
        out = []
        for leaf, info in zip(leaves, self._info):
            if info.page_axis < 0:
                out.append(None)
            else:
                out.append(np.asarray(jnp.take(leaf, idx, axis=info.page_axis)))
        return out

    def insert_pages(self, cache, pages: List[int],
                     payload: List[Optional[np.ndarray]]):
        """Write evicted page contents into freshly allocated pages —
        restore is a re-splice, not a re-prefill."""
        idx = jnp.asarray(pages, jnp.int32)
        leaves = jax.tree_util.tree_flatten(cache)[0]
        out = []
        for leaf, info, content in zip(leaves, self._info, payload):
            if content is None:
                out.append(leaf)
            else:
                moved = jnp.moveaxis(leaf, info.page_axis, 0)
                vals = jnp.moveaxis(jnp.asarray(content), info.page_axis, 0)
                out.append(jnp.moveaxis(moved.at[idx].set(vals), 0,
                                        info.page_axis))
        return jax.tree_util.tree_unflatten(self._treedef, out)
