"""Serving subsystem: paged KV-cache allocation, iteration-level scheduling,
and a streaming gateway — layered on :class:`repro.inference.InferenceEngine`.

The paper's modularity thesis (§4.2, §6) applied to serving: the KV cache
stays an encapsulated component of each token mixer (``kv_cache_layout``
is a *config knob* on attention), and this package adds the resource
management above it — the way Orca-style iteration-level scheduling and
vLLM-style paging decouple serving throughput from model code.

  * :mod:`repro.serving.paged_cache` — refcounted page pool allocator,
    the shared-prefix :class:`PrefixIndex`, and host-side manipulation of
    paged cache pytrees (page tables, copy-on-write forks, eviction to
    host memory, restore by re-splicing pages).
  * :mod:`repro.serving.scheduler` — the iteration-level loop: priority
    admission with prefix-cache reuse, chunked prefill interleaved with
    decode, self-speculative draft-verify, preemption when pages run out.
  * :mod:`repro.serving.draft` — the n-gram draft proposer feeding the
    scheduler's speculative verify step.
  * :mod:`repro.serving.gateway` — non-blocking ``submit()/stream()`` API
    with per-request sampling params, token callbacks, and telemetry.
"""

from repro.serving.draft import NgramProposer
from repro.serving.gateway import SamplingParams, ServingGateway
from repro.serving.paged_cache import (BlockAllocator, PagedCacheManager,
                                       PrefixIndex)
from repro.serving.scheduler import ServeRequest, Scheduler

__all__ = [
    "BlockAllocator",
    "NgramProposer",
    "PagedCacheManager",
    "PrefixIndex",
    "SamplingParams",
    "Scheduler",
    "ServeRequest",
    "ServingGateway",
]
