"""Self-speculative n-gram draft proposer ("prompt lookup" decoding).

Drafting costs nothing but a dict lookup: language repeats itself, so the
continuation of the current suffix n-gram has often already appeared in
the sequence's own history (prompt + generated tokens). The proposer
remembers, for every n-gram up to ``max_n``, where it last occurred — and
the occurrence before that, so the current suffix never matches itself —
and proposes the k tokens that followed the most recent prior occurrence.
The scheduler verifies the whole draft in one multi-token dispatch;
wrong drafts cost one wasted lane in a step that ran anyway, so even
modest hit rates are pure TPOT profit.

Updates are O(max_n) dict writes per token; proposing is O(max_n)
lookups, longest n-gram first (longer context = higher acceptance).
"""

from __future__ import annotations

from typing import Dict, List, Tuple

__all__ = ["NgramProposer"]


class NgramProposer:
    """Per-sequence draft proposer over the sequence's own token history."""

    def __init__(self, max_n: int = 3):
        if max_n < 1:
            raise ValueError(f"max_n must be >= 1, got {max_n}")
        self.max_n = max_n
        self._hist: List[int] = []
        # Per n-gram length: end position (index just past the gram) of its
        # latest occurrence, and of the occurrence before that. Two levels
        # are enough: a proposal only ever needs the latest occurrence
        # *strictly before* the suffix itself.
        self._last: List[Dict[Tuple[int, ...], int]] = [
            {} for _ in range(max_n + 1)]
        self._prev: List[Dict[Tuple[int, ...], int]] = [
            {} for _ in range(max_n + 1)]

    def __len__(self) -> int:
        return len(self._hist)

    def extend(self, tokens):
        """Fold new history (prompt at submit, each token as emitted)."""
        for t in tokens:
            self._hist.append(int(t))
            end = len(self._hist)
            for n in range(1, self.max_n + 1):
                if n > end:
                    break
                g = tuple(self._hist[end - n:])
                old = self._last[n].get(g)
                if old is not None:
                    self._prev[n][g] = old
                self._last[n][g] = end

    def propose(self, k: int) -> List[int]:
        """Up to ``k`` draft tokens continuing the current suffix from its
        most recent prior occurrence; empty when history never repeats."""
        if k < 1:
            return []
        hist = self._hist
        end = len(hist)
        for n in range(min(self.max_n, end), 0, -1):
            g = tuple(hist[end - n:])
            pos = self._last[n].get(g)
            if pos == end:  # the suffix itself — use the occurrence before
                pos = self._prev[n].get(g)
            if pos is None or pos >= end:
                continue
            return hist[pos:pos + k]
        return []
