"""Streaming serving gateway: non-blocking submit/stream over the scheduler.

The front door of the serving subsystem: callers ``submit()`` prompts with
per-request :class:`SamplingParams` and a priority, then either iterate
``stream(request_id)`` for tokens as they are generated, register an
``on_token`` callback, or ``drain()`` to completion. The gateway is
single-threaded and cooperative — ``stream()``/``drain()`` advance the
scheduler's iteration loop themselves, so there is no background thread to
synchronize with (and no GIL fight with the JAX dispatch thread); a caller
that wants push-style delivery gets it via callbacks fired on every
generated token.

Telemetry (:meth:`metrics`) reports queue depth, KV page utilization,
completed/preempted counts, output tokens/s, and p50/p99 TTFT and TPOT —
the Table-4 metrics at serving granularity. Latency percentiles come from
the observability registry's bounded reservoirs (recorded once per request
at completion), so gateway memory stays O(reservoir + in-flight), not
O(requests served): finished results past the scheduler's retention cap
are retired together with their token queues.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

from repro.inference.engine import GenerationResult, InferenceEngine
from repro.observability.metrics import MetricsRegistry
from repro.observability.runtime import Observability
from repro.serving.scheduler import Scheduler, ServeRequest

__all__ = ["SamplingParams", "ServingGateway"]


@dataclasses.dataclass
class SamplingParams:
    """Per-request decode controls, threaded as per-slot arrays into the
    fused decode step (temperature <= 0 = exact greedy; top_k <= 0 = no
    top-k filtering)."""

    max_new_tokens: int = 16
    temperature: float = 0.0
    top_k: int = 0


class ServingGateway:
    """Non-blocking request gateway over a loaded :class:`InferenceEngine`."""

    def __init__(self, engine: InferenceEngine, *, prefill_chunk: int = 16,
                 seed: int = 0,
                 observability: Optional[Observability] = None,
                 max_done_results: int = 4096,
                 prefix_caching: bool = True, spec_k: int = 4,
                 spec_ngram: int = 3):
        # The gateway always has a registry (its latency reservoirs need
        # one); passing an Observability bundle additionally routes the
        # metrics into its sinks and arms request-lifecycle tracing.
        self.observability = observability
        self.registry: MetricsRegistry = (
            observability.registry if observability is not None
            else MetricsRegistry())
        self.scheduler = Scheduler(
            engine, prefill_chunk=prefill_chunk, seed=seed,
            registry=self.registry,
            tracer=observability.tracer if observability is not None else None,
            max_done_results=max_done_results, on_retire=self._retire,
            prefix_caching=prefix_caching, spec_k=spec_k,
            spec_ngram=spec_ngram)
        self._next_id = 0
        self._queues: Dict[int, deque] = {}
        self._t0 = time.perf_counter()
        self._tokens_out = 0

    def _retire(self, request_id: int):
        """Scheduler evicted this finished result (retention cap): drop the
        matching token queue so gateway state stays bounded too."""
        self._queues.pop(request_id, None)

    # ------------------------------------------------------------ lifecycle

    def submit(self, prompt: np.ndarray, *,
               sampling: Optional[SamplingParams] = None, priority: int = 0,
               deadline_s: Optional[float] = None,
               on_token: Optional[Callable[[int, int], None]] = None) -> int:
        """Enqueue a prompt; returns immediately with the request id. No
        device work happens until :meth:`step`/:meth:`stream`/:meth:`drain`
        advances the scheduler.

        ``deadline_s`` is a wall-clock latency SLO from submit: a request
        still unfinished when it expires is cancelled (pages freed through
        the normal preemption/teardown path) and resolves to a
        ``timed_out=True`` result with whatever tokens it produced —
        ``stream()``/``drain()`` terminate instead of hanging on it."""
        sampling = sampling or SamplingParams()
        rid = self._next_id
        self._next_id += 1
        q: deque = deque()
        self._queues[rid] = q

        def hook(req_id: int, tok: int):
            q.append(tok)
            self._tokens_out += 1
            if on_token is not None:
                on_token(req_id, tok)

        self.scheduler.submit(ServeRequest(
            request_id=rid, prompt=np.asarray(prompt, np.int32),
            max_new_tokens=sampling.max_new_tokens,
            temperature=sampling.temperature, top_k=sampling.top_k,
            priority=priority, arrival_time=time.perf_counter(),
            deadline_s=deadline_s, on_token=hook))
        return rid

    def step(self) -> bool:
        """One scheduler iteration; returns whether work remains."""
        return self.scheduler.step()

    def stream(self, request_id: int) -> Iterator[int]:
        """Yield the request's tokens as they are generated, driving the
        scheduler while the request is still in flight. Concurrent requests
        make progress on the same iterations — streaming one request never
        starves the rest."""
        q = self._queues[request_id]
        while True:
            if q:
                yield q.popleft()
            elif self.scheduler.is_done(request_id):
                return
            elif not self.scheduler.step():
                while q:
                    yield q.popleft()
                return

    def drain(self) -> Dict[int, GenerationResult]:
        """Run the scheduler to idle; returns results for every request
        completed so far, keyed by request id. With every result retired,
        no KV page may still hold a reference — a nonzero count means a
        refcount bug (a leak, or a missed decref on a shared prefix page),
        so the drain fails loudly rather than serving on a shrinking pool."""
        while self.scheduler.step():
            pass
        alloc = self.scheduler.allocator
        if alloc is not None and alloc.num_in_use != 0:
            raise RuntimeError(
                f"KV page leak after drain: {alloc.num_in_use} pages still "
                f"referenced with no sequence in flight")
        return {rid: self.scheduler.result(rid)
                for rid in list(self._queues)
                if self.scheduler.is_done(rid)}

    def result(self, request_id: int) -> Optional[GenerationResult]:
        return self.scheduler.result(request_id)

    # ------------------------------------------------------------ telemetry

    def metrics(self) -> Dict[str, Any]:
        """Serving telemetry: queue/pool state plus latency percentiles from
        the registry's bounded reservoirs (timed-out requests never enter
        them — their "latency" is the deadline, not a service time)."""
        sched = self.scheduler
        wall = max(time.perf_counter() - self._t0, 1e-9)
        ttft = self.registry.histogram("serving/ttft_s")
        tpot = self.registry.histogram("serving/tpot_s")
        return {
            "queue_depth": sched.queue_depth,
            "running": sum(s is not None for s in sched._slot_seq),
            "block_utilization": sched.block_utilization,
            "completed": sched.stats["completed"],
            "timeouts": sched.stats["timeouts"],
            "preemptions": sched.stats["preemptions"],
            "restores": sched.stats["restores"],
            "prefill_chunks": sched.stats["prefill_chunks"],
            "decode_steps": sched.stats["decode_steps"],
            "max_concurrent": sched.stats["max_concurrent"],
            "prefix_hit_rate": sched.stats["prefix_hits"] / max(
                sched.stats["prefix_hits"] + sched.stats["prefix_misses"], 1),
            "prefill_tokens_skipped": sched.stats["prefill_tokens_skipped"],
            "cow_forks": sched.stats["cow_forks"],
            "drafted_tokens": sched.stats["drafted_tokens"],
            "accepted_tokens": sched.stats["accepted_tokens"],
            "verify_steps": sched.stats["verify_steps"],
            # Tokens committed per verify dispatch: accepted drafts plus
            # the model's own token. > 1 means speculation is paying.
            "accepted_per_step": (
                (sched.stats["accepted_tokens"] + sched.stats["verify_steps"])
                / max(sched.stats["verify_steps"], 1)),
            "tokens_out": self._tokens_out,
            "tokens_per_s": self._tokens_out / wall,
            "ttft_p50_s": ttft.percentile(50),
            "ttft_p99_s": ttft.percentile(99),
            "tpot_p50_s": tpot.percentile(50),
            "tpot_p99_s": tpot.percentile(99),
        }
