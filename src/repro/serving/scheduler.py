"""Iteration-level serving scheduler: chunked prefill, paging, preemption.

Orca-style scheduling (paper §6) on top of ``InferenceEngine``: every
iteration interleaves at most one *prefill chunk* with one fused decode
step over all running slots, so a long prompt never stalls in-flight
decodes for more than the configured chunk budget.

Key mechanics:

  * **Chunked prefill = ``extend_step``.** A prompt is fed through the
    model's ``extend_step`` in chunks (S' > 1 decode steps mask causally
    among themselves), writing straight into the slot's cache — the same
    program decode uses, so no separate prefill graph. Chunk lengths are
    the greedy power-of-two decomposition of the prompt (each <= the chunk
    budget), which bounds compiled chunk shapes to O(log budget).
  * **Slot-view splicing.** A chunk runs on a B=1 *view* of the batch
    cache: per-slot leaves are sliced at the slot, shared leaves (the page
    pools) pass through whole; after the chunk, per-slot rows are spliced
    back and updated pools replace the originals. ``slot`` is a traced
    scalar — one compile per chunk length, not per slot.
  * **Paging + preemption.** With ``kv_cache_layout="paged"`` models, KV
    pages are allocated on demand (admission, per prefill chunk, and at
    page boundaries during decode). When the pool runs dry the
    lowest-priority sequence is *evicted to host memory* (its pages and
    per-slot rows — not its tokens) and later *restored by re-splicing*
    into freshly allocated pages: no re-prefill, the way SageMaker-MP
    argues resource management should live in the framework, not the model.
  * **Per-slot sampling.** The fused decode step threads per-slot
    temperature/top-k arrays and a PRNG key, so mixed greedy/sampled
    requests batch together (greedy rows are exact argmax).
  * **Shared-prefix caching.** On fully paged models, admission consults
    a :class:`PrefixIndex` of published (immutable, fully written) prompt
    pages: matched pages are increfed and mapped straight into the new
    sequence's page table, so a repeated system prompt skips prefill
    entirely. Divergence mid-page copy-on-write forks the partially
    matched page *at admission* — before any fused step could write into
    it — keeping shared pages strictly read-only. Evict, finish, and
    deadline expiry decref (never hard-free), so one sharer's teardown
    can't strand the others; pages decrefed to zero stay content-intact
    on the free list and are revived on the next hit.
  * **Self-speculative decoding.** Greedy sequences draft k tokens from
    their own history (:class:`~repro.serving.draft.NgramProposer`); a
    *batched verify* — one multi-token ``extend_step`` over ALL slots,
    (S, k+1) — replaces the single-token decode whenever any slot has a
    draft. Drafting rows commit their accepted prefix plus the model's
    correction; sampled and draft-less rows ride the same dispatch
    committing their usual one token, so speculation adds zero extra
    dispatches per iteration. Rejected tails roll back by rewinding the
    position counter (rejected KV entries self-heal: every position is
    rewritten before any later query may attend to it). Output is
    token-for-token identical to plain greedy.

The scheduler is layout-agnostic: dense-cache models (and recurrent
mixers, whose O(1) state bypasses paging entirely) run through the same
loop with page logic inert. Speculation gates itself off for recurrent
state (which cannot roll back) and clamps its verify window so it can
never wrap a dense or sliding-window KV ring; prefix caching requires a
fully paged cache.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.module import functional
from repro.inference.engine import (GenerationResult, InferenceEngine,
                                    greedy_verify, sample_tokens)
from repro.observability.metrics import MetricsRegistry
from repro.observability.tracing import Tracer
from repro.serving.draft import NgramProposer
from repro.serving.paged_cache import (BlockAllocator, PagedCacheManager,
                                       PrefixIndex)

__all__ = ["ServeRequest", "Scheduler"]


@dataclasses.dataclass
class ServeRequest:
    """A serving request. ``priority``: higher preempts lower; FCFS within a
    priority level. ``on_token`` fires on the scheduler thread for every
    generated token (the gateway's streaming hook)."""

    request_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    top_k: int = 0  # 0 = no top-k filtering
    priority: int = 0
    arrival_time: float = 0.0
    on_token: Optional[Callable[[int, int], None]] = None
    # Wall-clock budget from submit. A request that has not COMPLETED
    # within deadline_s — still queued, still prefilling, or mid-decode —
    # is cancelled at the next iteration: its pages free through the
    # normal teardown path and its result carries timed_out=True with
    # whatever tokens it produced. Covers both TTFT and total-latency
    # SLOs (no first token by the deadline is a fortiori a miss).
    deadline_s: Optional[float] = None


# Sequence lifecycle states.
_WAITING, _PREFILL, _RUNNING, _PREEMPTED, _DONE = range(5)


@dataclasses.dataclass
class _Seq:
    req: ServeRequest
    state: int = _WAITING
    slot: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    table_row: Optional[np.ndarray] = None  # host copy of the page-table row
    prefill_done: int = 0  # prompt tokens whose KV is in the cache
    tokens: List[int] = dataclasses.field(default_factory=list)
    # Eviction payload: per-slot rows + page contents, on host.
    evicted_rows: Optional[List[Optional[np.ndarray]]] = None
    evicted_pages: Optional[List[Optional[np.ndarray]]] = None
    n_preempt: int = 0
    timed_out: bool = False
    # Prefix caching: prompt tokens served from shared pages at admission,
    # how many of this sequence's prompt pages are published to the index,
    # and the chain hash after them (the publish cursor).
    n_matched: int = 0
    n_published: int = 0
    chain_parent: int = 0
    # Speculative decoding: per-sequence draft proposer + accounting.
    # ``spec_backoff``/``spec_fails`` implement adaptive drafting: a
    # fully rejected draft pauses drafting for exponentially growing
    # windows (reset on any acceptance), so sequences whose output the
    # n-gram proposer cannot predict fall back to plain-decode cost
    # instead of paying the K+1-token verify every iteration.
    proposer: Optional[NgramProposer] = None
    n_drafted: int = 0
    n_accepted: int = 0
    spec_backoff: int = 0
    spec_fails: int = 0
    t_submit: float = 0.0
    t_admit: float = 0.0  # first admission to a slot (prefill start)
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def ctx_len(self) -> int:
        """Tokens whose KV currently lives in the cache: the prefilled
        prompt plus every generated token already fed back (the latest
        sampled token rides in the host loop until the next decode)."""
        return self.prefill_done + max(len(self.tokens) - 1, 0)

    def sort_key(self):
        return (-self.req.priority, self.req.arrival_time, self.req.request_id)


class Scheduler:
    """Iteration-level scheduler over a loaded :class:`InferenceEngine`.

    ``prefill_chunk`` (a power of two) bounds how many prompt tokens one
    iteration may prefill — the per-iteration decode stall budget.
    """

    def __init__(self, engine: InferenceEngine, *, prefill_chunk: int = 16,
                 seed: int = 0,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 max_done_results: int = 4096,
                 on_retire: Optional[Callable[[int], None]] = None,
                 prefix_caching: bool = True, spec_k: int = 4,
                 spec_ngram: int = 3):
        assert engine._params is not None, "engine.load(params) first"
        if prefill_chunk < 1 or prefill_chunk & (prefill_chunk - 1):
            raise ValueError(f"prefill_chunk must be a power of two, "
                             f"got {prefill_chunk}")
        self.engine = engine
        self.prefill_chunk = prefill_chunk
        self.slots = engine.config.slots
        self._key = jax.random.PRNGKey(seed)
        # Telemetry: latency reservoirs + lifecycle spans. `registry` keeps
        # TTFT/TPOT in bounded reservoirs (the unbounded-list fix);
        # `tracer` emits queued -> prefill -> decode spans per request on a
        # tid = request_id lane. `max_done_results` bounds the retained
        # result map — the oldest finished result is retired (and
        # `on_retire(request_id)` told) once the cap is exceeded.
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        if max_done_results < 1:
            raise ValueError(
                f"max_done_results must be >= 1, got {max_done_results}")
        self.max_done_results = max_done_results
        self._on_retire = on_retire
        # Offset mapping the perf_counter stamps on _Seq onto the tracer's
        # wall-clock timebase, so request lifecycle spans land on the same
        # axis as live spans and merged fleet traces.
        self._clock_offset = time.time() - time.perf_counter()

        if engine.uses_paged_cache():
            from repro.core.config import visit_config

            missing = []

            def check(path, c):
                if (getattr(c, "kv_cache_layout", None) == "paged"
                        and c.num_pages is None):
                    missing.append(path)

            visit_config(engine.config.model, check)
            if missing:
                raise ValueError(
                    "paged models must set MultiheadAttention.Config."
                    "num_pages explicitly for serving (pool geometry must "
                    f"not depend on batch size): {missing[:3]}")
        self._cache = engine.init_cache(self.slots)
        self._axes = engine.batch_axes()
        self.manager = PagedCacheManager(self._cache, self._axes)
        self.allocator: Optional[BlockAllocator] = None
        if self.manager.is_paged:
            self.allocator = BlockAllocator(self.manager.num_pages)
            # A sequence is bounded by BOTH the pool and its page-table
            # width (n_logical rows = ceil(max_len / page)): a pool larger
            # than one table row must not let a sequence index past it.
            self.capacity_tokens = min(self.allocator.capacity,
                                       self.manager.n_logical
                                       ) * self.manager.page_size
            # init_states may have installed full-residency identity tables;
            # in serving the allocator owns every mapping.
            self._cache = self.manager.clear_tables(self._cache)
        else:
            self.capacity_tokens = engine.config.max_len
        # Pristine per-slot rows (all slots identical at init) — admission
        # resets a recycled slot from these.
        self._zero_rows = self.manager.extract_slot(self._cache, 0)

        # Feature gates, derived from what state the model actually keeps.
        # Speculation rolls back by rewinding KV positions — recurrent
        # mixers (Mamba/RWKV) consume tokens irreversibly, so any state
        # leaf outside the attention contract disables drafting. Prefix
        # sharing additionally needs every KV byte behind the page pools
        # (dense ring rows are per-slot and cannot be shared).
        names = {i.name for i in self.manager._info}
        # "scale_pool" is part of the paged attention contract too: it is a
        # page-axis leaf (per-page dequant scales for quantized pools) that
        # the manager moves atomically with k_pool/v_pool, so it is as
        # rewindable and shareable as the payload it describes.
        attn_leaves = {"k", "v", "pos", "k_pool", "v_pool", "pos_pool",
                       "page_table", "index", "scale_pool"}
        self.spec_k = int(spec_k) if names <= attn_leaves else 0
        self.spec_ngram = max(int(spec_ngram), 1)
        # The verify window writes spec_k + 1 positions; none may wrap a
        # dense (or sliding-window) KV ring, which would clobber history a
        # rejected draft cannot give back. The tightest ring bounds it.
        self._spec_write_limit = self.capacity_tokens
        cache_leaves = jax.tree_util.tree_flatten(self._cache)[0]
        for leaf, info in zip(cache_leaves, self.manager._info):
            if info.name == "pos" and info.batch_axis >= 0:
                self._spec_write_limit = min(self._spec_write_limit,
                                             leaf.shape[info.batch_axis + 1])
        self.prefix: Optional[PrefixIndex] = None
        if (prefix_caching and self.manager.is_paged
                and names <= {"k_pool", "v_pool", "pos_pool", "page_table",
                              "index", "scale_pool"}):
            self.prefix = PrefixIndex(self.manager.page_size)

        self._slot_seq: List[Optional[_Seq]] = [None] * self.slots
        self._waiting: List[_Seq] = []
        self._preempted: List[_Seq] = []
        self._done: Dict[int, _Seq] = {}
        self.stats: Dict[str, Any] = {
            "admitted": 0, "completed": 0, "preemptions": 0, "restores": 0,
            "decode_steps": 0, "prefill_chunks": 0, "max_concurrent": 0,
            "truncated": 0, "timeouts": 0,
            "prefix_hits": 0, "prefix_misses": 0,
            "prefill_tokens_skipped": 0, "cow_forks": 0,
            "drafted_tokens": 0, "accepted_tokens": 0, "verify_steps": 0,
        }

    # ------------------------------------------------------------- plumbing

    def _chunk_fn_builder(self):
        """(params, cache, ids (1, C), slot) -> (cache, last_logits (V,)).

        One compiled program per chunk length C; ``slot`` is traced.
        """
        model = self.engine.model
        axes = self._axes

        def chunk(params, cache, ids, slot):
            def take(leaf, ax):
                if ax < 0:
                    return leaf
                return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)

            sub = jax.tree.map(take, cache, axes)
            (sub, logits), _ = functional(
                model, state=params,
                inputs={"state": sub, "ids_step": ids}, method="extend_step")

            def put(bc, c, ax):
                if ax < 0:
                    return c  # shared leaf (page pool): chunk updated it
                return jax.lax.dynamic_update_slice_in_dim(
                    bc, c.astype(bc.dtype), slot, axis=ax)

            cache = jax.tree.map(put, cache, sub, axes)
            return cache, logits[0, -1]

        return chunk

    def _chunk_fn(self, c: int):
        return self.engine._jit(("serve_chunk", c), self._chunk_fn_builder,
                                donate_argnums=(1,))

    def _spec_decode_fn_builder(self, K: int):
        """(params, cache, ids (S, K+1), drafts (S, K), n_draft (S,), key,
        temps (S,), topks (S,), active (S,))
        -> (cache, tokens (S, K+1), n_accept (S,), key).

        The batched verify step: ONE multi-token ``extend_step`` over all
        slots replaces the fused single-token decode whenever any slot has
        a draft. Drafting rows feed ``[t_last, d_1..d_k, pad]`` and commit
        ``n_accept + 1`` tokens under :func:`greedy_verify`; sampled and
        draft-less rows ride along with ``n_draft = 0`` — their position-0
        logits are exactly the plain decode step's (later positions are
        causally invisible to position 0), so they commit their usual one
        token and the whole batch still costs a single dispatch. Inputs
        past a row's draft are padding: their logits are unused and their
        KV writes either land in unmapped pages (dropped) or are rewritten
        before any later query can attend to them (``_spec_batch_safe``
        guarantees no ring wrap). Rollback of each row's rejected tail is
        just the position counter: ``extend_step`` advanced it by K+1, the
        committed context is start + 1 + n_accept, so the ``index`` leaves
        rewind by K - n_accept per slot; inactive rows keep their pre-step
        per-slot state entirely, exactly like the plain decode step.
        """
        model = self.engine.model
        axes = self._axes
        names = [i.name for i in self.manager._info]
        treedef = self.manager._treedef

        def spec_decode(params, cache, ids, drafts, n_draft, key, temps,
                        topks, active):
            (new_cache, logits), _ = functional(
                model, state=params,
                inputs={"state": cache, "ids_step": ids},
                method="extend_step")
            toks, n_acc = jax.vmap(greedy_verify)(logits, drafts, n_draft)
            key, sub = jax.random.split(key)
            sampled = sample_tokens(logits[:, 0], sub, temps, topks)
            toks = toks.at[:, 0].set(jnp.where(temps > 0, sampled,
                                               toks[:, 0]))
            n_acc = jnp.where((temps > 0) | ~active, 0, n_acc)
            rollback = (K - n_acc).astype(jnp.int32)  # (S,) index rewind

            def bcast(vec, leaf, ax):
                shape = [1] * leaf.ndim
                shape[ax] = vec.shape[0]
                return vec.reshape(shape)

            out = []
            for new, old, ax, nm in zip(
                    jax.tree_util.tree_flatten(new_cache)[0],
                    jax.tree_util.tree_flatten(cache)[0],
                    jax.tree_util.tree_flatten(axes)[0], names):
                if ax < 0:
                    out.append(new)  # shared pool: writes self-heal
                    continue
                if nm == "index":
                    new = new - bcast(rollback, new, ax).astype(new.dtype)
                out.append(jnp.where(bcast(active, new, ax), new, old))
            cache = jax.tree_util.tree_unflatten(treedef, out)
            return cache, toks, n_acc, key

        return spec_decode

    def _spec_decode_fn(self, K: int):
        return self.engine._jit(("serve_spec_decode", K),
                                lambda: self._spec_decode_fn_builder(K),
                                donate_argnums=(1,))

    def _decode_fn(self):
        return self.engine._jit(
            "serve_decode_sampling",
            lambda: self.engine._serve_decode_fn(sampling=True),
            donate_argnums=(1,))

    def _sample_first(self, seq: _Seq, logits: jax.Array) -> int:
        """Sample the first token from the final prefill chunk's logits with
        the same per-slot rule the fused decode step applies."""
        from repro.inference.engine import sample_one

        tok, self._key = sample_one(logits, self._key, seq.req.temperature,
                                    seq.req.top_k)
        return tok

    # ------------------------------------------------------ page accounting

    def _pages_needed(self, upto_tokens: int, have: int) -> int:
        return max(-(-upto_tokens // self.manager.page_size) - have, 0)

    def _alloc_fresh(self, n: int) -> Optional[List[int]]:
        """Allocate n pages as *fresh* storage: drop any prefix-index
        entries naming them (their cached content is being recycled) and
        invalidate their stale positions before they can be mapped — a
        previous tenant's tokens must never reach a new sequence's mask.
        Pages are reset lazily here, not at free time, precisely so that
        freed pages keep servable content for future prefix hits."""
        pages = self.allocator.alloc(n)
        if pages is None:
            return None
        if self.prefix is not None:
            self.prefix.forget_pages(pages)
        self._cache = self.manager.reset_pages(self._cache, pages)
        return pages

    def _try_alloc(self, seq: _Seq, upto_tokens: int) -> bool:
        """Ensure ``seq`` has pages mapped for the first ``upto_tokens``
        token positions, evicting lower-priority sequences if the pool runs
        dry. False = could not (seq must wait or be preempted itself)."""
        if self.allocator is None:
            return True
        n = self._pages_needed(upto_tokens, len(seq.pages))
        if n == 0:
            return True
        while self.allocator.num_free < n:
            victim = self._pick_victim(exclude=seq)
            if victim is None:
                return False
            self._evict(victim)
        new = self._alloc_fresh(n)
        assert new is not None
        start = len(seq.pages)
        seq.pages.extend(new)
        for j, p in enumerate(new):
            seq.table_row[start + j] = p
        self._cache = self.manager.write_table_row(
            self._cache, seq.slot, seq.table_row)
        return True

    def _pick_victim(self, exclude: _Seq) -> Optional[_Seq]:
        """Lowest-priority on-device sequence strictly below ``exclude``
        (FCFS-stable: among equals the latest arrival goes first)."""
        candidates = [s for s in self._slot_seq
                      if s is not None and s is not exclude and s.pages]
        if not candidates:
            return None
        victim = max(candidates, key=lambda s: s.sort_key())
        if victim.sort_key() <= exclude.sort_key():
            return None  # nobody outranked by the requester
        return victim

    # ------------------------------------------------------- state changes

    def _admit(self, seq: _Seq):
        slot = self._slot_seq.index(None)
        seq.slot = slot
        seq.state = _PREFILL
        seq.prefill_done = 0
        if seq.t_admit == 0.0:
            seq.t_admit = time.perf_counter()
        if self.manager.is_paged:
            seq.table_row = np.full(self.manager.n_logical, -1, np.int64)
        # Recycled slot: restore pristine rows (zero recurrent state, empty
        # dense KV rows, index 0) and unmap its page-table row.
        self._cache = self.manager.splice_slot(self._cache, slot,
                                               self._zero_rows)
        if self.manager.is_paged:
            self._cache = self.manager.write_table_row(self._cache, slot,
                                                       seq.table_row)
        self._slot_seq[slot] = seq
        if self.prefix is not None:
            self._match_prefix(seq)
        self.stats["admitted"] += 1
        # Device-resident concurrency (preempted sequences don't count).
        concurrent = sum(s is not None for s in self._slot_seq)
        self.stats["max_concurrent"] = max(self.stats["max_concurrent"],
                                           concurrent)

    def _claim_page(self, page: int):
        """Take a reference on a prefix-index page: another sharer if the
        page is live, a revival off the free list if its last holder
        already let go (cached-free content is still intact)."""
        if self.allocator.refcount(page) > 0:
            self.allocator.incref(page)
        else:
            self.allocator.revive(page)

    def _cow_fork(self, seq: _Seq, donor: int, valid: int) -> Optional[int]:
        """Copy-on-write: fork the partially matched ``donor`` page into a
        private copy carrying only the shared ``valid`` token positions.
        The fork happens at admission — before any fused step could write
        this sequence's next position into the shared page — so published
        pages stay strictly read-only. Returns the private page id, or
        None if the pool can't supply the copy (caller drops the partial
        match; the full-page prefix still stands). The caller already
        holds a reference on ``donor``; it is released here either way."""
        while self.allocator.num_free < 1:
            victim = self._pick_victim(exclude=seq)
            if victim is None:
                self.allocator.decref(donor)
                return None
            self._evict(victim)
        got = self._alloc_fresh(1)
        assert got is not None
        self._cache = self.manager.copy_page(self._cache, donor, got[0],
                                             valid)
        self.allocator.decref(donor)
        self.stats["cow_forks"] += 1
        return got[0]

    def _match_prefix(self, seq: _Seq):
        """Map the longest published prefix of the prompt into the
        sequence's page table so those tokens skip prefill. At most
        ``len(prompt) - 1`` tokens match — the final prompt token always
        prefills so its next-token logits exist."""
        full, chain, partial = self.prefix.match(seq.req.prompt)
        claimed: List[int] = []
        for p in full:
            self._claim_page(p)
            claimed.append(p)
        matched = len(claimed) * self.manager.page_size
        seq.chain_parent = chain
        seq.n_published = len(full)
        if partial is not None:
            donor, j = partial
            self._claim_page(donor)
            forked = self._cow_fork(seq, donor, j)
            if forked is not None:
                claimed.append(forked)
                matched += j
        if not claimed:
            self.stats["prefix_misses"] += 1
            self.registry.counter("serving/prefix_cache_misses").inc()
            return
        seq.pages = claimed
        for idx, p in enumerate(claimed):
            seq.table_row[idx] = p
        self._cache = self.manager.write_table_row(self._cache, seq.slot,
                                                   seq.table_row)
        # The decode position counter starts mid-stream: matched tokens
        # are already in the cache.
        self._cache = self.manager.set_index(self._cache, seq.slot, matched)
        seq.prefill_done = matched
        seq.n_matched = matched
        self.stats["prefix_hits"] += 1
        self.stats["prefill_tokens_skipped"] += matched
        self.registry.counter("serving/prefix_cache_hits").inc()
        self.registry.counter("serving/prefill_tokens_skipped").inc(matched)

    def _publish_prefix(self, seq: _Seq):
        """Publish this sequence's fully prefilled prompt pages to the
        index. A page is publishable once every one of its token positions
        holds prompt KV — after that it is immutable (decode writes only
        at positions past the prompt) and safe to share."""
        ps = self.manager.page_size
        prompt = seq.req.prompt
        covered = min(seq.prefill_done, len(prompt))
        while ((seq.n_published + 1) * ps <= covered
               and seq.n_published < len(seq.pages)):
            i = seq.n_published
            toks = tuple(int(t) for t in prompt[i * ps:(i + 1) * ps])
            seq.chain_parent = self.prefix.publish(seq.chain_parent, toks,
                                                   seq.pages[i])
            seq.n_published += 1

    def _evict(self, seq: _Seq):
        """Preempt: page contents + per-slot rows move to host, pages and
        the slot free up. Tokens stay exactly as generated so far. Shared
        prefix pages are decrefed, never freed — other sharers (and the
        index) keep them; restore re-splices the host copy into fresh
        private pages either way."""
        seq.evicted_rows = self.manager.extract_slot(self._cache, seq.slot)
        if seq.pages:
            seq.evicted_pages = self.manager.extract_pages(self._cache,
                                                           seq.pages)
            self._cache = self.manager.write_table_row(
                self._cache, seq.slot,
                np.full(self.manager.n_logical, -1, np.int64))
            self.allocator.decref_all(seq.pages)
        self._slot_seq[seq.slot] = None
        seq.slot = -1
        seq.state = _PREEMPTED
        seq.n_preempt += 1
        self.stats["preemptions"] += 1
        self._preempted.append(seq)

    def _restore(self, seq: _Seq) -> bool:
        """Undo an eviction into a free slot: alloc fresh pages, re-splice
        the saved page contents and slot rows, rebuild the table row."""
        n_pages = len(seq.pages)
        new_pages: List[int] = []
        if n_pages:
            got = self._alloc_fresh(n_pages)
            if got is None:
                return False
            new_pages = got
        slot = self._slot_seq.index(None)
        seq.slot = slot
        self._cache = self.manager.splice_slot(self._cache, slot,
                                               seq.evicted_rows)
        if self.manager.is_paged:
            seq.table_row = np.full(self.manager.n_logical, -1, np.int64)
            for j, p in enumerate(new_pages):
                seq.table_row[j] = p
            if new_pages:
                self._cache = self.manager.insert_pages(
                    self._cache, new_pages, seq.evicted_pages)
            self._cache = self.manager.write_table_row(self._cache, slot,
                                                       seq.table_row)
        seq.pages = new_pages
        seq.evicted_rows = seq.evicted_pages = None
        seq.state = _PREFILL if seq.prefill_done < len(seq.req.prompt) \
            else _RUNNING
        self._slot_seq[slot] = seq
        self.stats["restores"] += 1
        return True

    def _finish(self, seq: _Seq, *, truncated: bool = False):
        if seq.pages:
            if seq.slot >= 0:
                self._cache = self.manager.write_table_row(
                    self._cache, seq.slot,
                    np.full(self.manager.n_logical, -1, np.int64))
            # decref, not free: other sequences may share prefix pages,
            # and pages dropping to refcount 0 keep their contents on the
            # free list for future prefix hits (reset happens lazily at
            # the next allocation).
            self.allocator.decref_all(seq.pages)
            seq.pages = []
        if seq.slot >= 0:
            self._slot_seq[seq.slot] = None
            seq.slot = -1
        seq.state = _DONE
        seq.t_done = time.perf_counter()
        self._done[seq.req.request_id] = seq
        if seq.timed_out:
            self.stats["timeouts"] += 1
        else:
            self.stats["completed"] += 1
        if truncated:
            self.stats["truncated"] += 1
        self._record_lifecycle(seq)
        # Bounded result retention: FIFO-retire the oldest finished result
        # (dict preserves insertion = completion order).
        while len(self._done) > self.max_done_results:
            rid, _ = next(iter(self._done.items()))
            del self._done[rid]
            if self._on_retire is not None:
                self._on_retire(rid)

    def _record_lifecycle(self, seq: _Seq):
        """Latency reservoirs + queued→prefill→decode spans for a finished
        request (timed-out requests get spans but no latency samples —
        their 'latency' is the deadline, not a service time)."""
        n = len(seq.tokens)
        if not seq.timed_out:
            if n:
                self.registry.histogram("serving/ttft_s").record(
                    max(seq.t_first - seq.t_submit, 0.0))
            if n > 1:
                self.registry.histogram("serving/tpot_s").record(
                    max(seq.t_done - seq.t_first, 0.0) / (n - 1))
        if self.tracer is None:
            return
        rid = seq.req.request_id
        off = self._clock_offset
        self.tracer.set_thread_name(rid, f"req {rid}")
        t_admit = seq.t_admit or seq.t_done
        self.tracer.add_span("queued", seq.t_submit + off, t_admit + off,
                             tid=rid, request_id=rid, priority=seq.req.priority)
        t_first = seq.t_first or seq.t_done
        self.tracer.add_span("prefill", t_admit + off, t_first + off,
                             tid=rid, request_id=rid,
                             prompt_len=len(seq.req.prompt),
                             preemptions=seq.n_preempt,
                             prefix_tokens_reused=seq.n_matched)
        if n > 1:
            self.tracer.add_span("decode", t_first + off, seq.t_done + off,
                                 tid=rid, request_id=rid, tokens=n,
                                 tokens_drafted=seq.n_drafted,
                                 tokens_accepted=seq.n_accepted)
        self.tracer.instant("done", tid=rid, request_id=rid,
                            timed_out=seq.timed_out)

    def _time_out(self, seq: _Seq):
        """Cancel a deadline-expired sequence wherever it is in its
        lifecycle, releasing its device resources through the normal
        teardown path."""
        seq.timed_out = True
        if seq.state == _WAITING:
            self._waiting.remove(seq)
        elif seq.state == _PREEMPTED:
            self._preempted.remove(seq)
            # _evict already freed the pages and the slot; drop the host
            # payload so _finish doesn't free the (reused) page ids again.
            seq.pages = []
            seq.evicted_rows = seq.evicted_pages = None
        self._finish(seq)

    def _expire_deadlines(self):
        now = time.perf_counter()
        live = [s for s in self._slot_seq if s is not None]
        for seq in list(self._waiting) + list(self._preempted) + live:
            d = seq.req.deadline_s
            if d is not None and now - seq.t_submit > d:
                self._time_out(seq)

    def _emit(self, seq: _Seq, tok: int):
        if not seq.tokens:
            seq.t_first = time.perf_counter()
        seq.tokens.append(tok)
        if seq.proposer is not None:
            seq.proposer.extend([tok])
        if seq.req.on_token is not None:
            seq.req.on_token(seq.req.request_id, tok)

    # ------------------------------------------------------------ main loop

    def submit(self, req: ServeRequest):
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            # A zero-length prompt has no logits to sample the first token
            # from; fail loudly instead of decoding from padding.
            raise ValueError(f"request {req.request_id}: empty prompt")
        if self.manager.is_paged and len(prompt) > self.capacity_tokens:
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds paged KV capacity "
                f"{self.capacity_tokens} (num_pages x page_size; no ring "
                f"fallback in the paged layout)")
        seq = _Seq(req=dataclasses.replace(req, prompt=prompt))
        seq.t_submit = time.perf_counter()
        # Drafting applies to greedy requests only (a sampled token is not
        # predictable, so verification could never be exact).
        if self.spec_k > 0 and req.temperature <= 0:
            seq.proposer = NgramProposer(self.spec_ngram)
            seq.proposer.extend(prompt)
        self._waiting.append(seq)
        self._waiting.sort(key=_Seq.sort_key)

    @property
    def has_work(self) -> bool:
        return bool(self._waiting or self._preempted
                    or any(s is not None for s in self._slot_seq))

    @property
    def queue_depth(self) -> int:
        return len(self._waiting) + len(self._preempted)

    @property
    def block_utilization(self) -> float:
        if self.allocator is None:
            return float("nan")
        return self.allocator.num_in_use / max(self.allocator.capacity, 1)

    def _fill_slots(self):
        """Restore preempted and admit waiting sequences, best priority
        first, while slots (and head-of-line pages) allow."""
        while None in self._slot_seq:
            cand = []
            if self._preempted:
                cand.append(min(self._preempted, key=_Seq.sort_key))
            if self._waiting:
                cand.append(self._waiting[0])
            if not cand:
                return
            seq = min(cand, key=_Seq.sort_key)
            if seq.state == _PREEMPTED:
                if not self._restore(seq):
                    return  # head-of-line waits for pages
                self._preempted.remove(seq)
            else:
                self._admit(seq)
                self._waiting.pop(0)

    def _prefill_one(self):
        """One chunk of prefill for the best-priority prefilling sequence —
        at most ``prefill_chunk`` tokens per iteration, so co-resident
        decodes stall by one bounded chunk, never a whole prompt."""
        cands = [s for s in self._slot_seq
                 if s is not None and s.state == _PREFILL]
        if not cands:
            return
        seq = min(cands, key=_Seq.sort_key)
        prompt = seq.req.prompt
        remaining = len(prompt) - seq.prefill_done
        c = self.prefill_chunk
        while c > remaining:  # greedy power-of-two decomposition
            c //= 2
        if not self._try_alloc(seq, seq.prefill_done + c):
            return  # pool dry and nobody to evict: retry next iteration
        ids = jnp.asarray(prompt[seq.prefill_done:seq.prefill_done + c]
                          )[None, :]
        span = (self.tracer.span("prefill_chunk", chunk=c,
                                 request_id=seq.req.request_id)
                if self.tracer is not None else contextlib.nullcontext())
        with span:
            self._cache, logits = self._chunk_fn(c)(
                self.engine._params, self._cache, ids,
                jnp.asarray(seq.slot, jnp.int32))
        seq.prefill_done += c
        self.stats["prefill_chunks"] += 1
        if self.prefix is not None:
            self._publish_prefix(seq)
        if seq.prefill_done == len(prompt):
            tok = self._sample_first(seq, logits)
            self._emit(seq, tok)
            if (tok == self.engine.config.eos_token
                    or seq.req.max_new_tokens <= 1):
                self._finish(seq)
            else:
                seq.state = _RUNNING

    def _spec_eligible(self, seq: _Seq) -> bool:
        """Drafting applies to greedy sequences wanting >= 2 more tokens
        whose whole padded verify window (spec_k + 1 positions) stays
        inside capacity and every KV ring — writes past the budget (draft
        padding) must never wrap."""
        return (self.spec_k > 0 and seq.proposer is not None
                and seq.req.max_new_tokens - len(seq.tokens) >= 2
                and seq.ctx_len + self.spec_k + 1 <= self._spec_write_limit)

    def _spec_batch_safe(self) -> bool:
        """The batched K+1 verify writes spec_k + 1 positions at EVERY
        slot — riding and even inactive (mid-prefill) rows included. That
        is safe exactly when no slot's window can wrap a KV ring or run
        off its page table: garbage-at-future-positions self-heals, but a
        wrapped write clobbers history no rollback can give back. One slot
        near its limit sends the whole iteration down the plain 1-token
        decode instead."""
        limit = self._spec_write_limit
        for seq in self._slot_seq:
            idx = 0 if seq is None else (
                seq.prefill_done if seq.state == _PREFILL else seq.ctx_len)
            if idx + self.spec_k + 1 > limit:
                return False
        return True

    def _decode_step(self):
        running = [s for s in self._slot_seq
                   if s is not None and s.state == _RUNNING]
        if not running:
            return
        # Draft pass (host-only): greedy sequences propose up to spec_k
        # tokens from their own history. Committing n_accept + 1 tokens
        # must not overshoot max_new_tokens, so drafts are clipped to
        # remaining - 1. Proposing is stateless, so drafts dropped later
        # (eviction, unsafe batch) simply regenerate next iteration.
        drafts: Dict[int, List[int]] = {}
        if self.spec_k > 0 and self._spec_batch_safe():
            for seq in running:
                if not self._spec_eligible(seq):
                    continue
                if seq.spec_backoff > 0:
                    # Adaptive drafting: recently rejected wholesale, so
                    # sit out this window at plain-decode cost.
                    seq.spec_backoff -= 1
                    continue
                remaining = seq.req.max_new_tokens - len(seq.tokens)
                d = seq.proposer.propose(self.spec_k)[:remaining - 1]
                if d:
                    drafts[seq.req.request_id] = d
        # Every running slot needs pages mapped through its write window
        # (next token, plus its draft if it has one); one that can't get
        # them (pool dry, outranked by everyone) is preempted itself
        # rather than silently dropping KV writes.
        for seq in list(running):
            if seq.state != _RUNNING:
                continue  # evicted as an earlier sequence's victim
            if seq.ctx_len >= self.capacity_tokens and self.manager.is_paged:
                self._finish(seq, truncated=True)
            elif not self._try_alloc(
                    seq, seq.ctx_len + 1
                    + len(drafts.get(seq.req.request_id, ()))):
                self._evict(seq)
        # _try_alloc may have evicted sequences anywhere in the list.
        running = [s for s in running if s.state == _RUNNING]
        if not running:
            return
        if any(s.req.request_id in drafts for s in running):
            self._spec_decode_step(running, drafts)
        else:
            self._plain_decode_step(running)

    def _plain_decode_step(self, running: List[_Seq]):
        """The fused single-token decode over all running slots."""
        cfg = self.engine.config
        last = np.full((self.slots, 1), cfg.pad_token, np.int32)
        temps = np.zeros((self.slots,), np.float32)
        topks = np.zeros((self.slots,), np.int32)
        active = np.zeros((self.slots,), bool)
        for seq in running:
            last[seq.slot, 0] = seq.tokens[-1]
            temps[seq.slot] = seq.req.temperature
            topks[seq.slot] = seq.req.top_k
            active[seq.slot] = True
        span = (self.tracer.span("decode_step", batch=len(running))
                if self.tracer is not None else contextlib.nullcontext())
        with span:
            self._cache, toks, self._key = self._decode_fn()(
                self.engine._params, self._cache, jnp.asarray(last),
                self._key, jnp.asarray(temps), jnp.asarray(topks),
                jnp.asarray(active))
            toks = np.asarray(toks)
        self.stats["decode_steps"] += 1
        for seq in running:
            tok = int(toks[seq.slot])
            self._emit(seq, tok)
            if (len(seq.tokens) >= seq.req.max_new_tokens
                    or tok == cfg.eos_token):
                self._finish(seq)

    def _spec_decode_step(self, running: List[_Seq],
                          drafts: Dict[int, List[int]]):
        """The batched draft-verify decode: one (S, K+1) dispatch commits
        n_accept + 1 tokens per drafting row and exactly one token per
        riding row — same iteration latency shape as the plain step, so
        speculation never serializes per-sequence dispatches."""
        K = self.spec_k
        cfg = self.engine.config
        S = self.slots
        ids = np.full((S, K + 1), cfg.pad_token, np.int32)
        dr = np.full((S, K), -1, np.int32)
        nd = np.zeros((S,), np.int32)
        temps = np.zeros((S,), np.float32)
        topks = np.zeros((S,), np.int32)
        active = np.zeros((S,), bool)
        for seq in running:
            ids[seq.slot, 0] = seq.tokens[-1]
            d = drafts.get(seq.req.request_id, ())
            ids[seq.slot, 1:1 + len(d)] = d
            dr[seq.slot, :len(d)] = d
            nd[seq.slot] = len(d)
            temps[seq.slot] = seq.req.temperature
            topks[seq.slot] = seq.req.top_k
            active[seq.slot] = True
        span = (self.tracer.span("spec_decode_step", batch=len(running),
                                 drafted=int(nd.sum()))
                if self.tracer is not None else contextlib.nullcontext())
        with span:
            self._cache, toks, n_acc, self._key = self._spec_decode_fn(K)(
                self.engine._params, self._cache, jnp.asarray(ids),
                jnp.asarray(dr), jnp.asarray(nd), self._key,
                jnp.asarray(temps), jnp.asarray(topks), jnp.asarray(active))
            toks = np.asarray(toks)
            n_acc = np.asarray(n_acc)
        self.stats["decode_steps"] += 1
        for seq in running:
            k_d = int(nd[seq.slot])
            accepted = int(n_acc[seq.slot])
            if k_d:
                # verify_steps counts per-sequence verify events (not
                # dispatches), so accepted_per_step stays "tokens
                # committed per drafting sequence per step".
                self.stats["verify_steps"] += 1
                self.stats["drafted_tokens"] += k_d
                self.stats["accepted_tokens"] += accepted
                seq.n_drafted += k_d
                seq.n_accepted += accepted
                self.registry.histogram("serving/spec_acceptance").record(
                    accepted / k_d)
                if accepted:
                    seq.spec_fails = 0
                else:
                    # Wholesale rejection: the proposer is guessing wrong
                    # on this sequence, and the (S, K+1) verify costs
                    # ~K+1x a plain step in FLOPs. Back off drafting for
                    # an exponentially growing window (capped) so
                    # unpredictable sequences decode at plain cost.
                    seq.spec_fails += 1
                    seq.spec_backoff = min(1 << seq.spec_fails, 32)
            for t in toks[seq.slot, :accepted + 1]:
                tok = int(t)
                self._emit(seq, tok)
                if (tok == cfg.eos_token
                        or len(seq.tokens) >= seq.req.max_new_tokens):
                    self._finish(seq)
                    break

    def step(self) -> bool:
        """One scheduler iteration: expire deadlines, fill slots, one
        prefill chunk, one fused decode step. Returns whether any work
        remains."""
        self._expire_deadlines()
        self._fill_slots()
        self._prefill_one()
        self._decode_step()
        # Per-iteration gauges (dict updates — no sink I/O on the hot path).
        reg = self.registry
        reg.gauge("serving/queue_depth").set(float(self.queue_depth))
        reg.gauge("serving/running").set(
            float(sum(s is not None for s in self._slot_seq)))
        if self.allocator is not None:
            reg.gauge("serving/page_pool_utilization").set(
                self.block_utilization)
            reg.gauge("serving/page_pool_free").set(
                float(self.allocator.num_free))
        if self.tracer is not None:
            self.tracer.counter("queue_depth", self.queue_depth)
            if self.allocator is not None:
                self.tracer.counter("page_pool_utilization",
                                    self.block_utilization)
        return self.has_work

    # ----------------------------------------------------------- batch API

    def run(self, requests: List[ServeRequest]) -> List[GenerationResult]:
        """Serve a request list to completion (the ``engine.serve``-shaped
        batch entry point; the gateway drives :meth:`step` incrementally)."""
        for r in requests:
            self.submit(r)
        guard = 0
        while self.step():
            guard += 1
            if guard > 100_000:
                raise RuntimeError("scheduler livelock (pool too small for "
                                   "any single sequence?)")
        return [self.result(r.request_id) for r in requests]

    def is_done(self, request_id: int) -> bool:
        return request_id in self._done

    def result(self, request_id: int) -> Optional[GenerationResult]:
        seq = self._done.get(request_id)
        if seq is None:
            return None
        n = len(seq.tokens)
        if n == 0:  # cancelled before the first token
            ttft = max(seq.t_done - seq.t_submit, 0.0)
        else:
            ttft = max(seq.t_first - seq.t_submit, 0.0)
        if n > 1:
            tpot = (seq.t_done - seq.t_first) / (n - 1)
        else:
            tpot = ttft  # single-token request: prefill was the work
        return GenerationResult(request_id, seq.tokens, ttft_s=ttft,
                                tpot_s=tpot, timed_out=seq.timed_out)
