"""Iteration-level serving scheduler: chunked prefill, paging, preemption.

Orca-style scheduling (paper §6) on top of ``InferenceEngine``: every
iteration interleaves at most one *prefill chunk* with one fused decode
step over all running slots, so a long prompt never stalls in-flight
decodes for more than the configured chunk budget.

Key mechanics:

  * **Chunked prefill = ``extend_step``.** A prompt is fed through the
    model's ``extend_step`` in chunks (S' > 1 decode steps mask causally
    among themselves), writing straight into the slot's cache — the same
    program decode uses, so no separate prefill graph. Chunk lengths are
    the greedy power-of-two decomposition of the prompt (each <= the chunk
    budget), which bounds compiled chunk shapes to O(log budget).
  * **Slot-view splicing.** A chunk runs on a B=1 *view* of the batch
    cache: per-slot leaves are sliced at the slot, shared leaves (the page
    pools) pass through whole; after the chunk, per-slot rows are spliced
    back and updated pools replace the originals. ``slot`` is a traced
    scalar — one compile per chunk length, not per slot.
  * **Paging + preemption.** With ``kv_cache_layout="paged"`` models, KV
    pages are allocated on demand (admission, per prefill chunk, and at
    page boundaries during decode). When the pool runs dry the
    lowest-priority sequence is *evicted to host memory* (its pages and
    per-slot rows — not its tokens) and later *restored by re-splicing*
    into freshly allocated pages: no re-prefill, the way SageMaker-MP
    argues resource management should live in the framework, not the model.
  * **Per-slot sampling.** The fused decode step threads per-slot
    temperature/top-k arrays and a PRNG key, so mixed greedy/sampled
    requests batch together (greedy rows are exact argmax).

The scheduler is layout-agnostic: dense-cache models (and recurrent
mixers, whose O(1) state bypasses paging entirely) run through the same
loop with page logic inert.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.module import functional
from repro.inference.engine import GenerationResult, InferenceEngine
from repro.observability.metrics import MetricsRegistry
from repro.observability.tracing import Tracer
from repro.serving.paged_cache import BlockAllocator, PagedCacheManager

__all__ = ["ServeRequest", "Scheduler"]


@dataclasses.dataclass
class ServeRequest:
    """A serving request. ``priority``: higher preempts lower; FCFS within a
    priority level. ``on_token`` fires on the scheduler thread for every
    generated token (the gateway's streaming hook)."""

    request_id: int
    prompt: np.ndarray  # (prompt_len,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    top_k: int = 0  # 0 = no top-k filtering
    priority: int = 0
    arrival_time: float = 0.0
    on_token: Optional[Callable[[int, int], None]] = None
    # Wall-clock budget from submit. A request that has not COMPLETED
    # within deadline_s — still queued, still prefilling, or mid-decode —
    # is cancelled at the next iteration: its pages free through the
    # normal teardown path and its result carries timed_out=True with
    # whatever tokens it produced. Covers both TTFT and total-latency
    # SLOs (no first token by the deadline is a fortiori a miss).
    deadline_s: Optional[float] = None


# Sequence lifecycle states.
_WAITING, _PREFILL, _RUNNING, _PREEMPTED, _DONE = range(5)


@dataclasses.dataclass
class _Seq:
    req: ServeRequest
    state: int = _WAITING
    slot: int = -1
    pages: List[int] = dataclasses.field(default_factory=list)
    table_row: Optional[np.ndarray] = None  # host copy of the page-table row
    prefill_done: int = 0  # prompt tokens whose KV is in the cache
    tokens: List[int] = dataclasses.field(default_factory=list)
    # Eviction payload: per-slot rows + page contents, on host.
    evicted_rows: Optional[List[Optional[np.ndarray]]] = None
    evicted_pages: Optional[List[Optional[np.ndarray]]] = None
    n_preempt: int = 0
    timed_out: bool = False
    t_submit: float = 0.0
    t_admit: float = 0.0  # first admission to a slot (prefill start)
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def ctx_len(self) -> int:
        """Tokens whose KV currently lives in the cache: the prefilled
        prompt plus every generated token already fed back (the latest
        sampled token rides in the host loop until the next decode)."""
        return self.prefill_done + max(len(self.tokens) - 1, 0)

    def sort_key(self):
        return (-self.req.priority, self.req.arrival_time, self.req.request_id)


class Scheduler:
    """Iteration-level scheduler over a loaded :class:`InferenceEngine`.

    ``prefill_chunk`` (a power of two) bounds how many prompt tokens one
    iteration may prefill — the per-iteration decode stall budget.
    """

    def __init__(self, engine: InferenceEngine, *, prefill_chunk: int = 16,
                 seed: int = 0,
                 registry: Optional[MetricsRegistry] = None,
                 tracer: Optional[Tracer] = None,
                 max_done_results: int = 4096,
                 on_retire: Optional[Callable[[int], None]] = None):
        assert engine._params is not None, "engine.load(params) first"
        if prefill_chunk < 1 or prefill_chunk & (prefill_chunk - 1):
            raise ValueError(f"prefill_chunk must be a power of two, "
                             f"got {prefill_chunk}")
        self.engine = engine
        self.prefill_chunk = prefill_chunk
        self.slots = engine.config.slots
        self._key = jax.random.PRNGKey(seed)
        # Telemetry: latency reservoirs + lifecycle spans. `registry` keeps
        # TTFT/TPOT in bounded reservoirs (the unbounded-list fix);
        # `tracer` emits queued -> prefill -> decode spans per request on a
        # tid = request_id lane. `max_done_results` bounds the retained
        # result map — the oldest finished result is retired (and
        # `on_retire(request_id)` told) once the cap is exceeded.
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        if max_done_results < 1:
            raise ValueError(
                f"max_done_results must be >= 1, got {max_done_results}")
        self.max_done_results = max_done_results
        self._on_retire = on_retire
        # Offset mapping the perf_counter stamps on _Seq onto the tracer's
        # wall-clock timebase, so request lifecycle spans land on the same
        # axis as live spans and merged fleet traces.
        self._clock_offset = time.time() - time.perf_counter()

        if engine.uses_paged_cache():
            from repro.core.config import visit_config

            missing = []

            def check(path, c):
                if (getattr(c, "kv_cache_layout", None) == "paged"
                        and c.num_pages is None):
                    missing.append(path)

            visit_config(engine.config.model, check)
            if missing:
                raise ValueError(
                    "paged models must set MultiheadAttention.Config."
                    "num_pages explicitly for serving (pool geometry must "
                    f"not depend on batch size): {missing[:3]}")
        self._cache = engine.init_cache(self.slots)
        self._axes = engine.batch_axes()
        self.manager = PagedCacheManager(self._cache, self._axes)
        self.allocator: Optional[BlockAllocator] = None
        if self.manager.is_paged:
            self.allocator = BlockAllocator(self.manager.num_pages)
            # A sequence is bounded by BOTH the pool and its page-table
            # width (n_logical rows = ceil(max_len / page)): a pool larger
            # than one table row must not let a sequence index past it.
            self.capacity_tokens = min(self.allocator.capacity,
                                       self.manager.n_logical
                                       ) * self.manager.page_size
            # init_states may have installed full-residency identity tables;
            # in serving the allocator owns every mapping.
            self._cache = self.manager.clear_tables(self._cache)
        else:
            self.capacity_tokens = engine.config.max_len
        # Pristine per-slot rows (all slots identical at init) — admission
        # resets a recycled slot from these.
        self._zero_rows = self.manager.extract_slot(self._cache, 0)

        self._slot_seq: List[Optional[_Seq]] = [None] * self.slots
        self._waiting: List[_Seq] = []
        self._preempted: List[_Seq] = []
        self._done: Dict[int, _Seq] = {}
        self.stats: Dict[str, Any] = {
            "admitted": 0, "completed": 0, "preemptions": 0, "restores": 0,
            "decode_steps": 0, "prefill_chunks": 0, "max_concurrent": 0,
            "truncated": 0, "timeouts": 0,
        }

    # ------------------------------------------------------------- plumbing

    def _chunk_fn_builder(self):
        """(params, cache, ids (1, C), slot) -> (cache, last_logits (V,)).

        One compiled program per chunk length C; ``slot`` is traced.
        """
        model = self.engine.model
        axes = self._axes

        def chunk(params, cache, ids, slot):
            def take(leaf, ax):
                if ax < 0:
                    return leaf
                return jax.lax.dynamic_slice_in_dim(leaf, slot, 1, axis=ax)

            sub = jax.tree.map(take, cache, axes)
            (sub, logits), _ = functional(
                model, state=params,
                inputs={"state": sub, "ids_step": ids}, method="extend_step")

            def put(bc, c, ax):
                if ax < 0:
                    return c  # shared leaf (page pool): chunk updated it
                return jax.lax.dynamic_update_slice_in_dim(
                    bc, c.astype(bc.dtype), slot, axis=ax)

            cache = jax.tree.map(put, cache, sub, axes)
            return cache, logits[0, -1]

        return chunk

    def _chunk_fn(self, c: int):
        return self.engine._jit(("serve_chunk", c), self._chunk_fn_builder,
                                donate_argnums=(1,))

    def _decode_fn(self):
        return self.engine._jit(
            "serve_decode_sampling",
            lambda: self.engine._serve_decode_fn(sampling=True),
            donate_argnums=(1,))

    def _sample_first(self, seq: _Seq, logits: jax.Array) -> int:
        """Sample the first token from the final prefill chunk's logits with
        the same per-slot rule the fused decode step applies."""
        from repro.inference.engine import sample_one

        tok, self._key = sample_one(logits, self._key, seq.req.temperature,
                                    seq.req.top_k)
        return tok

    # ------------------------------------------------------ page accounting

    def _pages_needed(self, upto_tokens: int, have: int) -> int:
        return max(-(-upto_tokens // self.manager.page_size) - have, 0)

    def _try_alloc(self, seq: _Seq, upto_tokens: int) -> bool:
        """Ensure ``seq`` has pages mapped for the first ``upto_tokens``
        token positions, evicting lower-priority sequences if the pool runs
        dry. False = could not (seq must wait or be preempted itself)."""
        if self.allocator is None:
            return True
        n = self._pages_needed(upto_tokens, len(seq.pages))
        if n == 0:
            return True
        while self.allocator.num_free < n:
            victim = self._pick_victim(exclude=seq)
            if victim is None:
                return False
            self._evict(victim)
        new = self.allocator.alloc(n)
        assert new is not None
        start = len(seq.pages)
        seq.pages.extend(new)
        for j, p in enumerate(new):
            seq.table_row[start + j] = p
        self._cache = self.manager.write_table_row(
            self._cache, seq.slot, seq.table_row)
        return True

    def _pick_victim(self, exclude: _Seq) -> Optional[_Seq]:
        """Lowest-priority on-device sequence strictly below ``exclude``
        (FCFS-stable: among equals the latest arrival goes first)."""
        candidates = [s for s in self._slot_seq
                      if s is not None and s is not exclude and s.pages]
        if not candidates:
            return None
        victim = max(candidates, key=lambda s: s.sort_key())
        if victim.sort_key() <= exclude.sort_key():
            return None  # nobody outranked by the requester
        return victim

    # ------------------------------------------------------- state changes

    def _admit(self, seq: _Seq):
        slot = self._slot_seq.index(None)
        seq.slot = slot
        seq.state = _PREFILL
        seq.prefill_done = 0
        if seq.t_admit == 0.0:
            seq.t_admit = time.perf_counter()
        if self.manager.is_paged:
            seq.table_row = np.full(self.manager.n_logical, -1, np.int64)
        # Recycled slot: restore pristine rows (zero recurrent state, empty
        # dense KV rows, index 0) and unmap its page-table row.
        self._cache = self.manager.splice_slot(self._cache, slot,
                                               self._zero_rows)
        if self.manager.is_paged:
            self._cache = self.manager.write_table_row(self._cache, slot,
                                                       seq.table_row)
        self._slot_seq[slot] = seq
        self.stats["admitted"] += 1
        # Device-resident concurrency (preempted sequences don't count).
        concurrent = sum(s is not None for s in self._slot_seq)
        self.stats["max_concurrent"] = max(self.stats["max_concurrent"],
                                           concurrent)

    def _evict(self, seq: _Seq):
        """Preempt: page contents + per-slot rows move to host, pages and
        the slot free up. Tokens stay exactly as generated so far."""
        seq.evicted_rows = self.manager.extract_slot(self._cache, seq.slot)
        if seq.pages:
            seq.evicted_pages = self.manager.extract_pages(self._cache,
                                                           seq.pages)
            self._cache = self.manager.reset_pages(self._cache, seq.pages)
            self._cache = self.manager.write_table_row(
                self._cache, seq.slot,
                np.full(self.manager.n_logical, -1, np.int64))
            self.allocator.free(seq.pages)
        self._slot_seq[seq.slot] = None
        seq.slot = -1
        seq.state = _PREEMPTED
        seq.n_preempt += 1
        self.stats["preemptions"] += 1
        self._preempted.append(seq)

    def _restore(self, seq: _Seq) -> bool:
        """Undo an eviction into a free slot: alloc fresh pages, re-splice
        the saved page contents and slot rows, rebuild the table row."""
        n_pages = len(seq.pages)
        new_pages: List[int] = []
        if n_pages:
            got = self.allocator.alloc(n_pages)
            if got is None:
                return False
            new_pages = got
        slot = self._slot_seq.index(None)
        seq.slot = slot
        self._cache = self.manager.splice_slot(self._cache, slot,
                                               seq.evicted_rows)
        if self.manager.is_paged:
            seq.table_row = np.full(self.manager.n_logical, -1, np.int64)
            for j, p in enumerate(new_pages):
                seq.table_row[j] = p
            if new_pages:
                self._cache = self.manager.insert_pages(
                    self._cache, new_pages, seq.evicted_pages)
            self._cache = self.manager.write_table_row(self._cache, slot,
                                                       seq.table_row)
        seq.pages = new_pages
        seq.evicted_rows = seq.evicted_pages = None
        seq.state = _PREFILL if seq.prefill_done < len(seq.req.prompt) \
            else _RUNNING
        self._slot_seq[slot] = seq
        self.stats["restores"] += 1
        return True

    def _finish(self, seq: _Seq, *, truncated: bool = False):
        if seq.pages:
            self._cache = self.manager.reset_pages(self._cache, seq.pages)
            self._cache = self.manager.write_table_row(
                self._cache, seq.slot,
                np.full(self.manager.n_logical, -1, np.int64))
            self.allocator.free(seq.pages)
            seq.pages = []
        if seq.slot >= 0:
            self._slot_seq[seq.slot] = None
            seq.slot = -1
        seq.state = _DONE
        seq.t_done = time.perf_counter()
        self._done[seq.req.request_id] = seq
        if seq.timed_out:
            self.stats["timeouts"] += 1
        else:
            self.stats["completed"] += 1
        if truncated:
            self.stats["truncated"] += 1
        self._record_lifecycle(seq)
        # Bounded result retention: FIFO-retire the oldest finished result
        # (dict preserves insertion = completion order).
        while len(self._done) > self.max_done_results:
            rid, _ = next(iter(self._done.items()))
            del self._done[rid]
            if self._on_retire is not None:
                self._on_retire(rid)

    def _record_lifecycle(self, seq: _Seq):
        """Latency reservoirs + queued→prefill→decode spans for a finished
        request (timed-out requests get spans but no latency samples —
        their 'latency' is the deadline, not a service time)."""
        n = len(seq.tokens)
        if not seq.timed_out:
            if n:
                self.registry.histogram("serving/ttft_s").record(
                    max(seq.t_first - seq.t_submit, 0.0))
            if n > 1:
                self.registry.histogram("serving/tpot_s").record(
                    max(seq.t_done - seq.t_first, 0.0) / (n - 1))
        if self.tracer is None:
            return
        rid = seq.req.request_id
        off = self._clock_offset
        self.tracer.set_thread_name(rid, f"req {rid}")
        t_admit = seq.t_admit or seq.t_done
        self.tracer.add_span("queued", seq.t_submit + off, t_admit + off,
                             tid=rid, request_id=rid, priority=seq.req.priority)
        t_first = seq.t_first or seq.t_done
        self.tracer.add_span("prefill", t_admit + off, t_first + off,
                             tid=rid, request_id=rid,
                             prompt_len=len(seq.req.prompt),
                             preemptions=seq.n_preempt)
        if n > 1:
            self.tracer.add_span("decode", t_first + off, seq.t_done + off,
                                 tid=rid, request_id=rid, tokens=n)
        self.tracer.instant("done", tid=rid, request_id=rid,
                            timed_out=seq.timed_out)

    def _time_out(self, seq: _Seq):
        """Cancel a deadline-expired sequence wherever it is in its
        lifecycle, releasing its device resources through the normal
        teardown path."""
        seq.timed_out = True
        if seq.state == _WAITING:
            self._waiting.remove(seq)
        elif seq.state == _PREEMPTED:
            self._preempted.remove(seq)
            # _evict already freed the pages and the slot; drop the host
            # payload so _finish doesn't free the (reused) page ids again.
            seq.pages = []
            seq.evicted_rows = seq.evicted_pages = None
        self._finish(seq)

    def _expire_deadlines(self):
        now = time.perf_counter()
        live = [s for s in self._slot_seq if s is not None]
        for seq in list(self._waiting) + list(self._preempted) + live:
            d = seq.req.deadline_s
            if d is not None and now - seq.t_submit > d:
                self._time_out(seq)

    def _emit(self, seq: _Seq, tok: int):
        if not seq.tokens:
            seq.t_first = time.perf_counter()
        seq.tokens.append(tok)
        if seq.req.on_token is not None:
            seq.req.on_token(seq.req.request_id, tok)

    # ------------------------------------------------------------ main loop

    def submit(self, req: ServeRequest):
        prompt = np.asarray(req.prompt, np.int32).reshape(-1)
        if len(prompt) == 0:
            # A zero-length prompt has no logits to sample the first token
            # from; fail loudly instead of decoding from padding.
            raise ValueError(f"request {req.request_id}: empty prompt")
        if self.manager.is_paged and len(prompt) > self.capacity_tokens:
            raise ValueError(
                f"prompt of {len(prompt)} tokens exceeds paged KV capacity "
                f"{self.capacity_tokens} (num_pages x page_size; no ring "
                f"fallback in the paged layout)")
        seq = _Seq(req=dataclasses.replace(req, prompt=prompt))
        seq.t_submit = time.perf_counter()
        self._waiting.append(seq)
        self._waiting.sort(key=_Seq.sort_key)

    @property
    def has_work(self) -> bool:
        return bool(self._waiting or self._preempted
                    or any(s is not None for s in self._slot_seq))

    @property
    def queue_depth(self) -> int:
        return len(self._waiting) + len(self._preempted)

    @property
    def block_utilization(self) -> float:
        if self.allocator is None:
            return float("nan")
        return self.allocator.num_in_use / max(self.allocator.capacity, 1)

    def _fill_slots(self):
        """Restore preempted and admit waiting sequences, best priority
        first, while slots (and head-of-line pages) allow."""
        while None in self._slot_seq:
            cand = []
            if self._preempted:
                cand.append(min(self._preempted, key=_Seq.sort_key))
            if self._waiting:
                cand.append(self._waiting[0])
            if not cand:
                return
            seq = min(cand, key=_Seq.sort_key)
            if seq.state == _PREEMPTED:
                if not self._restore(seq):
                    return  # head-of-line waits for pages
                self._preempted.remove(seq)
            else:
                self._admit(seq)
                self._waiting.pop(0)

    def _prefill_one(self):
        """One chunk of prefill for the best-priority prefilling sequence —
        at most ``prefill_chunk`` tokens per iteration, so co-resident
        decodes stall by one bounded chunk, never a whole prompt."""
        cands = [s for s in self._slot_seq
                 if s is not None and s.state == _PREFILL]
        if not cands:
            return
        seq = min(cands, key=_Seq.sort_key)
        prompt = seq.req.prompt
        remaining = len(prompt) - seq.prefill_done
        c = self.prefill_chunk
        while c > remaining:  # greedy power-of-two decomposition
            c //= 2
        if not self._try_alloc(seq, seq.prefill_done + c):
            return  # pool dry and nobody to evict: retry next iteration
        ids = jnp.asarray(prompt[seq.prefill_done:seq.prefill_done + c]
                          )[None, :]
        span = (self.tracer.span("prefill_chunk", chunk=c,
                                 request_id=seq.req.request_id)
                if self.tracer is not None else contextlib.nullcontext())
        with span:
            self._cache, logits = self._chunk_fn(c)(
                self.engine._params, self._cache, ids,
                jnp.asarray(seq.slot, jnp.int32))
        seq.prefill_done += c
        self.stats["prefill_chunks"] += 1
        if seq.prefill_done == len(prompt):
            tok = self._sample_first(seq, logits)
            self._emit(seq, tok)
            if (tok == self.engine.config.eos_token
                    or seq.req.max_new_tokens <= 1):
                self._finish(seq)
            else:
                seq.state = _RUNNING

    def _decode_step(self):
        running = [s for s in self._slot_seq
                   if s is not None and s.state == _RUNNING]
        if not running:
            return
        # Every running slot needs its next token's page mapped; one that
        # can't get it (pool dry, outranked by everyone) is preempted
        # itself rather than silently dropping KV writes.
        for seq in list(running):
            if seq.state != _RUNNING:
                continue  # evicted as an earlier sequence's victim
            if seq.ctx_len >= self.capacity_tokens and self.manager.is_paged:
                self._finish(seq, truncated=True)
            elif not self._try_alloc(seq, seq.ctx_len + 1):
                self._evict(seq)
        # _try_alloc may have evicted sequences anywhere in the list.
        running = [s for s in running if s.state == _RUNNING]
        if not running:
            return
        cfg = self.engine.config
        last = np.full((self.slots, 1), cfg.pad_token, np.int32)
        temps = np.zeros((self.slots,), np.float32)
        topks = np.zeros((self.slots,), np.int32)
        active = np.zeros((self.slots,), bool)
        for seq in running:
            last[seq.slot, 0] = seq.tokens[-1]
            temps[seq.slot] = seq.req.temperature
            topks[seq.slot] = seq.req.top_k
            active[seq.slot] = True
        span = (self.tracer.span("decode_step", batch=len(running))
                if self.tracer is not None else contextlib.nullcontext())
        with span:
            self._cache, toks, self._key = self._decode_fn()(
                self.engine._params, self._cache, jnp.asarray(last),
                self._key, jnp.asarray(temps), jnp.asarray(topks),
                jnp.asarray(active))
            toks = np.asarray(toks)
        self.stats["decode_steps"] += 1
        for seq in running:
            tok = int(toks[seq.slot])
            self._emit(seq, tok)
            if (len(seq.tokens) >= seq.req.max_new_tokens
                    or tok == cfg.eos_token):
                self._finish(seq)

    def step(self) -> bool:
        """One scheduler iteration: expire deadlines, fill slots, one
        prefill chunk, one fused decode step. Returns whether any work
        remains."""
        self._expire_deadlines()
        self._fill_slots()
        self._prefill_one()
        self._decode_step()
        # Per-iteration gauges (dict updates — no sink I/O on the hot path).
        reg = self.registry
        reg.gauge("serving/queue_depth").set(float(self.queue_depth))
        reg.gauge("serving/running").set(
            float(sum(s is not None for s in self._slot_seq)))
        if self.allocator is not None:
            reg.gauge("serving/page_pool_utilization").set(
                self.block_utilization)
            reg.gauge("serving/page_pool_free").set(
                float(self.allocator.num_free))
        if self.tracer is not None:
            self.tracer.counter("queue_depth", self.queue_depth)
            if self.allocator is not None:
                self.tracer.counter("page_pool_utilization",
                                    self.block_utilization)
        return self.has_work

    # ----------------------------------------------------------- batch API

    def run(self, requests: List[ServeRequest]) -> List[GenerationResult]:
        """Serve a request list to completion (the ``engine.serve``-shaped
        batch entry point; the gateway drives :meth:`step` incrementally)."""
        for r in requests:
            self.submit(r)
        guard = 0
        while self.step():
            guard += 1
            if guard > 100_000:
                raise RuntimeError("scheduler livelock (pool too small for "
                                   "any single sequence?)")
        return [self.result(r.request_id) for r in requests]

    def is_done(self, request_id: int) -> bool:
        return request_id in self._done

    def result(self, request_id: int) -> Optional[GenerationResult]:
        seq = self._done.get(request_id)
        if seq is None:
            return None
        n = len(seq.tokens)
        if n == 0:  # cancelled before the first token
            ttft = max(seq.t_done - seq.t_submit, 0.0)
        else:
            ttft = max(seq.t_first - seq.t_submit, 0.0)
        if n > 1:
            tpot = (seq.t_done - seq.t_first) / (n - 1)
        else:
            tpot = ttft  # single-token request: prefill was the work
        return GenerationResult(request_id, seq.tokens, ttft_s=ttft,
                                tpot_s=tpot, timed_out=seq.timed_out)
