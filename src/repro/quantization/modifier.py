"""QuantizationModifier: low precision for a whole arch in one mesh rule.

The paper's §4.2 claim, applied to precision: turning on fp8 train
compute, w8a8 quantized linears, and/or a quantized paged KV cache for
any registered arch is ~10 lines of config — one modifier in a mesh
rule — never a model edit::

    QuantizationModifier.default_config().set(
        fp8=True,            # delayed-scaling fp8 compute (Fp8Config ok)
        w8a8=True,           # Linear -> QuantizedLinear everywhere
        kv_dtype="int8")     # paged KV pools -> int8 + scale_pool

It composes with the rest of the rule list: apply it *after*
``DtypePolicyModifier`` (it clones each layer's existing policy and adds
the fp8 field, so bf16-compute + fp8 boundaries is the natural stack),
and ZeRO-1 / master weights / grad accumulation need no special casing —
the amax histories are ordinary tiny replicated params.
"""

from __future__ import annotations

from typing import Any, Optional

from repro.core.config import ConfigBase, config_class, visit_config
from repro.core.module import no_context
from repro.layers.base import DtypePolicy
from repro.quantization import kv as kv_lib
from repro.quantization.fp8 import Fp8Config
from repro.quantization.linear import Int8ConfigModifier
from repro.trainer.mesh_rules import ConfigModifier

__all__ = ["QuantizationModifier", "set_kv_cache_dtype"]


def set_kv_cache_dtype(model_cfg: ConfigBase, name: str, *,
                       paged_only: bool = False) -> ConfigBase:
    """Point every attention config's ``kv_cache_dtype`` at a storage
    dtype by short name ("fp32" | "bf16" | "int8" | "fp8_e4m3").

    The serving/bench-facing entry point for cache quantization: callers
    name a format, never a dtype. With ``paged_only`` the dense layers
    (which cannot carry scale rows) keep their configured dtype.
    """
    dtype = kv_lib.dtype_by_name(name)

    def visit(path, cfg):
        if "kv_cache_dtype" not in cfg.keys():
            return
        if paged_only and getattr(cfg, "kv_cache_layout", None) != "paged":
            return
        cfg.set(kv_cache_dtype=dtype)

    visit_config(model_cfg, visit)
    return model_cfg


class QuantizationModifier(ConfigModifier):
    """One knob for every low-precision mechanism in the tree."""

    @config_class
    class Config(ConfigModifier.Config):
        # fp8 train compute: ``True`` for defaults or an ``Fp8Config``.
        # Clones each layer's existing dtype_policy and sets its ``fp8``
        # field, so it layers on top of a prior DtypePolicyModifier.
        fp8: Optional[Any] = None
        # Swap every Linear for QuantizedLinear (w8a8).
        w8a8: bool = False
        straight_through: bool = True
        # Paged-KV storage format by short name ("int8" | "fp8_e4m3");
        # dense layers are left alone (no scale rows in a dense ring).
        kv_dtype: Optional[str] = None

    @no_context
    def apply(self, trainer_cfg):
        c = self.config
        if c.fp8 is not None and c.fp8 is not False:
            fp8_cfg = c.fp8 if isinstance(c.fp8, ConfigBase) else Fp8Config()
            # Layers typically share one policy instance (modifiers set
            # the same object tree-wide); clone once per distinct
            # instance so sharing is preserved.
            cloned = {}

            def add_fp8(path, cfg):
                if isinstance(cfg, DtypePolicy) or \
                        "dtype_policy" not in cfg.keys():
                    return
                cur = cfg.dtype_policy
                key = id(cur)
                if key not in cloned:
                    base = cur.clone() if cur is not None else DtypePolicy()
                    cloned[key] = base.set(fp8=fp8_cfg)
                cfg.set(dtype_policy=cloned[key])

            visit_config(trainer_cfg, add_fp8)
        if c.w8a8:
            trainer_cfg = Int8ConfigModifier.default_config().set(
                straight_through=c.straight_through,
            ).instantiate().apply(trainer_cfg)
        if c.kv_dtype is not None:
            set_kv_cache_dtype(trainer_cfg, c.kv_dtype, paged_only=True)
        return trainer_cfg
