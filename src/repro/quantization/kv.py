"""Quantized paged-KV storage formats: int8 / fp8-e4m3 pools + scale rows.

The paged KV cache stores each token slot's K and V rows in a
low-precision storage dtype with one fp32 scale per (slot, k|v) carried
in a ``scale_pool`` leaf of shape ``(num_pages, page_size, 2)`` that
lives alongside ``k_pool``/``v_pool`` in the cache tree.  Because the
scale row shares the physical-page axis with the payload pools, every
page operation the serving stack performs — COW forks, evict-to-host,
restore, prefix-page sharing — moves the scales atomically with the KV
bytes by construction, and a prefix hit replays *bitwise identical*
quantized pages (quantization is deterministic, so shared pages equal a
cold prefill's).

Resolution is declarative: a layer's ``kv_cache_dtype`` resolves through
:func:`pool_format` into either ``None`` (plain ``astype`` storage —
fp32/bf16, and fp8 on the *dense* layout which has nowhere to put
scales) or a :class:`KVQuantFormat` the attention layer and kernels
treat as opaque.  Per-slot scaling (amax over the slot's ``(heads,
head_dim)`` rows) keeps the round-trip error relative to each token's
own magnitude: ~0.4% worst-case for int8, ~6% for e4m3's 3-bit mantissa.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quantization import numerics

__all__ = [
    "KVQuantFormat",
    "INT8_KV",
    "FP8_E4M3_KV",
    "format_by_name",
    "pool_format",
    "storage_dtype",
    "dtype_by_name",
    "init_scale_pool",
    "quantize_kv_write",
    "dequantize_kv",
]


@dataclasses.dataclass(frozen=True)
class KVQuantFormat:
    """One quantized pool storage scheme (opaque outside this package)."""

    name: str
    storage_dtype: Any
    qmax: float


INT8_KV = KVQuantFormat("int8", jnp.int8, numerics.INT8_QMAX)
FP8_E4M3_KV = KVQuantFormat("fp8_e4m3", jnp.float8_e4m3fn,
                            numerics.FP8_E4M3_MAX)

_BY_NAME = {f.name: f for f in (INT8_KV, FP8_E4M3_KV)}

# Serving/bench-facing dtype names -> storage dtypes (the only place the
# string names resolve, so benchmarks and launch scripts never spell a
# low-precision dtype).
_DTYPE_NAMES = {
    "fp32": jnp.float32,
    "bf16": jnp.bfloat16,
    "int8": jnp.int8,
    "fp8_e4m3": jnp.float8_e4m3fn,
}


def format_by_name(name: str) -> KVQuantFormat:
    if name not in _BY_NAME:
        raise ValueError(f"unknown KV quant format {name!r}; "
                         f"known: {sorted(_BY_NAME)}")
    return _BY_NAME[name]


def dtype_by_name(name: str) -> Any:
    """A ``kv_cache_dtype`` value from a short serving-facing name."""
    if name not in _DTYPE_NAMES:
        raise ValueError(f"unknown kv dtype name {name!r}; "
                         f"known: {sorted(_DTYPE_NAMES)}")
    return _DTYPE_NAMES[name]


def pool_format(kv_cache_dtype: Any, *, layout: str
                ) -> Optional[KVQuantFormat]:
    """Resolve a layer's ``kv_cache_dtype`` into a pool quant format.

    * int8 -> :data:`INT8_KV`; requires the paged layout (the per-slot
      scales live in the page pool — a dense ring has nowhere to carry
      them), so a dense int8 config raises here, at layer build time.
    * float8_e4m3 on the paged layout -> :data:`FP8_E4M3_KV` (scaled
      storage); on the dense layout it keeps the historical plain
      ``astype`` cache (unscaled), preserving that path's semantics.
    * anything else -> ``None`` (plain ``astype`` storage).

    Accepts either a dtype or one of the short serving-facing names.
    """
    if isinstance(kv_cache_dtype, str) and kv_cache_dtype in _DTYPE_NAMES:
        kv_cache_dtype = _DTYPE_NAMES[kv_cache_dtype]
    dt = jnp.dtype(kv_cache_dtype)
    if dt == jnp.dtype(jnp.int8):
        if layout != "paged":
            raise ValueError(
                "int8 KV storage requires kv_cache_layout='paged': the "
                "per-slot scale rows live in the page pool (scale_pool)")
        return INT8_KV
    if dt == jnp.dtype(jnp.float8_e4m3fn) and layout == "paged":
        return FP8_E4M3_KV
    return None


def storage_dtype(kv_cache_dtype: Any, *, layout: str) -> Any:
    """The dtype the pool leaves are allocated in."""
    fmt = pool_format(kv_cache_dtype, layout=layout)
    return fmt.storage_dtype if fmt is not None else kv_cache_dtype


def init_scale_pool(num_pages: int, page_size: int) -> jax.Array:
    """Fresh scale rows: unit scales so uninitialized slots dequantize to
    their raw (zero) storage values."""
    return jnp.ones((num_pages, page_size, 2), jnp.float32)


def quantize_kv_write(k: jax.Array, v: jax.Array, fmt: KVQuantFormat
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Quantize a cache write per token slot.

    ``k``/``v`` are update rows shaped ``(..., heads, head_dim)`` with the
    token-slot axes leading; the scale for each slot is ``amax over its
    (heads, head_dim) rows / qmax``.  Returns storage-dtype ``(k_q, v_q)``
    plus fp32 ``scales`` shaped ``(..., 2)`` (k-scale, v-scale) ready to
    scatter into ``scale_pool``.
    """

    def one(x):
        amax = numerics.abs_amax(x, axis=(-2, -1))
        scale = jnp.maximum(amax, numerics._EPS) / fmt.qmax
        q = numerics.scaled_cast(x, scale[..., None, None],
                                 fmt.storage_dtype)
        return q, scale

    k_q, k_scale = one(k)
    v_q, v_scale = one(v)
    return k_q, v_q, jnp.stack([k_scale, v_scale], axis=-1)


def dequantize_kv(k: jax.Array, v: jax.Array, scales: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """Inverse of :func:`quantize_kv_write` for gathered pool rows.

    ``k``/``v`` are ``(..., slots, heads, head_dim)`` storage values and
    ``scales`` is ``(..., slots, 2)``; returns fp32 dequantized rows.
    """
    k = numerics.dequantize(k, scales[..., 0][..., None, None])
    v = numerics.dequantize(v, scales[..., 1][..., None, None])
    return k, v
