"""fp8 train compute: per-tensor delayed scaling at module boundaries.

The fp8 recipe used by production trainers (Transformer Engine style):
activations entering a GEMM layer are cast through float8_e4m3fn with a
*delayed* per-tensor scale — ``scale = max(amax history) / E4M3_MAX`` —
so the scale is known before the activation is, and the current step's
amax is pushed into the history for the next step.  We implement the
simulated ("fake-quant") form: values are quantized to the exact e4m3
grid but carried in the compute dtype, with a straight-through gradient,
so the numerics (and the loss curve) match an fp8 MXU path while staying
runnable on any backend.

Wiring: :class:`repro.layers.base.BaseLayer` applies
:func:`boundary_fake_quant` inside its ``_to_compute`` module-boundary
cast whenever its ``DtypePolicy.fp8`` is set and the layer opts in via
``_fp8_boundary`` (GEMM layers: Linear); the amax history is an ordinary
``(history_len,)`` fp32 parameter named :data:`AMAX_HISTORY_KEY`
(weight-decay exempt, replicated) whose roll is emitted as a state
update and folded back into the params by the train step — which is what
lets the whole mechanism compose with ZeRO-1, master weights, and grad
accumulation (microbatch histories combine by elementwise max).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.config import ConfigBase, config_class
from repro.quantization import numerics

__all__ = [
    "Fp8Config",
    "AMAX_HISTORY_KEY",
    "boundary_fake_quant",
    "roll_amax_history",
]

# Layer-state / parameter name of the delayed-scaling amax history.
AMAX_HISTORY_KEY = "fp8_amax_history"


@config_class
class Fp8Config(ConfigBase):
    """Delayed-scaling fp8 compute mode (carried as ``DtypePolicy.fp8``).

    ``amax_history_len``: steps of amax history the scale is derived
        from (max over the window rides out per-batch amax noise).
    ``margin``: scale headroom factor; >1 trades a little resolution for
        fewer saturated outliers when activations spike between steps.
    """

    amax_history_len: int = 16
    margin: float = 1.0


def boundary_fake_quant(x: jax.Array, history: jax.Array, *,
                        margin: float = 1.0
                        ) -> Tuple[jax.Array, jax.Array]:
    """Fake-quantize one activation tensor with a delayed per-tensor scale.

    Returns ``(x_q, amax)``: ``x_q`` in ``x.dtype`` holding e4m3-grid
    values with a straight-through gradient, and the tensor's current
    fp32 amax (to roll into the history).  A fresh history (all zeros)
    falls back to just-in-time scaling from the current amax so step 0
    is sane.
    """
    amax = numerics.abs_amax(x)
    hist_max = jnp.max(history.astype(jnp.float32))
    ref = jnp.where(hist_max > 0.0, hist_max, amax) * margin
    scale = jnp.maximum(ref, numerics._EPS) / numerics.FP8_E4M3_MAX
    scale = jax.lax.stop_gradient(scale)
    q = numerics.scaled_cast(x, scale, jnp.float8_e4m3fn)
    deq = numerics.dequantize(q, scale).astype(x.dtype)
    # STE: forward sees the quantized value, gradient flows as identity.
    return x + jax.lax.stop_gradient(deq - x), amax


def roll_amax_history(history: jax.Array, amax: jax.Array) -> jax.Array:
    """New history with ``amax`` at [0] (newest-first ring)."""
    return jnp.concatenate(
        [amax.reshape(1).astype(history.dtype), history[:-1]])
