"""Scaled low-precision casts shared by every quantization consumer.

Symmetric scaling throughout: a tensor (or a slice of one) is stored as
``q = round_or_cast(x / scale)`` with ``scale = amax / qmax`` computed in
fp32 (bf16 inputs lose mantissa bits exactly where the division needs
them, so the amax/divide always run in fp32 regardless of input dtype).
Dequantization is ``q * scale``.

This module owns the raw dtype arithmetic; the pool/page framing lives in
:mod:`repro.quantization.kv` and the delayed-scaling train path in
:mod:`repro.quantization.fp8`.
"""

from __future__ import annotations

from typing import Any, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

__all__ = [
    "INT8_QMAX",
    "FP8_E4M3_MAX",
    "abs_amax",
    "quantize_int8",
    "dequantize",
    "scaled_cast",
]

INT8_QMAX = 127.0
# Largest finite float8_e4m3fn value; values are clipped here before the
# cast because e4m3fn has no inf (overflow would produce NaN).
FP8_E4M3_MAX = 448.0
_EPS = 1e-8

Axis = Union[int, Sequence[int], None]


def abs_amax(x: jax.Array, axis: Axis = None,
             keepdims: bool = False) -> jax.Array:
    """max(|x|) computed in fp32 (safe for bf16/fp16 inputs)."""
    return jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axis,
                   keepdims=keepdims)


def quantize_int8(x: jax.Array, axis: Axis) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 quantization along ``axis``: returns (q, scale).

    The amax reduction and the division run in fp32 *before* any rounding
    (a bf16 ``x / scale`` would quantize the quantization step itself).
    Already-int8 inputs are returned unchanged with unit scales — the
    no-op guard that makes double quantization safe.
    """
    if x.dtype == jnp.int8:
        shape = list(x.shape)
        for ax in ((axis,) if isinstance(axis, int) else (axis or ())):
            shape[ax] = 1
        return x, jnp.ones(shape, jnp.float32)
    amax = abs_amax(x, axis=axis, keepdims=True)
    scale = jnp.maximum(amax, _EPS) / INT8_QMAX
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                 -INT8_QMAX, INT8_QMAX).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of any symmetric scaled cast: fp32 ``q * scale``."""
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def scaled_cast(x: jax.Array, scale: jax.Array, dtype: Any) -> jax.Array:
    """``(x / scale)`` cast to a low-precision storage dtype, with the
    division in fp32 and the value range clipped to the dtype's finite
    span (int8 rounds; e4m3fn saturates instead of overflowing to NaN)."""
    y = x.astype(jnp.float32) / scale.astype(jnp.float32)
    dt = jnp.dtype(dtype)
    if dt == jnp.dtype(jnp.int8):
        y = jnp.clip(jnp.round(y), -INT8_QMAX, INT8_QMAX)
    elif dt == jnp.dtype(jnp.float8_e4m3fn):
        y = jnp.clip(y, -FP8_E4M3_MAX, FP8_E4M3_MAX)
    return y.astype(dt)
