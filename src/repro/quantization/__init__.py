"""Low-precision subsystem: quantized paged KV storage and fp8 compute.

This package is the single home for low-precision *dtype logic* in the
codebase — everything outside it (layers, kernels, serving, trainer)
handles opaque ``(values, scales)`` pairs, storage dtypes, or resolved
format objects produced here.  A grep contract
(``tests/test_quantization.py``) enforces that no ``int8``/``float8``
dtype branching leaks outside ``src/repro/quantization/`` and the kernel
registry, mirroring the no-impl-branching contract of the PR 4 kernel
registry.

Submodules:

* :mod:`repro.quantization.numerics` — scaled integer/fp8 casts and amax
  helpers shared by every consumer.
* :mod:`repro.quantization.kv` — paged-KV storage formats: int8 /
  simulated fp8-e4m3 pools with per-token-slot scales carried in a
  ``scale_pool`` leaf alongside ``k_pool``/``v_pool``.
* :mod:`repro.quantization.fp8` — fp8 train compute: per-tensor delayed
  scaling (amax history in layer state) applied at module boundaries by
  :class:`repro.layers.base.BaseLayer`.
* :mod:`repro.quantization.linear` — w8a8 :class:`QuantizedLinear` and
  the :class:`Int8ConfigModifier` that swaps it into any arch config.
* :mod:`repro.quantization.modifier` — :class:`QuantizationModifier`,
  the one mesh-rule knob that rewrites a registered arch config for fp8
  compute, w8a8 linears, and/or a quantized KV cache.

``linear`` and ``modifier`` import from ``repro.layers`` /
``repro.trainer`` and are therefore *not* imported here — import them
directly to avoid cycles (``repro.layers.attention`` imports
``repro.quantization.kv`` at module load).
"""

from repro.quantization import kv, numerics
from repro.quantization.kv import KVQuantFormat, pool_format
from repro.quantization.numerics import dequantize, quantize_int8

__all__ = [
    "KVQuantFormat",
    "dequantize",
    "kv",
    "numerics",
    "pool_format",
    "quantize_int8",
]
