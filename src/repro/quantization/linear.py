"""Quantization as a drop-in DotGeneral/Linear replacement (paper §4.2).

"All components are implemented as strictly encapsulated modules. This
allows expressing optimizations like quantization as a replacement of
DotGeneral layers with their quantization-aware equivalents." — we implement
exactly that: ``QuantizedLinear`` is interface-compatible with ``Linear``
(same params, same config surface + quantization knobs), integrated into any
experiment by the usual ~5-line ``replace_config`` traversal, selected per
hardware target by ``Int8ConfigModifier`` (App. A's INT8ConfigModifier) or
the broader :class:`repro.quantization.modifier.QuantizationModifier`.

Scheme: dynamic symmetric int8 ("w8a8"): per-output-channel weight scales,
per-token activation scales, int8 x int8 -> int32 accumulation (MXU-native
on TPU), rescale in fp32. Fake-quant semantics are exact on any backend.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import config_class
from repro.core.module import no_context
from repro.layers.basic import Linear
from repro.quantization.numerics import quantize_int8
from repro.trainer.mesh_rules import ConfigModifier

__all__ = ["QuantizedLinear", "Int8ConfigModifier", "quantize_int8"]


class QuantizedLinear(Linear):
    """Linear with dynamic int8 weight+activation quantization (w8a8).

    Same parameters as Linear (the checkpoint is interchangeable); the
    quantization is purely a compute-path choice.
    """

    # Runs its own quantization scheme; the base-layer fp8 boundary
    # fake-quant must not double-quantize its inputs.
    _fp8_boundary = False

    @config_class
    class Config(Linear.Config):
        # Straight-through estimator for training; pure int8 path at inference.
        straight_through: bool = True

    def forward(self, x: jax.Array) -> jax.Array:
        cfg = self.config
        x = self._to_compute(x)
        w = self.state["weight"]
        xq, x_scale = quantize_int8(x, axis=-1)  # per-token
        wq, w_scale = quantize_int8(w, axis=0)  # per-out-channel

        # int8 x int8 -> int32 accumulate (MXU-native), rescale fp32.
        acc = jax.lax.dot_general(
            xq, wq, (((x.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        y = acc.astype(jnp.float32) * x_scale * w_scale.reshape(
            (1,) * (x.ndim - 1) + (-1,))

        if cfg.straight_through and self.is_training:
            # STE: forward uses quantized value, gradient flows as if fp.
            y_fp = (x.astype(jnp.float32) @ w.astype(jnp.float32))
            y = y_fp + jax.lax.stop_gradient(y - y_fp)

        y = y.astype(x.dtype)
        if cfg.bias:
            y = y + self.state["bias"].astype(y.dtype)
        if cfg.output_partition is not None:
            y = self._shard(y, cfg.output_partition)
        return y


class Int8ConfigModifier(ConfigModifier):
    """Paper App. A's INT8ConfigModifier: swaps every Linear for its
    quantization-aware equivalent across the entire trainer config."""

    @config_class
    class Config(ConfigModifier.Config):
        straight_through: bool = True

    @no_context
    def apply(self, trainer_cfg):
        from repro.core.config import replace_config

        replace_config(
            trainer_cfg,
            target=lambda c: type(c) is Linear.Config,
            new_cfg=lambda old: QuantizedLinear.default_config().set(
                straight_through=self.config.straight_through,
                **{k: getattr(old, k) for k in old.keys() if k != "name"}),
            propagate=(),
        )
        return trainer_cfg
