"""Checkpointer: data-sharded serialization, async saves, GC (paper §5).

Paper-faithful properties, adapted to a single-host test substrate:

* **Data-sharded serialization** — leaves are partitioned across processes by
  a deterministic assignment (rather than "rank 0 writes everything"), with
  ``concurrency`` bounding in-flight host copies.
* **Async saves** — a background thread serializes while training continues;
  ``wait()`` blocks only on a prior in-flight save (as in §5).
* **GC policy** — keep-last-N, background-collected.
* **Storage-layer swap** — the directory layout + index live behind a small
  interface, so a cloud backend is a drop-in config change (we ship local-FS).

Format: <dir>/step_<k>/shard_<p>.npz + index.json (paths, shapes, dtypes).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import REQUIRED, Required, config_class
from repro.core.module import Module, no_context
from repro.core.utils import flatten_tree

__all__ = ["Checkpointer"]


class Checkpointer(Module):
    @config_class
    class Config(Module.Config):
        directory: Required[str] = REQUIRED
        keep_last_n: int = 3
        async_save: bool = True
        # Max leaves concurrently staged to host memory (paper: bounding
        # in-flight shards protects host RAM against slow backends).
        concurrency: int = 16
        process_index: int = 0
        process_count: int = 1

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._save_thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save

    @staticmethod
    def _flatten(state: Any) -> Dict[str, Any]:
        """Flattens ANY pytree (dicts, tuples, NamedTuples) to {path: leaf}."""
        leaves = jax.tree_util.tree_flatten_with_path(state)[0]
        return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}

    @no_context
    def save(self, step: int, state: Any):
        self.wait()
        cfg = self.config
        flat = self._flatten(state)
        # Data-sharded assignment: leaf i -> process (i % process_count).
        mine = {k: v for i, (k, v) in enumerate(sorted(flat.items()))
                if i % cfg.process_count == cfg.process_index}
        staged: Dict[str, np.ndarray] = {}
        sem = threading.Semaphore(cfg.concurrency)
        for k, v in mine.items():
            with sem:
                staged[k] = np.asarray(v)

        def _write():
            step_dir = os.path.join(cfg.directory, f"step_{step:08d}")
            os.makedirs(step_dir, exist_ok=True)
            shard_path = os.path.join(step_dir, f"shard_{cfg.process_index}.npz")
            np.savez(shard_path, **{k.replace("/", "|"): v for k, v in staged.items()})
            if cfg.process_index == 0:
                index = {
                    "step": step,
                    "keys": sorted(flat.keys()),
                    "process_count": cfg.process_count,
                    "created": time.time(),
                }
                with open(os.path.join(step_dir, "index.json"), "w") as f:
                    json.dump(index, f)
                # Commit marker makes partially-written checkpoints invisible.
                with open(os.path.join(step_dir, "COMMITTED"), "w") as f:
                    f.write("ok")
            self._gc()

        if cfg.async_save:
            self._save_thread = threading.Thread(target=_write, daemon=True)
            self._save_thread.start()
        else:
            _write()

    @no_context
    def wait(self):
        if self._save_thread is not None:
            self._save_thread.join()
            self._save_thread = None

    # --------------------------------------------------------------- restore

    @no_context
    def latest_step(self) -> Optional[int]:
        cfg = self.config
        if not os.path.isdir(cfg.directory):
            return None
        steps = []
        for d in os.listdir(cfg.directory):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(cfg.directory, d, "COMMITTED")):
                steps.append(int(d[len("step_"):]))
        return max(steps) if steps else None

    @no_context
    def restore(self, step: Optional[int] = None, *, like: Optional[Any] = None) -> Any:
        cfg = self.config
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"No committed checkpoint in {cfg.directory}")
        step_dir = os.path.join(cfg.directory, f"step_{step:08d}")
        with open(os.path.join(step_dir, "index.json")) as f:
            index = json.load(f)
        flat: Dict[str, np.ndarray] = {}
        for p in range(index["process_count"]):
            shard_path = os.path.join(step_dir, f"shard_{p}.npz")
            with np.load(shard_path) as z:
                for k in z.files:
                    flat[k.replace("|", "/")] = z[k]
        missing = set(index["keys"]) - set(flat)
        if missing:
            raise ValueError(f"Checkpoint step {step} missing leaves: {sorted(missing)[:5]}")
        if like is None:
            # Structure-free restore: flat {path: array} dict.
            return {k: jnp.asarray(v) for k, v in flat.items()}
        ref_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, ref_leaf in ref_paths:
            key = jax.tree_util.keystr(path)
            if key not in flat:
                raise ValueError(f"Checkpoint step {step} missing leaf {key}")
            leaves.append(jnp.asarray(flat[key], dtype=ref_leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    # ------------------------------------------------------------------- gc

    def _gc(self):
        cfg = self.config
        if not os.path.isdir(cfg.directory):
            return
        steps = sorted(
            int(d[len("step_"):]) for d in os.listdir(cfg.directory)
            if d.startswith("step_") and os.path.exists(
                os.path.join(cfg.directory, d, "COMMITTED")))
        for s in steps[:-cfg.keep_last_n] if cfg.keep_last_n > 0 else []:
            shutil.rmtree(os.path.join(cfg.directory, f"step_{s:08d}"),
                          ignore_errors=True)
