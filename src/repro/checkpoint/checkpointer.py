"""Checkpointer v2: data-sharded, async, fault-tolerant serialization (§5).

Paper-faithful properties, adapted to a single-host test substrate:

* **Data-sharded serialization** — leaves are partitioned across processes by
  a deterministic assignment (rather than "rank 0 writes everything").
* **Async saves with off-thread staging** — ``save()`` takes a cheap
  device-side snapshot (safe against the trainer donating the live buffers
  into the next step) and returns; device→host staging AND the file write
  happen in a background thread, with at most ``concurrency`` leaves staged
  concurrently. The training thread stalls only for the snapshot plus any
  still-in-flight previous save.
* **Error propagation** — a failure in the background write re-raises from
  ``wait()`` and from the next ``save()``; it is never swallowed by a daemon
  thread.
* **Commit barrier** — ``COMMITTED`` is written by process 0 only after
  *every* process's shard file exists (shards are written atomically via
  tmp+rename, so existence implies completeness). Readers only ever see
  fully-committed steps.
* **Checkpoint tiers** — besides the durable directory tier, the newest
  staged state is retained in host memory; ``emergency_save()`` flushes it
  (or a freshly passed state) synchronously — the preemption-signal path.
* **Aux state** — small JSON-serializable per-process state (e.g. the input
  iterator's cursor) rides along with each step so restore is exactly-once
  w.r.t. data.

Format: <dir>/step_<k>/shard_<p>.npz + aux_<p>.json + index.json.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import REQUIRED, Required, config_class
from repro.core.module import Module, no_context

__all__ = ["Checkpointer", "CheckpointWriteError"]


class CheckpointWriteError(RuntimeError):
    """An async checkpoint write failed; raised from ``wait()``/``save()``."""


class Checkpointer(Module):
    @config_class
    class Config(Module.Config):
        directory: Required[str] = REQUIRED
        keep_last_n: int = 3
        async_save: bool = True
        # Max leaves concurrently staged device->host (bounds peak host RAM
        # against slow backends; enforced by the staging thread pool).
        concurrency: int = 16
        process_index: int = 0
        process_count: int = 1
        # How long process 0 waits for the other processes' shards before
        # declaring the commit barrier failed.
        commit_timeout_s: float = 60.0
        # Barrier budget for emergency (preemption) saves: must fit inside
        # the scheduler's grace window — a peer that died before writing its
        # shard must not stall process 0 into a SIGKILL.
        emergency_commit_timeout_s: float = 5.0
        # Keep the newest staged state in host memory as a last-resort tier
        # (flushed by emergency_save() on preemption).
        memory_tier: bool = True

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._save_thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._aborted = False
        # Newest staged state: (step, staged_flat, all_keys, aux).
        self._memory: Optional[Tuple[int, Dict[str, np.ndarray], List[str],
                                     Optional[dict]]] = None
        self._memory_lock = threading.Lock()
        # Long-lived staging pool (lazy): its worker count IS the bound on
        # concurrent device->host transfers; workers exit when the
        # checkpointer is GC'd or the interpreter shuts down.
        self._stage_pool: Optional[ThreadPoolExecutor] = None

    # ------------------------------------------------------------------ save

    @staticmethod
    def _flatten(state: Any) -> Dict[str, Any]:
        """Flattens ANY pytree (dicts, tuples, NamedTuples) to {path: leaf}."""
        leaves = jax.tree_util.tree_flatten_with_path(state)[0]
        return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}

    def _snapshot(self, leaf: Any) -> Any:
        """Device-side copy decoupling the checkpoint from buffer donation:
        the trainer donates the live state into the next step, so the
        background thread must never read the original buffers."""
        if isinstance(leaf, jax.Array):
            return leaf.copy()
        return np.array(leaf, copy=True)

    def _to_host(self, leaf: Any) -> np.ndarray:
        """Device->host transfer of one leaf (runs on a staging worker)."""
        return np.asarray(leaf)

    def _stage(self, snap: Dict[str, Any]) -> Dict[str, np.ndarray]:
        """Stages all leaves to host with at most ``concurrency`` transfers
        in flight (bounded by the pool's worker count — unlike the old
        per-iteration ``with sem:`` which never had two acquires alive)."""
        if self._stage_pool is None:
            self._stage_pool = ThreadPoolExecutor(
                max_workers=max(self.config.concurrency, 1),
                thread_name_prefix="ckpt-stage")
        hosted = self._stage_pool.map(self._to_host, snap.values())
        return dict(zip(snap.keys(), hosted))

    def _shard_and_snapshot(self, state: Any):
        """(this process's leaves, snapshotted; all leaf keys) — the ONE
        sharding rule both save paths must share: leaf i -> process
        (i % process_count)."""
        cfg = self.config
        flat = self._flatten(state)
        snap = {k: self._snapshot(v)
                for i, (k, v) in enumerate(sorted(flat.items()))
                if i % cfg.process_count == cfg.process_index}
        return snap, sorted(flat.keys())

    def _raise_pending_error(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise CheckpointWriteError(
                f"async checkpoint write failed: {err!r}") from err

    @no_context
    def save(self, step: int, state: Any, *, aux: Optional[dict] = None):
        """Checkpoints ``state`` (any pytree of arrays) as ``step``.

        Async mode returns after a device-side snapshot; staging + write
        happen in the background. A failure of the *previous* async save
        raises here (and from ``wait()``) — errors are never silent.
        """
        if self._aborted:
            # 'Errors are never silent': an aborted (dead-process) instance
            # must not accept saves it would silently drop.
            raise CheckpointWriteError(
                "save() on an abort()-ed checkpointer; it simulates a dead "
                "process and can never commit")
        self.wait()  # bound in-flight saves to one; surfaces prior errors
        cfg = self.config
        snap, all_keys = self._shard_and_snapshot(state)

        def _write():
            try:
                staged = self._stage(snap)
                if cfg.memory_tier:
                    with self._memory_lock:
                        self._memory = (step, staged, all_keys, aux)
                self._write_step(step, staged, all_keys, aux)
                self._gc()
            except BaseException as e:  # noqa: BLE001 — re-raised at wait()
                self._error = e

        if cfg.async_save:
            self._save_thread = threading.Thread(
                target=_write, daemon=True, name=f"ckpt-save-{step}")
            self._save_thread.start()
        else:
            _write()
            self._raise_pending_error()

    def _write_step(self, step: int, staged: Dict[str, np.ndarray],
                    all_keys: List[str], aux: Optional[dict],
                    commit_timeout_s: Optional[float] = None):
        """Writes this process's shard (+aux), then commits (process 0) or
        awaits process 0's COMMITTED marker (everyone else) — the barrier
        is observed on ALL ranks, so a dead committer surfaces as a loud
        CheckpointWriteError everywhere instead of a silent half-commit."""
        cfg = self.config
        step_dir = os.path.join(cfg.directory, f"step_{step:08d}")
        os.makedirs(step_dir, exist_ok=True)
        if self._aborted:
            return
        # Aux BEFORE shard: the shard file is this process's "done" signal
        # to the commit barrier, so everything riding along must already be
        # in place when it appears (lets the committer clean stale tmp files
        # without racing an in-flight peer).
        if aux is not None:
            aux_path = os.path.join(step_dir, f"aux_{cfg.process_index}.json")
            with open(aux_path + ".tmp", "w") as f:
                json.dump(aux, f)
            os.replace(aux_path + ".tmp", aux_path)
        shard_path = os.path.join(step_dir, f"shard_{cfg.process_index}.npz")
        # Atomic write: a shard file that EXISTS is complete, which is what
        # lets the commit barrier treat existence as the per-process signal.
        # (.npz suffix so np.savez doesn't append one of its own.)
        tmp_path = shard_path + ".tmp.npz"
        np.savez(tmp_path,
                 **{k.replace("/", "|"): v for k, v in staged.items()})
        os.replace(tmp_path, shard_path)
        if cfg.process_index == 0:
            self._commit(step, step_dir, all_keys,
                         timeout_s=commit_timeout_s)
        else:
            self._await_commit(step, step_dir, timeout_s=commit_timeout_s)

    def _commit(self, step: int, step_dir: str, all_keys: List[str],
                timeout_s: Optional[float] = None):
        """Commit barrier: COMMITTED appears only after ALL shards exist.

        The old code committed right after process 0's own shard, making a
        checkpoint visible while other processes were still writing — a
        restore could then fail (or worse, silently read a stale shard left
        over from GC races).
        """
        cfg = self.config
        timeout_s = cfg.commit_timeout_s if timeout_s is None else timeout_s
        deadline = time.monotonic() + timeout_s
        wanted = [os.path.join(step_dir, f"shard_{p}.npz")
                  for p in range(cfg.process_count)]
        while not all(os.path.exists(p) for p in wanted):
            if self._aborted:
                return
            if time.monotonic() > deadline:
                missing = [p for p in wanted if not os.path.exists(p)]
                raise CheckpointWriteError(
                    f"commit barrier timed out after {timeout_s}s "
                    f"at step {step}: missing shards {missing}")
            time.sleep(0.02)
        if self._aborted:
            return
        # Every rank's shard (and therefore aux) is in place; anything else
        # in the step dir is debris from a previous torn attempt — stale
        # ``*.tmp*`` files a mid-save SIGKILL left behind, or shards/aux of
        # ranks beyond this fleet's world size (the same step re-saved
        # after a restart at a smaller world size). Clean it BEFORE the
        # marker so a COMMITTED step dir is exactly its manifest.
        for fname in os.listdir(step_dir):
            stale = ".tmp" in fname
            m = re.fullmatch(r"(?:shard|aux)_(\d+)\.(?:npz|json)", fname)
            if m and int(m.group(1)) >= cfg.process_count:
                stale = True
            if stale:
                try:
                    os.remove(os.path.join(step_dir, fname))
                except OSError:
                    pass
        index = {
            "step": step,
            "keys": all_keys,
            "process_count": cfg.process_count,
            "created": time.time(),
        }
        index_path = os.path.join(step_dir, "index.json")
        with open(index_path + ".tmp", "w") as f:
            json.dump(index, f)
        os.replace(index_path + ".tmp", index_path)
        # Commit marker makes partially-written checkpoints invisible.
        with open(os.path.join(step_dir, "COMMITTED"), "w") as f:
            f.write("ok")

    def _await_commit(self, step: int, step_dir: str,
                      timeout_s: Optional[float] = None):
        """Non-committer side of the barrier: wait for process 0's
        COMMITTED marker. A timeout means the committer died (or a peer
        never delivered its shard, so process 0 itself timed out) — raise
        so every rank aborts the save loudly rather than training on top of
        a checkpoint that never became durable."""
        cfg = self.config
        timeout_s = cfg.commit_timeout_s if timeout_s is None else timeout_s
        deadline = time.monotonic() + timeout_s
        marker = os.path.join(step_dir, "COMMITTED")
        while not os.path.exists(marker):
            if self._aborted:
                return
            if time.monotonic() > deadline:
                raise CheckpointWriteError(
                    f"commit barrier timed out after {timeout_s}s at step "
                    f"{step}: process {cfg.process_index} wrote its shard "
                    "but COMMITTED never appeared (committer dead?)")
            time.sleep(0.02)

    @no_context
    def wait(self):
        """Blocks on the in-flight async save; re-raises its error, if any."""
        if self._save_thread is not None:
            self._save_thread.join()
            self._save_thread = None
        self._raise_pending_error()

    @no_context
    def abort(self):
        """Simulates process death: the in-flight write must never commit.
        (Used by the supervisor's kill-during-async-save injection; a real
        SIGKILL gives the same observable outcome because shard writes are
        atomic and COMMITTED is written last.)

        Joins the write thread before returning so callers can read
        ``latest_step()`` without racing a still-live committer, and shuts
        the staging pool down (the instance is dead)."""
        self._aborted = True
        if (self._save_thread is not None
                and self._save_thread is not threading.current_thread()):
            self._save_thread.join()
            self._save_thread = None
        self._error = None  # a dead process reports nothing
        if self._stage_pool is not None:
            self._stage_pool.shutdown(wait=False)
            self._stage_pool = None

    # ----------------------------------------------------------- emergency

    @no_context
    def emergency_save(self, step: Optional[int] = None, state: Any = None,
                       *, aux: Optional[dict] = None) -> Optional[int]:
        """Synchronous last-resort save for the preemption path (§5).

        With ``state``: stage + write + commit NOW, bypassing the async
        machinery. Without: flush the in-memory tier (the newest staged
        state) to disk if it is not already committed. Returns the step
        committed, or None if nothing was written (nothing to flush, or
        this checkpointer was ``abort()``-ed — a dead process must never
        claim a commit).
        """
        cfg = self.config
        if self._aborted:
            return None
        try:
            self.wait()
        except CheckpointWriteError:
            pass  # best effort: the emergency state supersedes the failure
        if state is not None:
            assert step is not None, "emergency_save(state=...) needs step"
            snap, all_keys = self._shard_and_snapshot(state)
            self._write_step(step, self._stage(snap), all_keys, aux,
                             commit_timeout_s=cfg.emergency_commit_timeout_s)
            self._gc()
            self._raise_pending_error()
            return step if self._is_committed(step) else None
        with self._memory_lock:
            memory = self._memory
        if memory is None:
            return None
        m_step, staged, all_keys, m_aux = memory
        if not self._is_committed(m_step):
            self._write_step(m_step, staged, all_keys, m_aux,
                             commit_timeout_s=cfg.emergency_commit_timeout_s)
        return m_step if self._is_committed(m_step) else None

    def _is_committed(self, step: int) -> bool:
        """Only the COMMITTED marker makes a step resumable: a non-zero
        process that wrote its shard must not claim a commit that process 0
        (the committer) may never have made."""
        return os.path.exists(os.path.join(
            self.config.directory, f"step_{step:08d}", "COMMITTED"))

    # --------------------------------------------------------------- restore

    @no_context
    def latest_step(self) -> Optional[int]:
        cfg = self.config
        if not os.path.isdir(cfg.directory):
            return None
        steps = []
        for d in os.listdir(cfg.directory):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(cfg.directory, d, "COMMITTED")):
                steps.append(int(d[len("step_"):]))
        return max(steps) if steps else None

    @no_context
    def restore(self, step: Optional[int] = None, *, like: Optional[Any] = None) -> Any:
        cfg = self.config
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"No committed checkpoint in {cfg.directory}")
        step_dir = os.path.join(cfg.directory, f"step_{step:08d}")
        with open(os.path.join(step_dir, "index.json")) as f:
            index = json.load(f)
        flat: Dict[str, np.ndarray] = {}
        for p in range(index["process_count"]):
            shard_path = os.path.join(step_dir, f"shard_{p}.npz")
            with np.load(shard_path) as z:
                for k in z.files:
                    flat[k.replace("|", "/")] = z[k]
        missing = set(index["keys"]) - set(flat)
        if missing:
            raise ValueError(f"Checkpoint step {step} missing leaves: {sorted(missing)[:5]}")
        if like is None:
            # Structure-free restore: flat {path: array} dict.
            return {k: jnp.asarray(v) for k, v in flat.items()}
        ref_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        for path, ref_leaf in ref_paths:
            key = jax.tree_util.keystr(path)
            if key not in flat:
                raise ValueError(f"Checkpoint step {step} missing leaf {key}")
            arr = flat[key]
            if tuple(arr.shape) != tuple(ref_leaf.shape):
                raise ValueError(
                    f"Checkpoint step {step} leaf {key} has shape "
                    f"{tuple(arr.shape)}, expected {tuple(ref_leaf.shape)} — "
                    "restoring into a differently-shaped model?")
            leaves.append(jnp.asarray(arr, dtype=ref_leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    @no_context
    def restore_aux(self, step: Optional[int] = None, *,
                    process_index: Optional[int] = None) -> Optional[dict]:
        """Aux state for ``step`` (None if absent — e.g. a checkpoint
        written before aux existed). ``process_index`` selects another
        rank's aux — the resharding-restore path reads rank 0's (identical
        across ranks under the elastic global-view input contract, and the
        only one guaranteed to exist when the committing world size was
        smaller than this one)."""
        cfg = self.config
        if step is None:
            step = self.latest_step()
            if step is None:
                return None
        p = cfg.process_index if process_index is None else process_index
        aux_path = os.path.join(cfg.directory, f"step_{step:08d}",
                                f"aux_{p}.json")
        if not os.path.exists(aux_path):
            return None
        with open(aux_path) as f:
            return json.load(f)

    # ------------------------------------------------------------------- gc

    def _gc(self):
        """Deletes old step dirs after a successful commit so long elastic
        runs can't fill the disk. Rank 0 only (one deleter per fleet — peers
        racing the same rmtree would trip each other); never the newest
        COMMITTED; ``ignore_errors`` keeps it tolerant of concurrent readers
        holding files open. Uncommitted dirs strictly OLDER than the newest
        COMMITTED step are crash debris (a save that died mid-write and was
        superseded) and are collected too — an uncommitted dir at or beyond
        the newest commit may be an in-flight save and is left alone."""
        cfg = self.config
        if cfg.process_index != 0 or not os.path.isdir(cfg.directory):
            return
        all_steps = sorted(
            int(d[len("step_"):]) for d in os.listdir(cfg.directory)
            if d.startswith("step_"))
        committed = [s for s in all_steps if os.path.exists(os.path.join(
            cfg.directory, f"step_{s:08d}", "COMMITTED"))]
        doomed = set(committed[:-cfg.keep_last_n]
                     if cfg.keep_last_n > 0 else [])
        if committed:
            doomed.update(s for s in all_steps
                          if s not in committed and s < committed[-1])
        for s in doomed:
            shutil.rmtree(os.path.join(cfg.directory, f"step_{s:08d}"),
                          ignore_errors=True)
