"""internlm2-1.8b [dense] 24L d=2048 16H (GQA kv=8) d_ff=8192 vocab=92544.

GQA [arXiv:2403.17297].
"""

from repro.configs import common as c

ARCH_ID = "internlm2-1.8b"


def _model(L, d, Hq, Hkv, hd, dff, vocab, remat="full"):
    attn = c.attention_cfg(num_heads=Hq, num_kv_heads=Hkv, head_dim=hd,
                           rope_theta=1e6)
    layer = c.layer_cfg(d, attn, c.ffn_cfg(dff))
    dec = c.decoder_cfg(vocab_size=vocab, dim=d,
                        stack=c.repeat_cfg(layer, L, remat=remat),
                        tied_embeddings=False)
    return c.lm_cfg(dec)


def make_model():
    return _model(24, 2048, 16, 8, 128, 8192, 92544)


def make_smoke():
    return _model(2, 128, 4, 2, 32, 256, 128, remat=None)


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="dense", citation="arXiv:2403.17297",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=92544, model_dim=2048,
    skip_shapes={"long_500k": "pure full-attention dense arch; no sub-quadratic variant configured"},
)
