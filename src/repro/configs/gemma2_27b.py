"""gemma2-27b [dense] 46L d=4608 32H (GQA kv=16) d_ff=36864 vocab=256000.

Local(4096)/global alternating attention, attn-logit softcap 50, final-logit
softcap 30, sandwich (post) norms, GeGLU, sqrt(d)-scaled embeddings, RMSNorm
with unit offset, query scale (d/H)^-0.5 [arXiv:2408.00118].

The (local, global) pair is a heterogeneous Block scanned 23x. Local layers
bound their decode cache at 4096 tokens, so gemma2 RUNS long_500k (global
layers keep the full 524k cache, sharded over the data axis).
"""

from repro.configs import common as c
from repro.layers import RMSNorm

ARCH_ID = "gemma2-27b"
WINDOW = 4096


def _model(blocks, d, Hq, Hkv, hd, dff, vocab, remat="full"):
    norm = RMSNorm.default_config().set(unit_offset=True)
    q_scale = (d / Hq) ** -0.5

    def attn(window):
        return c.attention_cfg(
            num_heads=Hq, num_kv_heads=Hkv, head_dim=hd, rope_theta=10000.0,
            sliding_window=window, logit_softcap=50.0, query_scale=q_scale)

    geglu = ("linear", "nn.gelu_tanh")
    local = c.layer_cfg(d, attn(WINDOW), c.ffn_cfg(dff, geglu),
                        norm=norm, post_norms=True)
    glob = c.layer_cfg(d, attn(None), c.ffn_cfg(dff, geglu),
                       norm=norm, post_norms=True)
    stack = c.pattern_stack_cfg([local, glob], blocks, remat=remat)
    dec = c.decoder_cfg(vocab_size=vocab, dim=d, stack=stack,
                        tied_embeddings=True, logits_softcap=30.0,
                        scale_embeddings=True,
                        final_norm=norm.clone())
    return c.lm_cfg(dec)


def make_model():
    return _model(23, 4608, 32, 16, 128, 36864, 256000)


def make_smoke():
    return _model(1, 128, 4, 2, 32, 256, 128, remat=None)  # 1 block = 2 layers


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="dense", citation="arXiv:2408.00118",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=256000, model_dim=4608,
)
