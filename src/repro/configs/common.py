"""Shared config builders for the assigned architectures.

Every architecture is a *config program* over the layer library — no
model-specific layer classes exist anywhere (the paper's central claim).
Builders only choose child configs and dims; sharding defaults adapt to
divisibility against the production mesh (model axis = 16).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.config import ConfigBase
from repro.layers import (
    CausalLM,
    Decoder,
    FeedForward,
    MaskedLM,
    MultiheadAttention,
    Repeat,
    RMSNorm,
    TransformerLayer,
)
from repro.layers.basic import LayerNorm, Linear
from repro.layers.moe import MoELayer, ResidualMoE
from repro.layers.rope import RotaryEmbedding
from repro.layers.rwkv import RWKV6Block
from repro.layers.ssm import MambaMixer
from repro.layers.transformer import Block

MODEL_AXIS = 16  # production mesh model-axis size


def kv_cache_spec(num_kv_heads: int, head_dim: int):
    """(B, T, Hkv, D) cache sharding: heads on "model" when divisible;
    otherwise shard the SEQUENCE dim over "model" (flash-decoding layout —
    per-shard partial softmax, GSPMD inserts the combine)."""
    if num_kv_heads % MODEL_AXIS == 0:
        return (("pod", "data"), None, "model", None)
    return (("pod", "data"), "model", None, None)


def expert_specs(num_experts: int):
    """MoE (E, D, H) weight + dispatch sharding: expert parallelism over
    "model" when divisible (jamba 16e, arctic 128e); otherwise replicate E
    and tensor-shard the expert hidden dim (mixtral 8e)."""
    if num_experts % MODEL_AXIS == 0:
        return dict(
            up_weight_partition=("model", "data", None),
            down_weight_partition=("model", None, "data"),
            dispatch_partition=(("pod", "data"), None, "model", None),
            expert_partition=("model", ("pod", "data"), None, None),
        )
    return dict(
        up_weight_partition=(None, "data", "model"),
        down_weight_partition=(None, "model", "data"),
        dispatch_partition=(("pod", "data"), None, None, None),
        expert_partition=(None, ("pod", "data"), None, "model"),
    )


def attention_cfg(
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: Optional[int] = None,
    qkv_bias: bool = False,
    rope_theta: Optional[float] = 10000.0,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    query_scale: Optional[float] = None,
) -> MultiheadAttention.Config:
    cfg = MultiheadAttention.default_config().set(
        num_heads=num_heads,
        num_kv_heads=num_kv_heads,
        qkv_bias=qkv_bias,
        causal=causal,
        sliding_window=sliding_window,
        logit_softcap=logit_softcap,
        query_scale=query_scale,
    )
    if head_dim is not None:
        cfg.set(head_dim=head_dim)
    if rope_theta is None:
        cfg.set(rope=None)
    else:
        cfg.rope = RotaryEmbedding.default_config().set(theta=rope_theta)
    return cfg


def ffn_cfg(hidden_dim: int, activation=("linear", "nn.silu")) -> FeedForward.Config:
    return FeedForward.default_config().set(hidden_dim=hidden_dim,
                                            activation=activation)


def moe_cfg(hidden_dim: int, num_experts: int, top_k: int = 2,
            capacity_factor: float = 2.0,
            activation=("linear", "nn.silu")) -> MoELayer.Config:
    return MoELayer.default_config().set(
        hidden_dim=hidden_dim, num_experts=num_experts, top_k=top_k,
        capacity_factor=capacity_factor, activation=activation,
        **expert_specs(num_experts))


def layer_cfg(
    dim: int,
    attention: ConfigBase,
    feed_forward: ConfigBase,
    *,
    norm: Optional[ConfigBase] = None,
    post_norms: bool = False,
) -> TransformerLayer.Config:
    cfg = TransformerLayer.default_config().set(
        input_dim=dim,
        self_attention=attention,
        feed_forward=feed_forward,
        use_post_attention_norm=post_norms,
        use_post_ffn_norm=post_norms,
    )
    if attention is not None and "kv_cache_partition" in attention.keys():
        nh = attention.num_kv_heads or attention.num_heads
        hd = attention.head_dim or dim // attention.num_heads
        attention.set(kv_cache_partition=kv_cache_spec(nh, hd))
    if norm is not None:
        cfg.norm = norm
    return cfg


def decoder_cfg(
    *,
    vocab_size: int,
    dim: int,
    stack: ConfigBase,
    tied_embeddings: bool = True,
    logits_softcap: Optional[float] = None,
    scale_embeddings: bool = False,
    final_norm: Optional[ConfigBase] = None,
) -> Decoder.Config:
    cfg = Decoder.default_config().set(
        vocab_size=vocab_size, dim=dim, stack=stack,
        logits_softcap=logits_softcap)
    # Vocab dims only shard when divisible by the model axis (hubert: 504).
    vocab_ok = vocab_size % MODEL_AXIS == 0
    cfg.emb.set(scale_by_sqrt_dim=scale_embeddings,
                weight_partition=("model", "data") if vocab_ok else (None, "model"))
    cfg.set(logits_partition=(("pod", "data"), None, "model" if vocab_ok else None))
    if not tied_embeddings:
        cfg.lm_head = Linear.default_config().set(
            weight_partition=("data", "model") if vocab_ok else ("model", None))
    if final_norm is not None:
        cfg.final_norm = final_norm
    return cfg


def lm_cfg(decoder: Decoder.Config, z_loss: float = 0.0) -> CausalLM.Config:
    return CausalLM.default_config().set(name="model", decoder=decoder,
                                         z_loss_scale=z_loss)


def repeat_cfg(layer: ConfigBase, num_layers: int,
               remat: Optional[str] = "full") -> Repeat.Config:
    return Repeat.default_config().set(layer=layer, num_layers=num_layers,
                                       remat_policy=remat)


def pattern_stack_cfg(pattern: List[ConfigBase], num_blocks: int,
                      remat: Optional[str] = "full") -> Repeat.Config:
    """Repeat over a heterogeneous super-block (jamba, gemma2)."""
    block = Block.default_config().set(layers=pattern)
    return Repeat.default_config().set(layer=block, num_layers=num_blocks,
                                       remat_policy=remat)


# --------------------------------------------------------------------------
# Input shapes (assigned) + input spec helpers
# --------------------------------------------------------------------------

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}


def lm_input_specs(shape: str, *, vocab_size: int, modality: str = "text",
                   model_dim: Optional[int] = None, num_patches: int = 256
                   ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    For decode shapes this is the *step* input; the KV-cache state specs are
    derived separately via eval_shape of init_states.
    """
    info = SHAPES[shape]
    B, S = info["global_batch"], info["seq_len"]
    i32 = jnp.int32
    if info["kind"] in ("train", "prefill"):
        if modality == "audio":
            specs = {
                "input_embeddings": jax.ShapeDtypeStruct((B, S, model_dim), jnp.bfloat16),
                "mask_positions": jax.ShapeDtypeStruct((B, S), jnp.bool_),
                "labels": jax.ShapeDtypeStruct((B, S), i32),
            }
            if info["kind"] == "prefill":
                specs.pop("labels")
                specs.pop("mask_positions")
            return specs
        specs = {"input_ids": jax.ShapeDtypeStruct((B, S), i32)}
        if info["kind"] == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        if modality == "vlm":
            # Stub frontend: precomputed patch embeddings (assignment carve-out).
            specs["input_embeddings"] = jax.ShapeDtypeStruct(
                (B, num_patches, model_dim), jnp.bfloat16)
        return specs
    # decode: one new token against a seq_len cache
    return {"ids_step": jax.ShapeDtypeStruct((B, 1), i32)}


@dataclasses.dataclass
class ArchSpec:
    """Everything the launcher/benchmarks need to know about one arch."""

    arch_id: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio
    citation: str
    make_model: Any  # () -> model config (full size)
    make_smoke: Any  # () -> reduced model config
    vocab_size: int
    model_dim: int
    modality: str = "text"
    # Shapes this arch runs, with skip reasons for the rest.
    skip_shapes: Dict[str, str] = dataclasses.field(default_factory=dict)
    # 6*N*D model flops: N = active params (MoE: routed active only).
    active_params: Optional[int] = None
    total_params: Optional[int] = None

    def input_specs(self, shape: str):
        num_patches = 256 if self.modality == "vlm" else 0
        return lm_input_specs(shape, vocab_size=self.vocab_size,
                              modality=self.modality, model_dim=self.model_dim,
                              num_patches=num_patches)

    def supports(self, shape: str) -> bool:
        return shape not in self.skip_shapes
