"""phi-3-vision-4.2b [vlm] 32L d=3072 32H (kv=32) d_ff=8192 vocab=32064.

phi3-mini decoder + CLIP frontend [hf:microsoft/Phi-3-vision-128k-instruct].

Assignment carve-out: the vision encoder (CLIP ViT + projector) is a STUB —
``input_specs`` provides pre-projected patch embeddings (B, 256, 3072) that
the decoder consumes as a sequence prefix (Decoder._embed merge).
"""

from repro.configs import common as c

ARCH_ID = "phi-3-vision-4.2b"


def _model(L, d, Hq, Hkv, hd, dff, vocab, remat="full"):
    attn = c.attention_cfg(num_heads=Hq, num_kv_heads=Hkv, head_dim=hd,
                           rope_theta=10000.0)
    layer = c.layer_cfg(d, attn, c.ffn_cfg(dff))
    dec = c.decoder_cfg(vocab_size=vocab, dim=d,
                        stack=c.repeat_cfg(layer, L, remat=remat),
                        tied_embeddings=False)
    return c.lm_cfg(dec)


def make_model():
    return _model(32, 3072, 32, 32, 96, 8192, 32064)


def make_smoke():
    return _model(2, 128, 4, 4, 32, 256, 128, remat=None)


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="vlm", citation="hf:microsoft/Phi-3-vision-128k-instruct",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=32064, model_dim=3072, modality="vlm",
    skip_shapes={"long_500k": "pure full-attention dense decoder; no sub-quadratic variant configured"},
)
