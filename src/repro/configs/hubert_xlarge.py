"""hubert-xlarge [audio] 48L d=1280 16H (kv=16) d_ff=5120 vocab=504.

Encoder-only masked-unit prediction, same backbone as wav2vec2
[arXiv:2106.07447].

Assignment carve-out: the mel-spectrogram + conv feature extractor (and its
conv positional embedding) is a STUB — ``input_specs`` provides frame
embeddings (B, S, 1280). We implement the bidirectional transformer encoder
+ masked prediction head (MaskedLM).

Encoder-only => no decode step: decode_32k and long_500k are skipped
(documented in DESIGN.md); prefill_32k runs as the batched encoder forward.
"""

from repro.configs import common as c
from repro.layers import MaskedLM
from repro.layers.basic import LayerNorm

ARCH_ID = "hubert-xlarge"


def _model(L, d, H, dff, vocab, remat="full"):
    attn = c.attention_cfg(num_heads=H, num_kv_heads=H, rope_theta=None,
                           causal=False)
    norm = LayerNorm.default_config()
    layer = c.layer_cfg(d, attn, c.ffn_cfg(dff, activation="nn.gelu"), norm=norm)
    dec = c.decoder_cfg(vocab_size=vocab, dim=d,
                        stack=c.repeat_cfg(layer, L, remat=remat),
                        tied_embeddings=False,
                        final_norm=norm.clone())
    return MaskedLM.default_config().set(name="model", decoder=dec, dim=d)


def make_model():
    return _model(48, 1280, 16, 5120, 504)


def make_smoke():
    return _model(2, 128, 4, 256, 64, remat=None)


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="audio", citation="arXiv:2106.07447",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=504, model_dim=1280, modality="audio",
    skip_shapes={
        "decode_32k": "encoder-only architecture: no autoregressive decode step",
        "long_500k": "encoder-only architecture: no autoregressive decode step",
    },
)
