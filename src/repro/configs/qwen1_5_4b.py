"""qwen1.5-4b [dense] 40L d=2560 20H (GQA kv=20 == MHA) d_ff=6912 vocab=151936.

QKV bias [hf:Qwen/Qwen1.5-0.5B family].
"""

from repro.configs import common as c

ARCH_ID = "qwen1.5-4b"


def _model(L, d, Hq, Hkv, hd, dff, vocab, remat="full"):
    attn = c.attention_cfg(num_heads=Hq, num_kv_heads=Hkv, head_dim=hd,
                           qkv_bias=True, rope_theta=1e6)
    layer = c.layer_cfg(d, attn, c.ffn_cfg(dff))
    dec = c.decoder_cfg(vocab_size=vocab, dim=d,
                        stack=c.repeat_cfg(layer, L, remat=remat),
                        tied_embeddings=False)
    return c.lm_cfg(dec)


def make_model():
    return _model(40, 2560, 20, 20, 128, 6912, 151936)


def make_smoke():
    return _model(2, 160, 4, 4, 40, 320, 128, remat=None)


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="dense", citation="hf:Qwen/Qwen1.5-0.5B",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=151936, model_dim=2560,
    skip_shapes={"long_500k": "pure full-attention dense arch; no sub-quadratic variant configured"},
)
