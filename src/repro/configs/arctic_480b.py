"""arctic-480b [moe] 35L d=7168 56H (GQA kv=8) d_ff=4864 vocab=32000.

128 experts top-2 PLUS a dense residual FFN in parallel
[hf:Snowflake/snowflake-arctic-base]. Composition: ResidualMoE(dense, moe)
— each child keeps its own encapsulated config. 128 experts shard 8-per-chip
over the 16-way model axis (expert parallelism).

Note: the assignment pins d_ff=4864; we use it for both the experts and the
dense residual branch (the hf card's dense/residual split is not re-derived
here).
"""

from repro.configs import common as c
from repro.layers.moe import ResidualMoE

ARCH_ID = "arctic-480b"


def _model(L, d, Hq, Hkv, hd, dff, vocab, E, remat="full"):
    attn = c.attention_cfg(num_heads=Hq, num_kv_heads=Hkv, head_dim=hd,
                           rope_theta=1e6)
    ff = ResidualMoE.default_config()
    ff.dense = c.ffn_cfg(dff)
    ff.moe = c.moe_cfg(dff, num_experts=E, top_k=2)
    layer = c.layer_cfg(d, attn, ff)
    dec = c.decoder_cfg(vocab_size=vocab, dim=d,
                        stack=c.repeat_cfg(layer, L, remat=remat),
                        tied_embeddings=False)
    return c.lm_cfg(dec)


def make_model():
    return _model(35, 7168, 56, 8, 128, 4864, 32000, E=128)


def make_smoke():
    return _model(2, 128, 4, 2, 32, 128, 128, E=4, remat=None)


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="moe", citation="hf:Snowflake/snowflake-arctic-base",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=32000, model_dim=7168,
    skip_shapes={"long_500k": "pure full-attention arch; no sub-quadratic variant configured"},
)
