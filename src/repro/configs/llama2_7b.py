"""llama2-7b — the paper's own evaluation model (Tables 3-4): 32L d=4096
32H MHA d_ff=11008 vocab=32000 [arXiv:2307.09288].

Not part of the assigned pool; included so the paper's performance tables
have a direct counterpart in benchmarks/.
"""

from repro.configs import common as c

ARCH_ID = "llama2-7b"


def _model(L, d, Hq, Hkv, hd, dff, vocab, remat="full"):
    attn = c.attention_cfg(num_heads=Hq, num_kv_heads=Hkv, head_dim=hd,
                           rope_theta=10000.0)
    layer = c.layer_cfg(d, attn, c.ffn_cfg(dff))
    dec = c.decoder_cfg(vocab_size=vocab, dim=d,
                        stack=c.repeat_cfg(layer, L, remat=remat),
                        tied_embeddings=False)
    return c.lm_cfg(dec)


def make_model():
    return _model(32, 4096, 32, 32, 128, 11008, 32000)


def make_smoke():
    return _model(2, 128, 4, 4, 32, 256, 128, remat=None)


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="dense", citation="arXiv:2307.09288",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=32000, model_dim=4096,
    skip_shapes={"long_500k": "pure full-attention dense arch; no sub-quadratic variant configured"},
)
