"""qwen2-1.5b [dense] 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

GQA with QKV bias, SwiGLU, tied embeddings [arXiv:2407.10671].
"""

from repro.configs import common as c

ARCH_ID = "qwen2-1.5b"


def _model(L, d, Hq, Hkv, hd, dff, vocab, remat="full"):
    attn = c.attention_cfg(num_heads=Hq, num_kv_heads=Hkv, head_dim=hd,
                           qkv_bias=True, rope_theta=1e6)
    layer = c.layer_cfg(d, attn, c.ffn_cfg(dff))
    dec = c.decoder_cfg(vocab_size=vocab, dim=d,
                        stack=c.repeat_cfg(layer, L, remat=remat),
                        tied_embeddings=True)
    return c.lm_cfg(dec)


def make_model():
    return _model(28, 1536, 12, 2, 128, 8960, 151936)


def make_smoke():
    return _model(2, 128, 4, 2, 32, 256, 128, remat=None)


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="dense", citation="arXiv:2407.10671",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=151936, model_dim=1536,
    skip_shapes={"long_500k": "pure full-attention dense arch; no sub-quadratic variant configured"},
)
