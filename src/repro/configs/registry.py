"""Architecture registry: ``--arch <id>`` resolution + param accounting."""

from __future__ import annotations

import importlib
from typing import Dict, List, Optional, Tuple

import jax

from repro.configs.common import SHAPES, ArchSpec
from repro.layers.base import ParameterSpec

_ARCH_MODULES = {
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "phi-3-vision-4.2b": "repro.configs.phi3_vision_4_2b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "arctic-480b": "repro.configs.arctic_480b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "rwkv6-7b": "repro.configs.rwkv6_7b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    # Paper's own eval model (extra, not in the assigned pool):
    "llama2-7b": "repro.configs.llama2_7b",
}

ASSIGNED_ARCHS: List[str] = [a for a in _ARCH_MODULES if a != "llama2-7b"]
ALL_ARCHS: List[str] = list(_ARCH_MODULES)
SHAPE_NAMES: List[str] = list(SHAPES)


def get_spec(arch_id: str) -> ArchSpec:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"Unknown arch {arch_id!r}; known: {ALL_ARCHS}")
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.SPEC


def param_counts(model_cfg) -> Tuple[int, int]:
    """(total_params, active_params). Active discounts MoE expert weights by
    top_k/num_experts (the 6*N_active*D convention for MoE FLOPs)."""
    model = model_cfg.clone(name="tmp").instantiate()
    specs = model.create_parameter_specs_recursively()
    flat = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda s: isinstance(s, ParameterSpec))[0]

    # Collect MoE (top_k, num_experts) by traversing the config.
    from repro.core.config import visit_config
    moe_ratio: Dict[str, float] = {}

    def visit(path, cfg):
        if type(cfg).__qualname__.startswith("MoELayer"):
            if "num_experts" in cfg.keys() and cfg.num_experts:
                moe_ratio["ratio"] = min(
                    moe_ratio.get("ratio", 1.0), cfg.top_k / cfg.num_experts)

    visit_config(model_cfg, visit)
    ratio = moe_ratio.get("ratio", 1.0)

    total = active = 0
    for path, spec in flat:
        n = 1
        for s in spec.shape:
            n *= int(s)
        total += n
        key = jax.tree_util.keystr(path)
        is_expert = ("moe" in key and ("'wi" in key or "'wo'" in key))
        active += int(n * ratio) if is_expert else n
    return total, active


def supported_pairs() -> List[Tuple[str, str]]:
    """All (arch, shape) pairs that run (vs documented skips)."""
    out = []
    for arch in ASSIGNED_ARCHS:
        spec = get_spec(arch)
        for shape in SHAPE_NAMES:
            if spec.supports(shape):
                out.append((arch, shape))
    return out


def skipped_pairs() -> List[Tuple[str, str, str]]:
    out = []
    for arch in ASSIGNED_ARCHS:
        spec = get_spec(arch)
        for shape, reason in spec.skip_shapes.items():
            out.append((arch, shape, reason))
    return out
