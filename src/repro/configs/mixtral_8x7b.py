"""mixtral-8x7b [moe] 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

8 experts top-2 (renormalized gates) + sliding-window attention (4096)
[arXiv:2401.04088]. MoE is a *drop-in* FeedForward replacement; with 8
experts (16-way model axis not divisible) the experts are replicated and
each expert's hidden dim is tensor-sharded instead — see
configs.common.expert_specs.

SWA means the decode cache is window-bounded, so this arch RUNS long_500k.
"""

from repro.configs import common as c

ARCH_ID = "mixtral-8x7b"
WINDOW = 4096


def _model(L, d, Hq, Hkv, hd, dff, vocab, E, remat="full"):
    attn = c.attention_cfg(num_heads=Hq, num_kv_heads=Hkv, head_dim=hd,
                           rope_theta=1e6, sliding_window=WINDOW)
    layer = c.layer_cfg(d, attn, c.moe_cfg(dff, num_experts=E, top_k=2))
    dec = c.decoder_cfg(vocab_size=vocab, dim=d,
                        stack=c.repeat_cfg(layer, L, remat=remat),
                        tied_embeddings=False)
    return c.lm_cfg(dec)


def make_model():
    return _model(32, 4096, 32, 8, 128, 14336, 32000, E=8)


def make_smoke():
    return _model(2, 128, 4, 2, 32, 256, 128, E=4, remat=None)


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="moe", citation="arXiv:2401.04088",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=32000, model_dim=4096,
)
