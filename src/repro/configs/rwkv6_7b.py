"""rwkv6-7b [ssm] 32L d=4096 (attention-free) d_ff=14336 vocab=65536.

Finch: data-dependent decay WKV6 [arXiv:2404.05892]. head_dim=64 (64 heads).
The stack is RWKV6Block (time-mix + channel-mix, both token-shift stateful).
O(1) decode state -> RUNS long_500k.
"""

from repro.configs import common as c
from repro.layers.rwkv import RWKV6Block

ARCH_ID = "rwkv6-7b"


def _model(L, d, dff, vocab, head_dim=64, lora=64, remat="full"):
    block = RWKV6Block.default_config().set(input_dim=d)
    block.time_mix.set(head_dim=head_dim, decay_lora_dim=lora)
    block.channel_mix.set(hidden_dim=dff)
    dec = c.decoder_cfg(vocab_size=vocab, dim=d,
                        stack=c.repeat_cfg(block, L, remat=remat),
                        tied_embeddings=False)
    return c.lm_cfg(dec)


def make_model():
    return _model(32, 4096, 14336, 65536)


def make_smoke():
    return _model(2, 128, 256, 128, head_dim=32, lora=8, remat=None)


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="ssm", citation="arXiv:2404.05892",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=65536, model_dim=4096,
)
