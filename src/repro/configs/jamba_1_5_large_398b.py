"""jamba-1.5-large-398b [hybrid] 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2 [arXiv:2403.19887].

Jamba interleave: 1 attention per 8 layers (attn at block index 4), MoE
FFN every other layer (odd indices). The 8-layer super-block is a
heterogeneous Block scanned 9x — the hybrid is pure config: Mamba is a
drop-in child where attention would be (token-mixer interface).

16 experts == model axis -> 1 expert per chip (expert parallelism).
Mamba state is O(1) per token, attention is 1/8 of layers, so jamba RUNS
long_500k.
"""

from repro.configs import common as c
from repro.layers.ssm import MambaMixer

ARCH_ID = "jamba-1.5-large-398b"


def _block_pattern(d, Hq, Hkv, hd, dff, E, attn_index, n_layers):
    layers = []
    for i in range(n_layers):
        if i == attn_index:
            mixer = c.attention_cfg(num_heads=Hq, num_kv_heads=Hkv, head_dim=hd,
                                    rope_theta=None)  # jamba: no RoPE
        else:
            mixer = MambaMixer.default_config()
        ffn = c.moe_cfg(dff, num_experts=E, top_k=2) if i % 2 == 1 else c.ffn_cfg(dff)
        layers.append(c.layer_cfg(d, mixer, ffn))
    return layers


def _model(blocks, d, Hq, Hkv, hd, dff, vocab, E, attn_index=4, n_layers=8,
           remat="full"):
    pattern = _block_pattern(d, Hq, Hkv, hd, dff, E, attn_index, n_layers)
    stack = c.pattern_stack_cfg(pattern, blocks, remat=remat)
    dec = c.decoder_cfg(vocab_size=vocab, dim=d, stack=stack,
                        tied_embeddings=False)
    return c.lm_cfg(dec)


def make_model():
    return _model(9, 8192, 64, 8, 128, 24576, 65536, E=16)


def make_smoke():
    # 1 block of 4 layers: mamba+dense, mamba+moe, attn+dense, mamba+moe.
    return _model(1, 128, 4, 2, 32, 256, 128, E=4, attn_index=2, n_layers=4,
                  remat=None)


SPEC = c.ArchSpec(
    arch_id=ARCH_ID, family="hybrid", citation="arXiv:2403.19887",
    make_model=make_model, make_smoke=make_smoke,
    vocab_size=65536, model_dim=8192,
)
