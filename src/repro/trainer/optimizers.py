"""Optimizers & LR schedules in pure JAX (no optax in this environment).

Minimal GradientTransformation calculus (init/update pairs + chain), exposed
as plain functions so they compose with ``config_for_function`` — the paper's
3rd-party-interop mechanism is exercised on our own optimizer library.
"""

from __future__ import annotations

import math
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "GradientTransformation",
    "chain",
    "clip_by_global_norm",
    "scale_by_adam",
    "add_decayed_weights",
    "scale_by_schedule",
    "scale",
    "with_master_weights",
    "MasterWeightState",
    "sgd",
    "adamw",
    "linear_warmup_cosine",
    "constant_schedule",
    "global_norm",
]


class GradientTransformation(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    """Composes transforms; state is a tuple with one entry per transform.

    ``update`` validates the state against the chain before threading it:
    a state from a different optimizer config (e.g. a checkpoint restored
    after the chain changed length, or a bare inner-transform state) used
    to fail as a deep tree mismatch inside some transform — or worse,
    ``zip`` silently DROPPED trailing transforms' state. Now it raises a
    targeted error at the chain boundary.
    """
    n = len(transforms)
    expected = {"treedef": None}  # captured at init; checked on update

    def init(params):
        state = tuple(t.init(params) for t in transforms)
        expected["treedef"] = jax.tree.structure(state)
        return state

    def update(grads, state, params):
        if not isinstance(state, tuple) or len(state) != n:
            got = (f"a tuple of length {len(state)}"
                   if isinstance(state, tuple) else
                   f"a {type(state).__name__}")
            raise ValueError(
                f"chain() of {n} transforms got an optimizer state that is "
                f"{got}; the state does not match this optimizer chain — "
                "was a checkpoint restored from a different optimizer "
                "config?")
        if expected["treedef"] is not None:
            got_def = jax.tree.structure(state)
            if got_def != expected["treedef"]:
                raise ValueError(
                    "optimizer state structure does not match this chain "
                    f"(expected {expected['treedef']}, got {got_def}) — "
                    "was a checkpoint restored from a different optimizer "
                    "config?")
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32))) for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params):
        norm = global_norm(grads)
        factor = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
        return jax.tree.map(lambda g: g * factor.astype(g.dtype), grads), state

    return GradientTransformation(init, update)


class AdamState(NamedTuple):
    count: jax.Array
    mu: Any
    nu: Any


def scale_by_adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
                  moment_dtype=jnp.float32) -> GradientTransformation:
    """moment_dtype=bf16 halves optimizer-state HBM (config-driven memory
    lever for >=100B models on v5e; composes with host offload on TPU)."""

    def init(params):
        mu = jax.tree.map(lambda p: jnp.zeros_like(p, moment_dtype), params)
        nu = jax.tree.map(lambda p: jnp.zeros_like(p, moment_dtype), params)
        return AdamState(count=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(grads, state, params):
        count = state.count + 1
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32) +
                          (1 - b1) * g.astype(jnp.float32)).astype(moment_dtype),
            state.mu, grads)
        nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32) +
                          (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(moment_dtype),
            state.nu, grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        updates = jax.tree.map(
            lambda m, v: (m.astype(jnp.float32) / c1) /
            (jnp.sqrt(v.astype(jnp.float32) / c2) + eps), mu, nu)
        return updates, AdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init, update)


def add_decayed_weights(weight_decay: float, scales: Optional[Any] = None
                        ) -> GradientTransformation:
    """scales: optional tree (matching params) of per-param decay multipliers
    (from ParameterSpec.weight_decay_scale; 0 disables decay for biases/norms)."""

    def init(params):
        return ()

    def update(grads, state, params):
        assert params is not None, "add_decayed_weights needs params"
        if scales is None:
            new = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        else:
            new = jax.tree.map(
                lambda g, p, s: g + weight_decay * s * p.astype(g.dtype),
                grads, params, scales)
        return new, state

    return GradientTransformation(init, update)


def scale_by_schedule(schedule: Callable[[jax.Array], jax.Array]
                      ) -> GradientTransformation:
    def init(params):
        return jnp.zeros((), jnp.int32)

    def update(grads, count, params):
        factor = schedule(count)
        return jax.tree.map(lambda g: g * factor.astype(g.dtype), grads), count + 1

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    def init(params):
        return ()

    def update(grads, state, params):
        return jax.tree.map(lambda g: g * factor, grads), state

    return GradientTransformation(init, update)


class MasterWeightState(NamedTuple):
    master: Any  # fp32 (or master_dtype) copy of the params
    inner: Any


def with_master_weights(inner: GradientTransformation,
                        master_dtype=jnp.float32) -> GradientTransformation:
    """fp32 master-weight wrapper for low-precision param storage.

    When the dtype policy stores params in bf16, naive ``p += lr*u`` loses
    every update smaller than ~2^-8 of the weight magnitude. This wrapper
    keeps a ``master_dtype`` copy in the optimizer state: the inner
    transform's update applies to the master, and the emitted update is
    exactly the delta that lands the low-precision param on
    ``round(master')`` — so ``params`` always equals the rounded master and
    training dynamics match fp32 storage. (Param-structured, so ZeRO-1
    shards the master copy like the moments.)
    """

    def init(params):
        master = jax.tree.map(lambda p: p.astype(master_dtype), params)
        return MasterWeightState(master=master, inner=inner.init(master))

    def update(grads, state, params):
        assert params is not None, "with_master_weights needs params"
        updates, inner_state = inner.update(grads, state.inner, state.master)
        new_master = jax.tree.map(
            lambda m, u: m + u.astype(master_dtype), state.master, updates)
        emitted = jax.tree.map(
            lambda nm, p: nm.astype(p.dtype).astype(jnp.float32)
            - p.astype(jnp.float32), new_master, params)
        return emitted, MasterWeightState(master=new_master, inner=inner_state)

    return GradientTransformation(init, update)


# ------------------------------- schedules ----------------------------------


def constant_schedule(value: float = 1.0):
    return lambda step: jnp.asarray(value, jnp.float32)


def linear_warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                         end_lr_ratio: float = 0.1):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_lr * step / max(warmup_steps, 1)
        progress = jnp.clip((step - warmup_steps) / max(total_steps - warmup_steps, 1),
                            0.0, 1.0)
        cos = peak_lr * (end_lr_ratio + (1 - end_lr_ratio) * 0.5 *
                         (1 + jnp.cos(jnp.pi * progress)))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule


# ------------------------------ optimizers ----------------------------------


def sgd(learning_rate: float = 1e-2, momentum: float = 0.0
        ) -> GradientTransformation:
    if momentum == 0.0:
        return chain(scale(-learning_rate))

    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(grads, vel, params):
        vel = jax.tree.map(lambda v, g: momentum * v + g.astype(jnp.float32), vel, grads)
        return jax.tree.map(lambda v: -learning_rate * v, vel), vel

    return GradientTransformation(init, update)


def adamw(
    learning_rate: Optional[Callable] = None,
    peak_lr: float = 1e-3,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    weight_decay_scales: Optional[Any] = None,
    max_grad_norm: Optional[float] = 1.0,
    moment_dtype=jnp.float32,
    state_dtype: Optional[str] = None,
    master_weight_dtype: Optional[Any] = None,
) -> GradientTransformation:
    """AdamW with optional clipping + schedule; final update is negative.

    ``state_dtype`` ("fp32" | "bf16" | "int8") selects the EMA-buffer
    storage by *name*; the names are resolved inside
    :mod:`repro.memopt.state_quant` (bf16 halves, int8(+fp32 scales)
    quarters the 8 bytes/param moment footprint) and the quantized trees
    stay param-structured so ZeRO-1 keeps sharding them. Takes precedence
    over the legacy ``moment_dtype`` when set.

    ``master_weight_dtype`` (e.g. fp32 when the dtype policy stores params
    in bf16) wraps the whole chain in :func:`with_master_weights`: moments
    AND the update math run against a full-precision master copy held in
    the optimizer state (which ZeRO-1 then shards along the data axis).
    """
    schedule = learning_rate or constant_schedule(peak_lr)
    parts = []
    if max_grad_norm is not None:
        parts.append(clip_by_global_norm(max_grad_norm))
    if state_dtype is not None:
        # Lazy import: the memopt subsystem owns state-dtype names/literals
        # (grep contract) and itself builds on this module's protocol.
        from repro.memopt.state_quant import scale_by_adam_state_dtype

        parts.append(scale_by_adam_state_dtype(
            b1=b1, b2=b2, eps=eps, state_dtype=state_dtype))
    else:
        parts.append(scale_by_adam(b1=b1, b2=b2, eps=eps,
                                   moment_dtype=moment_dtype))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, weight_decay_scales))
    parts.append(scale_by_schedule(lambda step: -schedule(step)))
    tx = chain(*parts)
    if master_weight_dtype is not None:
        tx = with_master_weights(tx, master_dtype=master_weight_dtype)
    return tx
