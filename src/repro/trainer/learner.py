"""Learner: turns (grads, aux outputs) into parameter updates.

The Learner aggregates auxiliary losses (e.g. MoE load-balance) from the
OutputCollection *by key pattern* — neither the model nor any layer passes
them explicitly (InvocationContext encapsulation, §4.3).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.config import (
    REQUIRED,
    FunctionConfigBase,
    Required,
    config_class,
    config_for_function,
)
from repro.core.module import Module, OutputCollection, no_context
from repro.layers.base import ParameterSpec
from repro.trainer import optimizers as opt_lib

__all__ = ["Learner", "aggregate_aux_losses"]


def aggregate_aux_losses(collection: OutputCollection,
                         pattern: str = r".*/aux_loss$") -> jax.Array:
    """Sums every module output matching ``pattern`` (stacked leaves from
    scanned layers sum over all elements)."""
    rx = re.compile(pattern)
    total = jnp.zeros((), jnp.float32)
    for key, value in collection.module_outputs.items():
        if rx.match(key):
            total = total + jnp.sum(value.astype(jnp.float32))
    return total


class Learner(Module):
    @config_class
    class Config(Module.Config):
        # A config_for_function over an optimizer factory (e.g. adamw).
        optimizer: Required[FunctionConfigBase] = REQUIRED
        aux_loss_weight: float = 1.0
        aux_loss_pattern: str = r".*/aux_loss$"

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._tx: Optional[opt_lib.GradientTransformation] = None

    # Structural (no InvocationContext): used by trainer at setup time.
    @no_context
    def build(self, param_specs: Optional[Any] = None) -> opt_lib.GradientTransformation:
        cfg = self.config.optimizer.clone()
        if param_specs is not None and "weight_decay_scales" in cfg.keys():
            scales = jax.tree.map(
                lambda s: s.weight_decay_scale, param_specs,
                is_leaf=lambda s: isinstance(s, ParameterSpec))
            if isinstance(cfg.weight_decay_scales, type(REQUIRED)) or \
                    cfg.weight_decay_scales is None:
                cfg.set(weight_decay_scales=scales)
        self._tx = cfg.instantiate()
        return self._tx

    @property
    def tx(self) -> opt_lib.GradientTransformation:
        assert self._tx is not None, "call learner.build() first"
        return self._tx

    @no_context
    def init_state(self, params):
        return self.tx.init(params)

    @no_context
    def apply_updates(self, grads, opt_state, params, *,
                      update_partition_specs=None, param_partition_specs=None):
        """grads -> (new_params, new_opt_state).

        ``update_partition_specs`` (optional tree of PartitionSpecs matching
        params) is the ZeRO-1 hook: constraining the gradients to the
        data-sharded optimizer layout makes GSPMD lower the data-parallel
        psum into a reduce-scatter, the whole optimizer update then runs on
        1/N of each tensor per device, and constraining the applied params
        back to ``param_partition_specs`` is the single (bf16-update-sized)
        all-gather — no explicit collectives, sharding constraints only.
        """
        from repro.trainer.train_step import constrain_tree

        grads = constrain_tree(grads, update_partition_specs)
        updates, new_opt_state = self.tx.update(grads, opt_state, params)
        updates = constrain_tree(updates, update_partition_specs)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
            params, updates)
        new_params = constrain_tree(new_params, param_partition_specs)
        return new_params, new_opt_state
