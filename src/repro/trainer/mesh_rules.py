"""Mesh rules: instance-type regex -> config modifiers (paper §4.2, App. A).

A mesh rule maps an accelerator instance type (e.g. "tpu-v5e-256-*",
"gpu-H100-*", "cpu-*") to a list of ConfigModifiers applied to the trainer
config. Per-target parallelism/remat/kernel/quantization choices therefore
live in ~10 lines of config, with zero model-code changes — the paper's
heterogeneous-hardware mechanism.

Modifiers exploit the config system's traversal: e.g. RematPolicyModifier
rewrites the ``remat_policy`` of every Repeat config wherever it appears in
the (arbitrarily deep) tree.
"""

from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.config import (
    REQUIRED,
    ConfigBase,
    Required,
    config_class,
    update_configs_recursively,
    visit_config,
)
from repro.core.module import Module, no_context
from repro.kernels.registry import KernelConfig

__all__ = [
    "ConfigModifier",
    "MeshShapeModifier",
    "RematPolicyModifier",
    "KernelModifier",
    "OffloadOptimizerModifier",
    "GradAccumModifier",
    "DtypePolicyModifier",
    "Zero1Modifier",
    "FsdpModifier",
    "ElasticModifier",
    "apply_mesh_rules",
]


class ConfigModifier(Module):
    """Base: subclasses implement apply(trainer_cfg) -> trainer_cfg."""

    @no_context
    def apply(self, trainer_cfg: ConfigBase) -> ConfigBase:
        raise NotImplementedError


class MeshShapeModifier(ConfigModifier):
    @config_class
    class Config(ConfigModifier.Config):
        mesh_shape: Required[Tuple[int, ...]] = REQUIRED
        mesh_axis_names: Required[Tuple[str, ...]] = REQUIRED

    @no_context
    def apply(self, trainer_cfg):
        trainer_cfg.set(mesh_shape=self.config.mesh_shape,
                        mesh_axis_names=self.config.mesh_axis_names)
        return trainer_cfg


class RematPolicyModifier(ConfigModifier):
    """Sets remat_policy on every config that has one (Repeat stacks)."""

    @config_class
    class Config(ConfigModifier.Config):
        policy: Optional[str] = "full"

    @no_context
    def apply(self, trainer_cfg):
        update_configs_recursively(trainer_cfg,
                                   {"remat_policy": self.config.policy})
        return trainer_cfg


class KernelModifier(ConfigModifier):
    """Kernel selection is config (paper: cuDNN / NKI / SplashAttention /
    Pallas per backend).

    Rewrites every :class:`KernelConfig` anywhere in the trainer tree — one
    generic modifier replaces the old per-knob AttentionImplModifier +
    KernelBlockModifier pair, so a new backend or a per-hardware tiling
    table is a ~10-line mesh rule touching zero model code::

        KernelModifier.default_config().set(
            backend="auto",
            op_overrides={"attention.fwd": "pallas"},
            update={"block_q": 256, "blockwise_chunk_size": 2048})
    """

    @config_class
    class Config(ConfigModifier.Config):
        # Registry backend id ("auto" | "pallas" | "pallas:interpret" |
        # "blockwise" | "ref"); None leaves each layer's choice untouched.
        backend: Optional[str] = None
        # Per-op backend ids, e.g. {"attention.decode": "pallas"}.
        op_overrides: Optional[Dict[str, str]] = None
        # Pallas interpret mode (off-TPU kernel validation).
        interpret: Optional[bool] = None
        # Any other KernelConfig fields (per-hardware tiling table), e.g.
        # {"block_q": 512, "decode_block_k": 512}.
        update: Optional[Dict[str, Any]] = None

    @no_context
    def apply(self, trainer_cfg):
        c = self.config
        updates: Dict[str, Any] = dict(c.update or {})
        if c.backend is not None:
            updates["backend"] = c.backend
        if c.op_overrides is not None:
            updates["op_overrides"] = dict(c.op_overrides)
        if c.interpret is not None:
            updates["interpret"] = c.interpret
        unknown = [k for k in updates if k not in KernelConfig().keys()]
        if unknown:
            raise ValueError(
                f"KernelModifier.update has non-KernelConfig fields "
                f"{unknown}; known: {KernelConfig().keys()}")

        def visit(path, node):
            if isinstance(node, KernelConfig):
                # Copy container values per site so sites never alias.
                node.set(**{k: (dict(v) if isinstance(v, dict) else v)
                            for k, v in updates.items()})

        visit_config(trainer_cfg, visit)
        return trainer_cfg


class OffloadOptimizerModifier(ConfigModifier):
    @config_class
    class Config(ConfigModifier.Config):
        enabled: bool = True

    @no_context
    def apply(self, trainer_cfg):
        trainer_cfg.set(offload_optimizer_state=self.config.enabled)
        return trainer_cfg


class GradAccumModifier(ConfigModifier):
    @config_class
    class Config(ConfigModifier.Config):
        steps: Required[int] = REQUIRED

    @no_context
    def apply(self, trainer_cfg):
        trainer_cfg.set(grad_accum_steps=self.config.steps)
        return trainer_cfg


class DtypePolicyModifier(ConfigModifier):
    """Mixed precision for an entire experiment in one rule (paper §4.2).

    Sets ``dtype_policy`` on every layer config in the trainer tree (compute
    dtype casts happen at module boundaries; fp32 islands are untouched) and
    aligns the trainer's grad-accumulation dtype with the policy. The whole
    bf16-compute/fp32-master switch for any of the 11 archs is therefore::

        DtypePolicyModifier.default_config().set(
            policy=DtypePolicy().set(compute_dtype=jnp.bfloat16))
    """

    @config_class
    class Config(ConfigModifier.Config):
        # A repro.layers.base.DtypePolicy config.
        policy: Required[ConfigBase] = REQUIRED

    @no_context
    def apply(self, trainer_cfg):
        policy = self.config.policy
        update_configs_recursively(trainer_cfg, {"dtype_policy": policy})
        grad_dtype = getattr(policy, "grad_dtype", None)
        if grad_dtype is not None and "grad_dtype" in trainer_cfg.keys():
            trainer_cfg.set(grad_dtype=grad_dtype)
        return trainer_cfg


class Zero1Modifier(ConfigModifier):
    """ZeRO-1: partition optimizer state along the data axes (config-only)."""

    @config_class
    class Config(ConfigModifier.Config):
        enabled: bool = True

    @no_context
    def apply(self, trainer_cfg):
        trainer_cfg.set(
            opt_state_sharding="zero1" if self.config.enabled else "params")
        return trainer_cfg


class FsdpModifier(ConfigModifier):
    """FSDP-style parameter sharding over the data axes (config-only).

    Params shard by the same first-free-divisible-dim rule ZeRO-1 applies
    to optimizer state; combine with :class:`Zero1Modifier` for fully
    data-sharded params + optimizer (per-device bytes ~N× smaller on an
    N-way data mesh)::

        FsdpModifier.default_config().set(axes=("data",))
    """

    @config_class
    class Config(ConfigModifier.Config):
        axes: Tuple[str, ...] = ("pod", "data")
        enabled: bool = True

    @no_context
    def apply(self, trainer_cfg):
        trainer_cfg.set(
            fsdp_axes=tuple(self.config.axes) if self.config.enabled
            else None)
        return trainer_cfg


class ElasticModifier(ConfigModifier):
    """Turns a single-process trainer config into one rank of an elastic
    fleet (the launch layer applies this per worker).

    Sets the trainer's ``distributed`` runtime config, points the
    checkpointer at this rank's slice of the commit barrier, and switches
    the input to the *global-view contract*: every rank generates the
    identical global batch (input ``process_count=1``) and the elastic step
    slices its own canonical microbatches — the property that makes
    checkpoints resumable at a different world size with a bitwise-identical
    loss curve.
    """

    @config_class
    class Config(ConfigModifier.Config):
        coordinator_dir: str = ""
        process_index: int = 0
        process_count: int = 1
        # Canonical gradient decomposition G (0 -> process_count). For
        # loss-curve continuity across resharding, set G to the LCM of
        # every world size the job may restart at.
        grad_microbatches: int = 0
        collective_timeout_s: float = 60.0
        backend: str = "file"  # "file" | "jax"
        coordinator_address: str = ""

    @no_context
    def apply(self, trainer_cfg):
        from repro.launch.distributed import DistributedConfig

        c = self.config
        trainer_cfg.set(distributed=DistributedConfig().set(
            coordinator_dir=c.coordinator_dir,
            process_index=c.process_index,
            process_count=c.process_count,
            grad_microbatches=c.grad_microbatches,
            collective_timeout_s=c.collective_timeout_s,
            backend=c.backend,
            coordinator_address=c.coordinator_address,
        ))
        if trainer_cfg.checkpointer is not None:
            trainer_cfg.checkpointer.set(
                process_index=c.process_index,
                process_count=c.process_count,
                # The commit barrier is a collective too: a dead peer must
                # surface on the same timescale as a dead step collective.
                commit_timeout_s=c.collective_timeout_s)
        # Global-view input: rank-independent batches (the elastic step
        # slices microbatches; doc%N host sharding would make the data, and
        # therefore the loss curve, world-size-dependent).
        if "process_count" in trainer_cfg.input.keys():
            trainer_cfg.input.set(process_index=0, process_count=1)
        return trainer_cfg


MeshRules = Sequence[Tuple[str, Sequence[ConfigBase]]]


def apply_mesh_rules(trainer_cfg: ConfigBase, *, instance_type: str,
                     rules: MeshRules) -> ConfigBase:
    """Applies the first rule whose regex FULLY matches ``instance_type``.

    Anchored to ``re.fullmatch`` only: the old ``fullmatch(...) or
    match(...)`` made every rule a prefix match, so a broad rule listed
    first (e.g. ``"tpu-.*"``) shadowed more specific ones (``"tpu-v5e-.*"``)
    AND patterns like ``"tpu-v5e"`` silently matched ``"tpu-v5e-256"``.
    Write explicit ``.*`` suffixes for prefix semantics.
    """
    for pattern, modifier_cfgs in rules:
        if re.fullmatch(pattern, instance_type):
            for mc in modifier_cfgs:
                modifier = mc.instantiate()
                trainer_cfg = modifier.apply(trainer_cfg)
            return trainer_cfg
    return trainer_cfg
