"""SpmdTrainer: config-driven distributed training loop (paper §3–§5).

Everything is a replaceable child module: model, learner, input pipeline,
checkpointer. Parallelism is configured — mesh shape + axis names + the
partition specs the layers already carry — never coded (§4.2). The exact
``train_step`` built here is what the AOT dry-run lowers, fulfilling the
paper's "a program that AOT-compiles will run at scale" property.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer, CheckpointWriteError
from repro.observability.hardware import compiled_cost, device_memory_stats, estimate_mfu
from repro.observability.runtime import ObservabilityConfig, build_observability
from repro.runtime.goodput import GoodputMonitor
from repro.runtime.signals import Preempted
from repro.core.config import REQUIRED, ConfigBase, Required, config_class
from repro.core.module import Module, no_context
from repro.core.utils import (
    make_mesh,
    named_sharding,
    resolve_spec,
    set_mesh,
    tree_param_count,
)
from repro.data.input import SyntheticInput
from repro.launch.distributed import initialize as distributed_initialize
from repro.layers.base import ParameterSpec
from repro.trainer.learner import Learner
from repro.trainer.optimizers import global_norm
from repro.trainer.train_step import (
    build_train_step,
    canonical_mean,
    combine_microbatch_grads,
    make_loss_fn,
    slice_microbatch,
    zero1_partition_spec,
)

__all__ = ["SpmdTrainer", "TrainState", "WatchdogTimeout"]

TrainState = Dict[str, Any]  # {"step", "prng_key", "params", "opt_state"}


class WatchdogTimeout(RuntimeError):
    """A training step exceeded the configured watchdog timeout (§5)."""


def _flatten_metrics(metrics: Dict[str, Any]) -> Dict[str, float]:
    """Step metrics -> flat {name: float}. Nested dicts (the routed
    ``summaries`` subtree) flatten as ``summaries/<module-path>``. Forces a
    host transfer, so call only at the logging cadence."""
    flat: Dict[str, float] = {}
    for k, v in metrics.items():
        if isinstance(v, dict):
            for sk, sv in v.items():
                flat[f"{k}/{sk}"] = float(sv)
        else:
            flat[k] = float(v)
    return flat


def opt_state_shardings(opt_state_shapes: Any, params_structure,
                        param_shardings: Any, mesh, *,
                        param_state_shardings: Any = None) -> Any:
    """Shardings for an optimizer state pytree: any subtree whose structure
    matches the params tree inherits ``param_state_shardings`` (ZeRO-1
    partitioned specs; defaults to the param shardings — moments, master
    weights, SGD velocity are all param-structured); other leaves are
    replicated (counts, schedules)."""
    from jax.sharding import NamedSharding, PartitionSpec

    replicated = NamedSharding(mesh, PartitionSpec()) if mesh else None
    target = param_state_shardings if param_state_shardings is not None \
        else param_shardings

    def rec(node):
        if jax.tree.structure(node) == params_structure:
            return target
        if isinstance(node, tuple) and type(node) is not tuple:  # NamedTuple
            return type(node)(*[rec(x) for x in node])
        if isinstance(node, tuple):
            return tuple(rec(x) for x in node)
        if isinstance(node, list):
            return [rec(x) for x in node]
        if isinstance(node, dict):
            return {k: rec(v) for k, v in node.items()}
        return replicated

    return rec(opt_state_shapes)


class SpmdTrainer(Module):
    @config_class
    class Config(Module.Config):
        model: Required[ConfigBase] = REQUIRED
        learner: Learner.Config = Learner.Config()
        input: SyntheticInput.Config = SyntheticInput.Config()
        checkpointer: Optional[Checkpointer.Config] = None
        # --- parallelism is configuration (paper §4.2) ---
        mesh_shape: Tuple[int, ...] = (1,)
        mesh_axis_names: Tuple[str, ...] = ("data",)
        batch_partition: Any = (("pod", "data"),)  # applied to dim 0 of inputs
        # FSDP-style parameter sharding: when set, every parameter's first
        # free divisible dim is additionally sharded over these mesh axes
        # (the same partitioning rule ZeRO-1 applies to optimizer state,
        # lifted to the params themselves). Set by FsdpModifier.
        fsdp_axes: Optional[Tuple[str, ...]] = None
        # Elastic multi-process runtime (a repro.launch.distributed
        # .DistributedConfig). When set, run() takes the world-size-
        # invariant step path: the global batch decomposes into
        # ``distributed.grad_microbatches`` canonical microbatches, each
        # process computes its block, contributions are allgathered and
        # combined in canonical order on the host — bitwise-identical
        # updates at every world size (reshard-on-resume continuity).
        # Set by ElasticModifier.
        distributed: Optional[ConfigBase] = None
        # --- loop ---
        max_steps: int = 100
        seed: int = 0
        log_every_n: int = 10
        checkpoint_every_n: int = 0
        # Gradient accumulation (microbatching) — memory lever.
        grad_accum_steps: int = 1
        # Dtype gradients are ACCUMULATED in across microbatches (None ->
        # each param's dtype). Set by DtypePolicyModifier from the policy's
        # grad_dtype.
        grad_dtype: Any = None
        # Optimizer-state sharding: "params" replicates the opt state like
        # the params; "zero1" additionally partitions every param-shaped
        # optimizer leaf (moments, master weights) along the data axes —
        # per-device optimizer bytes shrink ~Nx on an N-way data mesh.
        opt_state_sharding: str = "params"
        zero1_axes: Tuple[str, ...] = ("pod", "data")
        # Optimizer-state host offload (TPU feature; see DESIGN.md for the
        # CPU dry-run substitution).
        offload_optimizer_state: bool = False
        # Unified observability (repro.observability): metrics registry +
        # JSONL sink, Chrome trace spans per step phase, MFU/memory gauges,
        # on-demand profiler window. None = zero instrumentation.
        observability: Optional[ObservabilityConfig] = None
        # Runtime resiliency (paper §5).
        watchdog_timeout_s: Optional[float] = None
        # "warn" prints; "raise" raises WatchdogTimeout at the next
        # heartbeat after a step overran (the async dispatch returns to the
        # host every step, so a hung device shows up at the next beat).
        watchdog_on_timeout: str = "warn"
        sdc_check_every_n: int = 0

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._add_child("model", cfg.model)
        self._add_child("learner", cfg.learner)
        self._add_child("input", cfg.input)
        if cfg.checkpointer is not None:
            self._add_child("checkpointer", cfg.checkpointer)
        self._mesh = None
        self._jit_step = None
        self._step_has_run = False
        # Telemetry bundle (engine-cached like the jitted step: one registry
        # / tracer / profiler across warm restarts on this instance).
        self.observability = build_observability(cfg.observability)
        self._step_cost = None
        self._opt_state_bytes = None
        self._mem_stats_unavailable = False
        self._lower_shapes = None
        # Set by a SIGTERM handler (see launch/train.py) or the supervisor's
        # fault injection; the loop polls it at each step boundary, takes a
        # synchronous emergency checkpoint, and raises Preempted.
        self.preemption_event = threading.Event()

    # ----------------------------------------------------------------- setup

    @no_context
    def build_mesh(self):
        cfg = self.config
        if self._mesh is None:
            n = int(np.prod(cfg.mesh_shape))
            if n > len(jax.devices()):
                raise RuntimeError(
                    f"mesh {cfg.mesh_shape} needs {n} devices, "
                    f"have {len(jax.devices())}")
            self._mesh = make_mesh(cfg.mesh_shape, cfg.mesh_axis_names)
        return self._mesh

    @no_context
    def param_specs(self):
        return self.model.create_parameter_specs_recursively()

    @no_context
    def param_shardings(self, mesh=None):
        mesh = mesh or self.build_mesh()
        cfg = self.config
        specs = self.param_specs()
        if cfg.fsdp_axes:
            from jax.sharding import NamedSharding

            # FSDP: params shard over the data axes with the same first-
            # free-divisible-dim rule ZeRO-1 uses for optimizer state (a
            # param that already uses an axis, or has no dividing dim, keeps
            # its own spec).
            return jax.tree.map(
                lambda s: NamedSharding(
                    mesh, zero1_partition_spec(s, mesh, cfg.fsdp_axes)),
                specs, is_leaf=lambda s: isinstance(s, ParameterSpec))
        return jax.tree.map(
            lambda s: named_sharding(s.mesh_axes, mesh), specs,
            is_leaf=lambda s: isinstance(s, ParameterSpec))

    @no_context
    def batch_shardings(self, batch_like, mesh=None):
        mesh = mesh or self.build_mesh()
        cfg = self.config

        def shard(x):
            ndim = len(x.shape)
            spec = tuple(cfg.batch_partition) + (None,) * (ndim - len(cfg.batch_partition))
            return named_sharding(spec[:ndim], mesh)

        return jax.tree.map(shard, batch_like)

    # ------------------------------------------------------------------ state

    @no_context
    def init_state(self, prng_key: Optional[jax.Array] = None) -> TrainState:
        cfg = self.config
        if prng_key is None:
            prng_key = jax.random.PRNGKey(cfg.seed)
        self.learner.build(self.param_specs())
        params = self.model.initialize_parameters_recursively(prng_key)
        opt_state = self.learner.init_state(params)
        return {
            "step": jnp.zeros((), jnp.int32),
            "prng_key": prng_key,
            "params": params,
            "opt_state": opt_state,
        }

    @no_context
    def zero1_partition_specs(self, mesh=None):
        """Tree (matching params) of ZeRO-1 PartitionSpecs for param-shaped
        optimizer-state leaves."""
        mesh = mesh or self.build_mesh()
        cfg = self.config
        return jax.tree.map(
            lambda s: zero1_partition_spec(s, mesh, cfg.zero1_axes),
            self.param_specs(),
            is_leaf=lambda s: isinstance(s, ParameterSpec))

    @no_context
    def state_shardings(self, state_shapes: TrainState, mesh=None):
        from jax.sharding import NamedSharding, PartitionSpec

        mesh = mesh or self.build_mesh()
        cfg = self.config
        p_shardings = self.param_shardings(mesh)
        opt_leaf_sh = None
        if cfg.opt_state_sharding == "zero1":
            opt_leaf_sh = jax.tree.map(
                lambda spec: NamedSharding(mesh, spec),
                self.zero1_partition_specs(mesh))
        elif cfg.opt_state_sharding != "params":
            raise ValueError(
                f"Unknown opt_state_sharding {cfg.opt_state_sharding!r}; "
                "expected 'params' or 'zero1'")
        opt_sh = opt_state_shardings(
            state_shapes["opt_state"], jax.tree.structure(state_shapes["params"]),
            p_shardings, mesh, param_state_shardings=opt_leaf_sh)
        if cfg.offload_optimizer_state:
            opt_sh = jax.tree.map(
                lambda s: s.with_memory_kind("pinned_host") if s is not None else s,
                opt_sh)
        rep = NamedSharding(mesh, PartitionSpec())
        return {
            "step": rep,
            "prng_key": rep,
            "params": p_shardings,
            "opt_state": opt_sh,
        }

    # ------------------------------------------------------------- train step

    @no_context
    def make_train_step(self) -> Callable[[TrainState, Dict[str, Any]],
                                          Tuple[TrainState, Dict[str, Any]]]:
        """Builds the jittable step from the composable pieces in
        ``repro.trainer.train_step`` (loss -> accumulated grads -> sharded
        optimizer update)."""
        cfg = self.config
        update_specs = param_specs = None
        if cfg.opt_state_sharding == "zero1":
            mesh = self.build_mesh()
            update_specs = self.zero1_partition_specs(mesh)
            param_specs = jax.tree.map(
                lambda s: resolve_spec(s.mesh_axes, mesh), self.param_specs(),
                is_leaf=lambda s: isinstance(s, ParameterSpec))
        return build_train_step(
            self.model,
            self.learner,
            aux_loss_weight=cfg.learner.aux_loss_weight,
            aux_loss_pattern=cfg.learner.aux_loss_pattern,
            grad_accum_steps=cfg.grad_accum_steps,
            grad_dtype=cfg.grad_dtype,
            update_partition_specs=update_specs,
            param_partition_specs=param_specs,
        )

    # ----------------------------------------------------------- elastic step

    @no_context
    def _make_elastic_step(self, shardings) -> Callable:
        """The world-size-invariant step for elastic multi-process training.

        The global batch (every process holds the identical global batch —
        the ElasticModifier configures the input with the global view)
        decomposes into G = ``distributed.grad_microbatches`` canonical
        microbatches. Process p computes microbatches
        ``[p*G/N, (p+1)*G/N)`` with ONE jitted per-microbatch program whose
        shapes do not depend on the world size, allgathers the float32
        contributions, and every process folds all G of them in canonical
        order with left-associative host arithmetic before one jitted
        optimizer-update program. Same programs + same data + same
        reduction order ⇒ bitwise-identical states at every world size —
        a checkpoint committed at world size P resumes at P' with the loss
        curve of the uninterrupted run.
        """
        cfg = self.config
        dcfg = cfg.distributed
        N = dcfg.process_count
        G = dcfg.grad_microbatches or N
        if G % max(N, 1) != 0:
            raise ValueError(
                f"grad_microbatches={G} must be divisible by process_count="
                f"{N} (set it to the LCM of every world size the job may "
                "run at)")
        if getattr(cfg.input, "process_count", 1) != 1:
            raise ValueError(
                "elastic training requires the global-view input contract "
                "(input.process_count == 1 on every rank; the trainer "
                "slices canonical microbatches itself) — apply "
                "ElasticModifier instead of sharding the input")
        collective = distributed_initialize(dcfg)  # None at world size 1
        per_rank = G // max(N, 1)
        mine = range(dcfg.process_index * per_rank,
                     (dcfg.process_index + 1) * per_rank)

        loss_fn = make_loss_fn(
            self.model, aux_loss_weight=cfg.learner.aux_loss_weight,
            aux_loss_pattern=cfg.learner.aux_loss_pattern)
        mb_grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))

        learner = self.learner
        update_specs = param_specs = None
        if cfg.opt_state_sharding == "zero1":
            mesh = self.build_mesh()
            update_specs = self.zero1_partition_specs(mesh)
            param_specs = jax.tree.map(
                lambda s: resolve_spec(s.mesh_axes, mesh), self.param_specs(),
                is_leaf=lambda s: isinstance(s, ParameterSpec))

        def apply_updates(state, grads):
            new_params, new_opt = learner.apply_updates(
                grads, state["opt_state"], state["params"],
                update_partition_specs=update_specs,
                param_partition_specs=param_specs)
            new_state = {
                "step": state["step"] + 1,
                "prng_key": state["prng_key"],
                "params": new_params,
                "opt_state": new_opt,
            }
            return new_state, global_norm(grads)

        apply_fn = jax.jit(apply_updates, donate_argnums=(0,))

        def elastic_step(state, batch):
            step_key = jax.random.fold_in(state["prng_key"], state["step"])
            payload: Dict[str, np.ndarray] = {}
            treedef = None
            n_leaves = 0
            for m in mine:
                mb = slice_microbatch(batch, m, G)
                mb_key = jax.random.fold_in(step_key, m)
                (total, parts), grads = mb_grad_fn(state["params"], mb,
                                                   mb_key)
                leaves, treedef = jax.tree_util.tree_flatten(grads)
                n_leaves = len(leaves)
                for i, leaf in enumerate(leaves):
                    # float32 exchange: bitwise-stable through the .npz
                    # roundtrip and under numpy accumulation on every rank.
                    payload[f"{m:05d}.g{i:05d}"] = np.asarray(
                        leaf, np.float32)
                payload[f"{m:05d}.metrics"] = np.asarray(
                    [total, parts["loss"], parts["aux_loss"]], np.float32)
            if collective is None:
                merged = payload
            else:
                merged = {}
                for contribution in collective.allgather(payload):
                    merged.update(contribution)
            per_mb = [[merged[f"{m:05d}.g{i:05d}"] for i in range(n_leaves)]
                      for m in range(G)]
            grads = combine_microbatch_grads(per_mb, treedef)
            scalar_means = canonical_mean(
                [merged[f"{m:05d}.metrics"] for m in range(G)])
            new_state, grad_norm = apply_fn(state, grads)
            metrics = {
                "total_loss": scalar_means[0],
                "grad_norm": grad_norm,
                "loss": scalar_means[1],
                "aux_loss": scalar_means[2],
            }
            return new_state, metrics

        return elastic_step

    # ---------------------------------------------------------- hardware cost

    @no_context
    def step_cost_analysis(self) -> Dict[str, Any]:
        """XLA's own analysis of the compiled train step: ``flops`` (the
        MFU numerator), ``bytes_accessed``, and ``peak_hbm_proxy_bytes``
        (argument + temp + output bytes of the executable).

        Memoized per trainer; the one extra lower+compile happens off the
        step path (first logging step, or on demand from the bench).
        Returns ``{}`` before the step is built and for the elastic
        multi-process step (not a single jitted program).
        """
        if self._step_cost is not None:
            return self._step_cost
        if (self.config.distributed is not None or self._jit_step is None
                or self._lower_shapes is None):
            return {}
        state_shapes, batch_abs = self._lower_shapes
        try:
            compiled = self._jit_step.lower(state_shapes, batch_abs).compile()
        except Exception:  # noqa: BLE001 — backend without AOT lowering
            self._step_cost = {}
            return self._step_cost
        cost = compiled_cost(compiled)
        try:
            ma = compiled.memory_analysis()
            cost["peak_hbm_proxy_bytes"] = int(
                ma.argument_size_in_bytes + ma.temp_size_in_bytes
                + ma.output_size_in_bytes)
        except Exception:  # noqa: BLE001 — backend without memory_analysis
            cost["peak_hbm_proxy_bytes"] = None
        self._step_cost = cost
        return cost

    # -------------------------------------------------------------------- run

    @no_context
    def run(self, num_steps: Optional[int] = None, *,
            monitor: Optional[GoodputMonitor] = None,
            step_hook: Optional[Callable[..., None]] = None) -> Dict[str, Any]:
        """Runs the training loop inside the fault-tolerance runtime.

        ``monitor`` attributes wall time to goodput buckets (a fresh one is
        created if not given; the supervisor passes one spanning restarts).
        ``step_hook(step=, state=, metrics=, trainer=)`` fires after every
        step (fault injection, custom telemetry).

        Checkpoints carry the input iterator's state, so a resume replays
        no data and skips none (exactly-once). When ``preemption_event`` is
        set, the loop takes a synchronous emergency checkpoint at the next
        step boundary and raises :class:`Preempted`.
        """
        import contextlib

        cfg = self.config
        num_steps = num_steps or cfg.max_steps
        obs = self.observability
        registry = obs.registry if obs is not None else None
        tracer = obs.tracer if obs is not None else None
        monitor = monitor if monitor is not None else GoodputMonitor()
        if registry is not None and monitor._sink is None:
            # The goodput monitor's event stream adopts the unified schema:
            # every bucket exit lands in the registry's sinks as
            # {"kind": "event", "name": "goodput/<bucket>", ...}.
            monitor._sink = registry.goodput_sink()

        @contextlib.contextmanager
        def phase(name, **meta):
            """One run phase: a goodput bucket, and (when tracing) a span
            on this rank's timeline lane. Host-side only — zero retraces."""
            if tracer is None:
                with monitor.bucket(name, **meta):
                    yield
            else:
                with monitor.bucket(name, **meta), tracer.span(name, **meta):
                    yield

        mesh = self.build_mesh()
        with set_mesh(mesh):
            with phase("init"):
                state = self.init_state()
                state_shapes = jax.eval_shape(lambda: state)
                shardings = self.state_shardings(state_shapes, mesh)
                state = jax.device_put(state, shardings)

                # Exact optimizer-state footprint (repro.memopt.accounting),
                # computed on shapes (no device transfer): the lever the
                # memory-frugal knobs (factored/quantized state, ZeRO-1)
                # move, exported as gauges and in the run result.
                from repro.memopt import accounting

                self._opt_state_bytes = accounting.state_bytes(
                    state_shapes["opt_state"])
                opt_bytes_per_device = accounting.per_device_state_bytes(
                    state_shapes["opt_state"], shardings["opt_state"])
                if registry is not None:
                    registry.gauge("train/opt_state_bytes").set(
                        self._opt_state_bytes)
                    if opt_bytes_per_device is not None:
                        registry.gauge(
                            "train/opt_state_bytes_per_device").set(
                                opt_bytes_per_device)

                sample = self.input.make_batch(0)
                batch_sh = self.batch_shardings(sample, mesh)
                self._lower_shapes = (state_shapes, {
                    k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                    for k, v in sample.items()})
                tokens_per_step = int(getattr(
                    sample.get("input_ids", sample.get("labels", None)),
                    "size", 0))
            # The jitted step is engine-cached: repeated run() calls on one
            # trainer (warm restarts, resume-after-checkpoint) reuse the
            # compiled executable — the train step compiles exactly once.
            if self._jit_step is None:
                if cfg.distributed is not None:
                    self._jit_step = self._make_elastic_step(shardings)
                else:
                    self._jit_step = jax.jit(
                        self.make_train_step(),
                        in_shardings=(shardings, batch_sh),
                        out_shardings=(shardings, None),
                        donate_argnums=(0,),
                    )
            step_fn = self._jit_step

            it = self.input.batches()
            start_step = 0
            if cfg.checkpointer is not None:
                latest = self.checkpointer.latest_step()
                if latest is not None:
                    with phase("restore", step=latest):
                        state = self.checkpointer.restore(latest, like=state)
                        state = jax.device_put(state, shardings)
                        # Elastic mode uses the global-view input contract:
                        # every rank's iterator state is identical, so a
                        # checkpoint committed at world size P restores into
                        # P' ranks by reading rank 0's aux — the reshard is
                        # a no-op by construction.
                        aux = self.checkpointer.restore_aux(
                            latest,
                            process_index=0 if cfg.distributed is not None
                            else None)
                        if aux and "input" in aux and hasattr(it, "restore"):
                            it.restore(aux["input"])
                        elif hasattr(it, "restore"):
                            print(f"[trainer] checkpoint step {latest} has no "
                                  "input-iterator state; data stream restarts "
                                  "from the beginning (pre-aux checkpoint?)")
                    start_step = latest

            watchdog = _Watchdog(cfg.watchdog_timeout_s,
                                 on_timeout=cfg.watchdog_on_timeout)
            history = []
            t0 = time.time()
            last_metrics = {}
            try:
                for step in range(start_step, num_steps):
                    if self.preemption_event.is_set():
                        committed = False
                        if cfg.checkpointer is not None:
                            with phase("checkpoint_stall", step=step,
                                       emergency=True):
                                try:
                                    committed = self.checkpointer.emergency_save(
                                        step, state, aux={"input": it.state()}
                                        if hasattr(it, "state") else None) is not None
                                except CheckpointWriteError as e:
                                    # Stay on the Preempted protocol (exit
                                    # 143, resumable from an OLDER step)
                                    # even if the emergency commit failed —
                                    # e.g. a peer process died before its
                                    # shard and the short barrier timed out.
                                    print(f"[trainer] emergency save failed: {e}")
                        raise Preempted(step, committed=committed)
                    with phase("input_stall", step=step):
                        batch = next(it)
                    batch = jax.device_put(batch, batch_sh)
                    watchdog.beat(step)
                    if obs is not None:
                        obs.profiler.on_step_start(step)
                    # The first invocation traces + XLA-compiles; attribute
                    # it to "compile" (it includes that one step's compute).
                    warm = self._step_has_run
                    t_step = time.perf_counter()
                    with phase("compile" if not warm else "step", step=step):
                        state, metrics = step_fn(state, batch)
                        if (not warm and obs is not None and obs.config.mfu
                                and not cfg.distributed):
                            # Pre-pay the MFU numerator's one extra AOT
                            # lower+compile here, in the compile bucket —
                            # never in a warm step (which must stay within
                            # the <1% instrumentation budget).
                            self.step_cost_analysis()
                    step_dur = time.perf_counter() - t_step
                    self._step_has_run = True
                    if obs is not None:
                        obs.profiler.on_step_end(step)
                    if cfg.sdc_check_every_n and step % cfg.sdc_check_every_n == 0:
                        self._sdc_check(batch)
                    if step % cfg.log_every_n == 0 or step == num_steps - 1:
                        m = _flatten_metrics(metrics)
                        m["step"] = step
                        m["steps_per_s"] = (step - start_step + 1) / (time.time() - t0)
                        history.append(m)
                        last_metrics = m
                        if registry is not None:
                            self._export_step_metrics(
                                registry, m, step,
                                step_dur=step_dur if warm else None,
                                tokens_per_step=tokens_per_step)
                    if (cfg.checkpointer is not None and cfg.checkpoint_every_n
                            and (step + 1) % cfg.checkpoint_every_n == 0):
                        # Async save: the training thread pays only the
                        # device-side snapshot (+ any still-in-flight save);
                        # staging and the write run in the background.
                        with phase("checkpoint_stall", step=step):
                            self.checkpointer.save(
                                step + 1, state, aux={"input": it.state()}
                                if hasattr(it, "state") else None)
                    if step_hook is not None:
                        step_hook(step=step, state=state, metrics=metrics,
                                  trainer=self)
            except KeyboardInterrupt:
                # The watchdog timer interrupts the main thread on timeout
                # in "raise" mode; convert to the typed error. A genuine
                # Ctrl-C (watchdog never fired) re-raises unchanged.
                watchdog.check()
                raise
            finally:
                if hasattr(it, "close"):
                    it.close()
                # Disarm the timer on EVERY exit (a Preempted/fault-injected
                # unwind must not leave a live timer to interrupt the next
                # supervisor attempt). cancel() does not check(): a pending
                # WatchdogTimeout must not mask the in-flight exception.
                watchdog.cancel()
                # Telemetry survives every exit path: a preempted/crashed
                # run still leaves its trace + flushed metrics behind.
                if obs is not None:
                    obs.profiler.close()
                    registry.drain()
                    obs.save_trace()
            watchdog.stop()
            if cfg.checkpointer is not None:
                with phase("checkpoint_stall", step=num_steps,
                           final_wait=True):
                    self.checkpointer.wait()
            if obs is not None:
                obs.save_trace()  # include the final-wait span
            return {"state": state, "history": history, "final": last_metrics,
                    "num_params": tree_param_count(state["params"]),
                    "opt_state_bytes": self._opt_state_bytes,
                    "input_state": it.state() if hasattr(it, "state") else None,
                    "goodput": monitor.summary(),
                    "goodput_events": monitor.events,
                    "telemetry": obs.snapshot() if obs is not None else None,
                    "step_cost": dict(self._step_cost or {})}

    def _export_step_metrics(self, registry, m: Dict[str, float], step: int,
                             *, step_dur: Optional[float] = None,
                             tokens_per_step: int = 0):
        """Routes one logging step's metrics into the registry: gauges keyed
        ``train/<metric>`` and ``summaries/<module-path>`` (the values
        modules ``add_summary``'d, routed out of the jitted step), plus
        hardware gauges — per-step MFU from the compiled step's own cost
        analysis, tokens/s/device, and ``device.memory_stats()`` where the
        backend reports them (TPU/GPU peak HBM; empty on CPU)."""
        obs = self.observability
        for k, v in m.items():
            if k == "step":
                continue
            name = k if k.startswith("summaries/") else f"train/{k}"
            registry.gauge(name).set(v)
        if step_dur and step_dur > 0:
            registry.histogram("train/step_time_s").record(step_dur)
            n_dev = int(np.prod(self.config.mesh_shape))
            if tokens_per_step:
                registry.gauge("train/tokens_per_s").set(
                    tokens_per_step / step_dur)
                registry.gauge("train/tokens_per_s_per_device").set(
                    tokens_per_step / step_dur / n_dev)
            if obs.config.mfu:
                cost = self.step_cost_analysis()
                mfu = estimate_mfu(
                    cost.get("flops"), step_dur, num_devices=n_dev,
                    peak_flops_per_device=obs.config.peak_flops_per_device)
                if mfu is not None:
                    registry.gauge("hardware/mfu").set(mfu)
                if cost.get("flops"):
                    registry.gauge("hardware/step_flops").set(cost["flops"])
                if cost.get("peak_hbm_proxy_bytes"):
                    registry.gauge("hardware/peak_hbm_proxy_bytes").set(
                        cost["peak_hbm_proxy_bytes"])
        if not self._mem_stats_unavailable:
            stats = device_memory_stats()
            # Backends without memory stats (CPU) answer {} every time —
            # probe once, don't pay the query on every logging step.
            self._mem_stats_unavailable = not stats
            for k, v in stats.items():
                registry.gauge(f"hardware/memory/{k}").set(v)
        registry.flush()

    def _sdc_check(self, batch):
        """Paper §5: repeat a computation and compare for silent corruption."""
        x = batch[sorted(batch.keys())[0]]
        f = jax.jit(lambda a: jnp.sum(a.astype(jnp.float32) * 1.000001))
        r1, r2 = f(x), f(x)
        if not np.allclose(np.asarray(r1), np.asarray(r2)):
            raise RuntimeError(f"SDC detected: {r1} != {r2}")


class _Watchdog:
    """Warns (or raises) when a training step exceeds the timeout (§5).

    ``on_timeout="warn"`` prints and keeps going; ``on_timeout="raise"``
    raises :class:`WatchdogTimeout` from the training thread: the timer
    thread interrupts the main thread (``_thread.interrupt_main()`` — the
    run loop converts the resulting KeyboardInterrupt), and as a fallback
    for interrupt-immune blocking calls the next ``beat()``/``stop()``
    raises directly.
    """

    def __init__(self, timeout_s: Optional[float], on_timeout: str = "warn"):
        import threading

        if on_timeout not in ("warn", "raise"):
            raise ValueError(
                f"watchdog on_timeout must be 'warn' or 'raise', got "
                f"{on_timeout!r}")
        self.timeout = timeout_s
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None
        self.fired = []

    def _fire(self, step: int):
        self.fired.append(step)
        print(f"[watchdog] step {step} exceeded {self.timeout}s")
        if self.on_timeout == "raise":
            import _thread

            # Raises KeyboardInterrupt in the main thread (at the next
            # bytecode boundary) so a hung host loop actually unblocks;
            # SpmdTrainer.run converts it to WatchdogTimeout via check().
            _thread.interrupt_main()

    def check(self):
        if self.fired and self.on_timeout == "raise":
            raise WatchdogTimeout(
                f"Training step(s) {self.fired} exceeded the watchdog "
                f"timeout of {self.timeout}s")

    def beat(self, step: int):
        import threading

        if self.timeout is None:
            return
        self.stop()
        self._timer = threading.Timer(self.timeout, self._fire, args=(step,))
        self._timer.daemon = True
        self._timer.start()

    def cancel(self):
        """Disarms the timer without raising (safe inside ``finally``)."""
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None

    def stop(self):
        self.cancel()
        self.check()
