"""SpmdTrainer: config-driven distributed training loop (paper §3–§5).

Everything is a replaceable child module: model, learner, input pipeline,
checkpointer. Parallelism is configured — mesh shape + axis names + the
partition specs the layers already carry — never coded (§4.2). The exact
``train_step`` built here is what the AOT dry-run lowers, fulfilling the
paper's "a program that AOT-compiles will run at scale" property.
"""

from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.core.config import REQUIRED, ConfigBase, Required, config_class
from repro.core.module import Module, functional, no_context
from repro.core.utils import (
    make_mesh,
    named_sharding,
    resolve_spec,
    set_mesh,
    tree_param_count,
)
from repro.data.input import SyntheticInput
from repro.layers.base import ParameterSpec
from repro.trainer.learner import Learner, aggregate_aux_losses
from repro.trainer.optimizers import global_norm

__all__ = ["SpmdTrainer", "TrainState"]

TrainState = Dict[str, Any]  # {"step", "prng_key", "params", "opt_state"}


def opt_state_shardings(opt_state_shapes: Any, params_structure,
                        param_shardings: Any, mesh) -> Any:
    """Shardings for an optimizer state pytree: any subtree whose structure
    matches the params tree inherits the param shardings; other leaves are
    replicated (counts, schedules)."""
    from jax.sharding import NamedSharding, PartitionSpec

    replicated = NamedSharding(mesh, PartitionSpec()) if mesh else None

    def rec(node):
        if jax.tree.structure(node) == params_structure:
            return param_shardings
        if isinstance(node, tuple) and type(node) is not tuple:  # NamedTuple
            return type(node)(*[rec(x) for x in node])
        if isinstance(node, tuple):
            return tuple(rec(x) for x in node)
        if isinstance(node, list):
            return [rec(x) for x in node]
        if isinstance(node, dict):
            return {k: rec(v) for k, v in node.items()}
        return replicated

    return rec(opt_state_shapes)


class SpmdTrainer(Module):
    @config_class
    class Config(Module.Config):
        model: Required[ConfigBase] = REQUIRED
        learner: Learner.Config = Learner.Config()
        input: SyntheticInput.Config = SyntheticInput.Config()
        checkpointer: Optional[Checkpointer.Config] = None
        # --- parallelism is configuration (paper §4.2) ---
        mesh_shape: Tuple[int, ...] = (1,)
        mesh_axis_names: Tuple[str, ...] = ("data",)
        batch_partition: Any = (("pod", "data"),)  # applied to dim 0 of inputs
        # --- loop ---
        max_steps: int = 100
        seed: int = 0
        log_every_n: int = 10
        checkpoint_every_n: int = 0
        # Gradient accumulation (microbatching) — memory lever.
        grad_accum_steps: int = 1
        # Optimizer-state host offload (TPU feature; see DESIGN.md for the
        # CPU dry-run substitution).
        offload_optimizer_state: bool = False
        # Runtime resiliency (paper §5).
        watchdog_timeout_s: Optional[float] = None
        sdc_check_every_n: int = 0

    def __init__(self, cfg, *, parent=None):
        super().__init__(cfg, parent=parent)
        self._add_child("model", cfg.model)
        self._add_child("learner", cfg.learner)
        self._add_child("input", cfg.input)
        if cfg.checkpointer is not None:
            self._add_child("checkpointer", cfg.checkpointer)
        self._mesh = None
        self._jit_step = None

    # ----------------------------------------------------------------- setup

    @no_context
    def build_mesh(self):
        cfg = self.config
        if self._mesh is None:
            n = int(np.prod(cfg.mesh_shape))
            if n > len(jax.devices()):
                raise RuntimeError(
                    f"mesh {cfg.mesh_shape} needs {n} devices, "
                    f"have {len(jax.devices())}")
            self._mesh = make_mesh(cfg.mesh_shape, cfg.mesh_axis_names)
        return self._mesh

    @no_context
    def param_specs(self):
        return self.model.create_parameter_specs_recursively()

    @no_context
    def param_shardings(self, mesh=None):
        mesh = mesh or self.build_mesh()
        specs = self.param_specs()
        return jax.tree.map(
            lambda s: named_sharding(s.mesh_axes, mesh), specs,
            is_leaf=lambda s: isinstance(s, ParameterSpec))

    @no_context
    def batch_shardings(self, batch_like, mesh=None):
        mesh = mesh or self.build_mesh()
        cfg = self.config

        def shard(x):
            ndim = len(x.shape)
            spec = tuple(cfg.batch_partition) + (None,) * (ndim - len(cfg.batch_partition))
            return named_sharding(spec[:ndim], mesh)

        return jax.tree.map(shard, batch_like)

    # ------------------------------------------------------------------ state

    @no_context
    def init_state(self, prng_key: Optional[jax.Array] = None) -> TrainState:
        cfg = self.config
        if prng_key is None:
            prng_key = jax.random.PRNGKey(cfg.seed)
        self.learner.build(self.param_specs())
        params = self.model.initialize_parameters_recursively(prng_key)
        opt_state = self.learner.init_state(params)
        return {
            "step": jnp.zeros((), jnp.int32),
            "prng_key": prng_key,
            "params": params,
            "opt_state": opt_state,
        }

    @no_context
    def state_shardings(self, state_shapes: TrainState, mesh=None):
        from jax.sharding import NamedSharding, PartitionSpec

        mesh = mesh or self.build_mesh()
        cfg = self.config
        p_shardings = self.param_shardings(mesh)
        opt_sh = opt_state_shardings(
            state_shapes["opt_state"], jax.tree.structure(state_shapes["params"]),
            p_shardings, mesh)
        if cfg.offload_optimizer_state:
            opt_sh = jax.tree.map(
                lambda s: s.with_memory_kind("pinned_host") if s is not None else s,
                opt_sh)
        rep = NamedSharding(mesh, PartitionSpec())
        return {
            "step": rep,
            "prng_key": rep,
            "params": p_shardings,
            "opt_state": opt_sh,
        }

    # ------------------------------------------------------------- train step

    @no_context
    def make_train_step(self) -> Callable[[TrainState, Dict[str, Any]],
                                          Tuple[TrainState, Dict[str, Any]]]:
        cfg = self.config
        model = self.model
        learner = self.learner
        aux_weight = cfg.learner.aux_loss_weight
        aux_pattern = cfg.learner.aux_loss_pattern
        accum = cfg.grad_accum_steps

        def loss_fn(params, batch, step_key):
            (loss, _aux), col = functional(
                model, state=params, inputs=(batch,), prng_key=step_key,
                is_training=True)
            aux_total = aggregate_aux_losses(col, aux_pattern)
            total = loss + aux_weight * aux_total
            return total, {"loss": loss, "aux_loss": aux_total}

        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        def compute_grads(params, batch, step_key):
            if accum <= 1:
                (total, parts), grads = grad_fn(params, batch, step_key)
                return total, parts, grads

            def microbatch(carry, mb):
                acc_grads, acc_total, acc_loss, acc_aux = carry
                mb_key = jax.random.fold_in(step_key, mb["_idx"])
                (total, parts), grads = grad_fn(params, {k: v for k, v in mb.items()
                                                         if k != "_idx"}, mb_key)
                acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
                return (acc_grads, acc_total + total, acc_loss + parts["loss"],
                        acc_aux + parts["aux_loss"]), None

            split = {k: v.reshape((accum, v.shape[0] // accum) + v.shape[1:])
                     for k, v in batch.items()}
            split["_idx"] = jnp.arange(accum)
            zero_grads = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, total, loss, aux), _ = jax.lax.scan(
                microbatch, (zero_grads, 0.0, 0.0, 0.0), split)
            inv = 1.0 / accum
            grads = jax.tree.map(lambda g: g * inv, grads)
            return total * inv, {"loss": loss * inv, "aux_loss": aux * inv}, grads

        def train_step(state: TrainState, batch: Dict[str, Any]):
            step_key = jax.random.fold_in(state["prng_key"], state["step"])
            total, parts, grads = compute_grads(state["params"], batch, step_key)
            new_params, new_opt = learner.apply_updates(
                grads, state["opt_state"], state["params"])
            metrics = {
                "total_loss": total,
                "grad_norm": global_norm(grads),
                **parts,
            }
            new_state = {
                "step": state["step"] + 1,
                "prng_key": state["prng_key"],
                "params": new_params,
                "opt_state": new_opt,
            }
            return new_state, metrics

        return train_step

    # -------------------------------------------------------------------- run

    @no_context
    def run(self, num_steps: Optional[int] = None) -> Dict[str, Any]:
        cfg = self.config
        num_steps = num_steps or cfg.max_steps
        mesh = self.build_mesh()
        with set_mesh(mesh):
            state = self.init_state()
            state_shapes = jax.eval_shape(lambda: state)
            shardings = self.state_shardings(state_shapes, mesh)
            state = jax.device_put(state, shardings)

            sample = self.input.make_batch(0)
            batch_sh = self.batch_shardings(sample, mesh)
            step_fn = jax.jit(
                self.make_train_step(),
                in_shardings=(shardings, batch_sh),
                out_shardings=(shardings, None),
                donate_argnums=(0,),
            )

            start_step = 0
            if cfg.checkpointer is not None:
                latest = self.checkpointer.latest_step()
                if latest is not None:
                    state = self.checkpointer.restore(latest, like=state)
                    state = jax.device_put(state, shardings)
                    start_step = latest

            watchdog = _Watchdog(cfg.watchdog_timeout_s)
            history = []
            it = self.input.batches()
            t0 = time.time()
            last_metrics = {}
            for step in range(start_step, num_steps):
                batch = next(it)
                batch = jax.device_put(batch, batch_sh)
                watchdog.beat(step)
                state, metrics = step_fn(state, batch)
                if cfg.sdc_check_every_n and step % cfg.sdc_check_every_n == 0:
                    self._sdc_check(batch)
                if step % cfg.log_every_n == 0 or step == num_steps - 1:
                    m = {k: float(v) for k, v in metrics.items()}
                    m["step"] = step
                    m["steps_per_s"] = (step - start_step + 1) / (time.time() - t0)
                    history.append(m)
                    last_metrics = m
                if (cfg.checkpointer is not None and cfg.checkpoint_every_n
                        and (step + 1) % cfg.checkpoint_every_n == 0):
                    self.checkpointer.save(step + 1, jax.device_get(state))
            watchdog.stop()
            if cfg.checkpointer is not None:
                self.checkpointer.wait()
            return {"state": state, "history": history, "final": last_metrics,
                    "num_params": tree_param_count(state["params"])}

    def _sdc_check(self, batch):
        """Paper §5: repeat a computation and compare for silent corruption."""
        x = batch[sorted(batch.keys())[0]]
        f = jax.jit(lambda a: jnp.sum(a.astype(jnp.float32) * 1.000001))
        r1, r2 = f(x), f(x)
        if not np.allclose(np.asarray(r1), np.asarray(r2)):
            raise RuntimeError(f"SDC detected: {r1} != {r2}")


class _Watchdog:
    """Warns (or raises) when a training step exceeds the timeout (§5)."""

    def __init__(self, timeout_s: Optional[float]):
        import threading

        self.timeout = timeout_s
        self._timer: Optional[threading.Timer] = None
        self.fired = []

    def beat(self, step: int):
        import threading

        if self.timeout is None:
            return
        self.stop()
        self._timer = threading.Timer(
            self.timeout, lambda: self.fired.append(step) or print(
                f"[watchdog] step {step} exceeded {self.timeout}s"))
        self._timer.daemon = True
        self._timer.start()

    def stop(self):
        if self._timer is not None:
            self._timer.cancel()
            self._timer = None
