"""Rematerialization policies over *tagged* activations (paper §4.2).

Layers tag remat points with ``checkpoint_name`` (e.g. "attn_out",
"ffn_hidden", "q_proj", "kv_proj", "ffn_out", "moe_dispatch"). A policy spec
string — carried in configs, hence swappable by mesh rules — selects what to
save, offload, or recompute:

  "full"                      recompute everything (minimum HBM)
  "none"                      no remat
  "save:attn_out,ffn_out"     save listed names, recompute the rest
  "offload:ffn_hidden"        offload listed names to host, recompute rest
  "save_dots"                 save all matmul outputs (XLA heuristic policy)
"""

from __future__ import annotations

from typing import Callable, Optional

import jax

__all__ = ["policy_from_spec", "KNOWN_TAGS"]

# The tag vocabulary the layer library emits (kept in one place so configs
# and tests can validate against it).
KNOWN_TAGS = (
    "q_proj",
    "kv_proj",
    "attn_out",
    "ffn_hidden",
    "ffn_out",
    "moe_dispatch",
    "mixer_out",
)


def policy_from_spec(spec: Optional[str]) -> Optional[Callable]:
    """Returns a jax.checkpoint policy (None = save everything is NOT
    expressible — None here means 'recompute everything', i.e. plain remat)."""
    if spec is None or spec == "full":
        return None  # jax.checkpoint default: recompute everything
    if spec == "none":
        return jax.checkpoint_policies.everything_saveable
    if spec == "save_dots":
        return jax.checkpoint_policies.dots_saveable
    if spec.startswith("save:"):
        names = tuple(n for n in spec[len("save:"):].split(",") if n)
        return jax.checkpoint_policies.save_only_these_names(*names)
    if spec.startswith("offload:"):
        names = tuple(n for n in spec[len("offload:"):].split(",") if n)
        return jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=(),
            names_which_can_be_offloaded=names,
            offload_src="device",
            offload_dst="pinned_host",
        )
    if spec.startswith("save_offload:"):
        # "save_offload:<saved>;<offloaded>"
        saved_s, _, off_s = spec[len("save_offload:"):].partition(";")
        saved = tuple(n for n in saved_s.split(",") if n)
        off = tuple(n for n in off_s.split(",") if n)
        return jax.checkpoint_policies.save_and_offload_only_these_names(
            names_which_can_be_saved=saved,
            names_which_can_be_offloaded=off,
            offload_src="device",
            offload_dst="pinned_host",
        )
    raise ValueError(f"Unknown remat policy spec {spec!r}")
