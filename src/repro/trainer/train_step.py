"""Composable SPMD train-step builder (paper §3–§5).

``SpmdTrainer.make_train_step`` used to be one monolithic closure; the
pieces now compose so launchers/benchmarks can build custom steps from the
same parts the trainer uses:

  * :func:`make_loss_fn` — model forward + aux-loss aggregation.
  * :func:`make_grad_fn` — value_and_grad with microbatched gradient
    accumulation that accumulates in a configurable grad dtype (the policy's
    ``grad_dtype``) instead of hardcoded fp32 buffers, validates batch
    divisibility, and passes non-splittable batch entries (shared position
    arrays, scalars) through to every microbatch instead of crashing.
  * :func:`build_train_step` — grads -> learner update, with optional
    ZeRO-1 sharding constraints threaded to the learner.
  * :func:`zero1_partition_spec` — optimizer-state partitioning along the
    data axes (ZeRO-1 / optimizer-state sharding a la SageMaker MP): each
    param-shaped optimizer leaf gets one extra dim sharded over the data
    axes, shrinking per-device moment bytes ~Nx on an N-way data mesh.
"""

from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

from repro.core.module import functional
from repro.core.utils import maybe_shard, resolve_spec
from repro.layers.base import ParameterSpec
from repro.trainer.learner import aggregate_aux_losses

__all__ = [
    "scalar_summaries",
    "make_loss_fn",
    "make_grad_fn",
    "apply_state_updates",
    "build_train_step",
    "zero1_partition_spec",
    "constrain_tree",
    "slice_microbatch",
    "combine_microbatch_grads",
    "canonical_mean",
]

TrainState = Dict[str, Any]


# ---------------------------------------------------------------------------
# ZeRO-1 optimizer-state partitioning
# ---------------------------------------------------------------------------


def zero1_partition_spec(spec: ParameterSpec, mesh,
                         axes: Sequence[str] = ("pod", "data")) -> PartitionSpec:
    """ZeRO-1 sharding for one param-shaped optimizer-state leaf.

    Starts from the param's own partition spec and additionally shards the
    first dimension that is (a) not already sharded and (b) divisible by the
    total data-axis size, over the data axes. Falls back to the param spec
    when no dimension divides (tiny scalars/biases stay as-is — they are a
    rounding error of optimizer HBM).
    """
    base = tuple(spec.mesh_axes) if spec.mesh_axes is not None else ()
    base = base + (None,) * (len(spec.shape) - len(base))
    # Resolve against the mesh FIRST: an axis name absent from the mesh (or
    # dropped by resolve) means the dim is really replicated and fair game.
    resolved = tuple(resolve_spec(base, mesh))
    resolved = resolved + (None,) * (len(spec.shape) - len(resolved))
    # Only axes the param does not already use anywhere are addable — a
    # PartitionSpec must not name one mesh axis twice (FSDP-style params
    # that already shard over "data" need no ZeRO-1 help: their moments
    # inherit that sharding).
    used = set()
    for entry in resolved:
        for a in (entry if isinstance(entry, (tuple, list)) else (entry,)):
            if a is not None:
                used.add(a)
    addable = [a for a in axes
               if mesh is not None and a in mesh.axis_names and a not in used]
    n = 1
    for a in addable:
        n *= mesh.shape[a]
    if n <= 1:
        return PartitionSpec(*resolved)
    for d, (dim, entry) in enumerate(zip(spec.shape, resolved)):
        if entry is None and dim % n == 0:
            extra = tuple(addable) if len(addable) > 1 else addable[0]
            new = resolved[:d] + (extra,) + resolved[d + 1:]
            return PartitionSpec(*new)
    return PartitionSpec(*resolved)


def constrain_tree(tree: Any, specs: Optional[Any]) -> Any:
    """with_sharding_constraint over a matching tree of PartitionSpecs."""
    if specs is None:
        return tree
    return jax.tree.map(lambda x, s: maybe_shard(x, s), tree, specs)


# ---------------------------------------------------------------------------
# Loss / grads
# ---------------------------------------------------------------------------


def scalar_summaries(col) -> Dict[str, Any]:
    """The exportable slice of an ``OutputCollection``: scalar summaries
    (loss/accuracy, MoE load-balance stats, per-layer norms) keyed by module
    path. Non-scalar summaries (activation histograms etc.) stay in the
    collection for callers that want them — routing tensors out of every
    step would bloat the jitted step's outputs for no telemetry gain."""
    out = {}
    for k, v in col.summaries.items():
        if isinstance(v, (int, float)) or getattr(v, "shape", None) == ():
            out[k] = v
    return out


def make_loss_fn(model, *, aux_loss_weight: float = 1.0,
                 aux_loss_pattern: str = r".*/aux_loss$") -> Callable:
    """(params, batch, step_key) -> (total_loss, {"loss", "aux_loss",
    "summaries"}).

    ``summaries`` routes every scalar ``add_summary`` value out of the
    jitted step (they used to be collected into the OutputCollection and
    dropped) so the trainer can export them through the metrics registry.
    """

    def loss_fn(params, batch, step_key):
        (loss, _aux), col = functional(
            model, state=params, inputs=(batch,), prng_key=step_key,
            is_training=True)
        aux_total = aggregate_aux_losses(col, aux_loss_pattern)
        total = loss + aux_loss_weight * aux_total
        # State updates (fp8 amax histories) ride out of the collection
        # keyed by module path; build_train_step folds them back into the
        # params after the optimizer update.
        return total, {"loss": loss, "aux_loss": aux_total,
                       "summaries": scalar_summaries(col),
                       "state_updates": dict(col.state_updates)}

    return loss_fn


def _split_batch(batch: Dict[str, Any], accum: int):
    """Splits array entries with the global batch dim into ``accum``
    microbatches; everything else (shared position arrays, scalars,
    non-arrays) is passed through to every microbatch unchanged."""
    arrays = {k: v for k, v in batch.items()
              if hasattr(v, "shape") and getattr(v, "ndim", 0) >= 1}
    if not arrays:
        raise ValueError(
            "grad_accum_steps > 1 requires at least one array batch entry "
            f"with a leading batch dimension; got keys {sorted(batch)}")
    # The global batch dim: taken from 'labels'/'input_ids' when present so
    # sequence-shaped extras can't masquerade as the batch axis.
    for anchor in ("labels", "input_ids"):
        if anchor in arrays:
            B = arrays[anchor].shape[0]
            break
    else:
        B = arrays[sorted(arrays)[0]].shape[0]
    if B % accum != 0:
        raise ValueError(
            f"Global batch size {B} is not divisible by grad_accum_steps="
            f"{accum}; pick a batch size that is a multiple of the "
            f"accumulation steps (microbatch = batch/steps).")
    split, static = {}, {}
    for k, v in batch.items():
        if k in arrays and v.shape[0] == B:
            split[k] = v.reshape((accum, B // accum) + v.shape[1:])
        else:
            static[k] = v
    return split, static


def make_grad_fn(loss_fn: Callable, *, grad_accum_steps: int = 1,
                 grad_dtype: Optional[Any] = None) -> Callable:
    """(params, batch, step_key) -> (total, parts, grads).

    With ``grad_accum_steps > 1`` the batch is split into microbatches and
    gradients accumulate in ``grad_dtype`` (None -> each param's dtype, i.e.
    fp32 for master-weight training) across a ``lax.scan``.
    """
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    accum = grad_accum_steps

    def compute_grads(params, batch, step_key):
        if accum <= 1:
            (total, parts), grads = grad_fn(params, batch, step_key)
            return total, parts, grads

        split, static = _split_batch(batch, accum)

        def microbatch(acc_grads, mb):
            mb_key = jax.random.fold_in(step_key, mb["_idx"])
            mb_batch = {k: v for k, v in mb.items() if k != "_idx"}
            mb_batch.update(static)
            (total, parts), grads = grad_fn(params, mb_batch, mb_key)
            acc_grads = jax.tree.map(
                lambda a, g: a + g.astype(a.dtype), acc_grads, grads)
            # Scalar metrics (incl. the routed summaries subtree) ride as
            # scan outputs and are averaged over microbatches below.
            return acc_grads, {"_total": total, **parts}

        split["_idx"] = jnp.arange(accum)
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, grad_dtype or p.dtype), params)
        grads, parts_stack = jax.lax.scan(microbatch, zero_grads, split)
        inv = 1.0 / accum
        grads = jax.tree.map(lambda g: g * jnp.asarray(inv, g.dtype), grads)
        # State updates are amax-semantics (fp8 histories): microbatches
        # combine by elementwise max, not the scalar-metric mean — slot
        # [0] becomes the step's true amax, the rolled-forward tail is
        # identical across microbatches so max is the identity there.
        state_updates = parts_stack.pop("state_updates", {})
        parts = jax.tree.map(lambda x: jnp.mean(x, axis=0), parts_stack)
        if state_updates:
            parts["state_updates"] = jax.tree.map(
                lambda x: jnp.max(x, axis=0), state_updates)
        else:
            parts["state_updates"] = {}
        total = parts.pop("_total")
        return total, parts, grads

    return compute_grads


# ---------------------------------------------------------------------------
# Elastic (world-size-invariant) microbatch decomposition
# ---------------------------------------------------------------------------
#
# The elastic trainer decomposes every global batch into a FIXED number of
# canonical microbatches G, independent of how many processes share the work
# (each process computes a contiguous block of them with the same jitted
# per-microbatch program). Gradients are then combined on the host in
# canonical microbatch order with left-associative float32 arithmetic — the
# same programs, the same data, and the same addition order at every world
# size means bitwise-identical optimizer updates whether the job runs on 1
# process or N, which is what lets a resharded resume reproduce the
# uninterrupted loss curve exactly.


def slice_microbatch(batch: Dict[str, Any], mb_index: int,
                     num_microbatches: int) -> Dict[str, Any]:
    """Canonical microbatch ``mb_index`` of the GLOBAL batch: the contiguous
    row block ``[m*B/G, (m+1)*B/G)`` of every batch-dim entry; non-batch
    entries (shared position arrays, scalars) pass through unchanged."""
    arrays = {k: v for k, v in batch.items()
              if hasattr(v, "shape") and getattr(v, "ndim", 0) >= 1}
    for anchor in ("labels", "input_ids"):
        if anchor in arrays:
            B = arrays[anchor].shape[0]
            break
    else:
        B = arrays[sorted(arrays)[0]].shape[0]
    if B % num_microbatches != 0:
        raise ValueError(
            f"Global batch size {B} is not divisible by grad_microbatches="
            f"{num_microbatches}")
    sz = B // num_microbatches
    lo = mb_index * sz
    return {k: (v[lo:lo + sz] if k in arrays and v.shape[0] == B else v)
            for k, v in batch.items()}


def canonical_mean(values: Sequence[Any]) -> np.ndarray:
    """Left-associative float32 mean in the given (canonical) order. Every
    process must fold contributions through this exact reduction for the
    result to be bitwise world-size-invariant."""
    acc = np.zeros_like(np.asarray(values[0], np.float32))
    for v in values:
        acc = (acc + np.asarray(v, np.float32)).astype(np.float32)
    return (acc * np.float32(1.0 / len(values))).astype(np.float32)


def combine_microbatch_grads(per_mb_leaves: Sequence[Sequence[Any]],
                             treedef) -> Any:
    """Host-side mean of per-microbatch gradient contributions.

    ``per_mb_leaves[m]`` is microbatch ``m``'s flat leaf list (float32
    numpy arrays, in ``jax.tree_util.tree_flatten`` order). Accumulation is
    leaf-wise, left-associative over microbatches in canonical order — see
    :func:`canonical_mean` for why the order is load-bearing.
    """
    G = len(per_mb_leaves)
    accs = [np.array(leaf, dtype=np.float32, copy=True)
            for leaf in per_mb_leaves[0]]
    for leaves in per_mb_leaves[1:]:
        for i, leaf in enumerate(leaves):
            accs[i] += np.asarray(leaf, np.float32)
    inv = np.float32(1.0 / G)
    for i in range(len(accs)):
        accs[i] *= inv
    return jax.tree_util.tree_unflatten(treedef, accs)


# ---------------------------------------------------------------------------
# Full step
# ---------------------------------------------------------------------------


def apply_state_updates(params: Dict[str, Any],
                        updates: Dict[str, Any]) -> Dict[str, Any]:
    """Folds OutputCollection state updates back into a params tree.

    ``updates`` is keyed by "/"-joined module path (the InvocationContext
    naming scheme), which maps exactly onto params-dict nesting — Repeat
    re-emits scan-stacked updates under its ``layer`` subtree so stacked
    layouts address the same way. Copy-on-write: only the dicts along each
    updated path are rebuilt. Unknown paths raise (an update implies the
    leaf existed in the state the forward ran with).
    """

    def set_path(node, keys, value):
        key = keys[0]
        if not isinstance(node, dict) or key not in node:
            raise KeyError(
                f"state update path {'/'.join(keys)!r} not found in params")
        out = dict(node)
        if len(keys) == 1:
            old = node[key]
            out[key] = value.astype(old.dtype) \
                if hasattr(value, "astype") else value
        else:
            out[key] = set_path(node[key], keys[1:], value)
        return out

    for path, value in updates.items():
        params = set_path(params, path.split("/"), value)
    return params


def build_train_step(
    model,
    learner,
    *,
    aux_loss_weight: float = 1.0,
    aux_loss_pattern: str = r".*/aux_loss$",
    grad_accum_steps: int = 1,
    grad_dtype: Optional[Any] = None,
    update_partition_specs: Optional[Any] = None,  # ZeRO-1 specs per param
    param_partition_specs: Optional[Any] = None,
) -> Callable[[TrainState, Dict[str, Any]], Tuple[TrainState, Dict[str, Any]]]:
    """Composes loss -> grads -> update into the jittable train step.

    With ``update_partition_specs`` set (ZeRO-1), gradients are constrained
    to the data-sharded optimizer layout before the optimizer update (GSPMD
    lowers the psum into a reduce-scatter) and the applied params are
    constrained back to ``param_partition_specs`` afterwards — no explicit
    collectives anywhere, sharding constraints only (paper §4.2).
    """
    from repro.trainer.optimizers import global_norm

    loss_fn = make_loss_fn(model, aux_loss_weight=aux_loss_weight,
                           aux_loss_pattern=aux_loss_pattern)
    compute_grads = make_grad_fn(loss_fn, grad_accum_steps=grad_accum_steps,
                                 grad_dtype=grad_dtype)

    def train_step(state: TrainState, batch: Dict[str, Any]):
        step_key = jax.random.fold_in(state["prng_key"], state["step"])
        total, parts, grads = compute_grads(state["params"], batch, step_key)
        state_updates = parts.pop("state_updates", None)
        new_params, new_opt = learner.apply_updates(
            grads, state["opt_state"], state["params"],
            update_partition_specs=update_partition_specs,
            param_partition_specs=param_partition_specs)
        if state_updates:
            # Forward-pass state (fp8 amax histories) overwrites the
            # optimizer's view of those leaves — they are carried as
            # params only so they checkpoint/shard like everything else.
            new_params = apply_state_updates(new_params, state_updates)
        # Norm telemetry: grad/param/update norms are the first things a
        # diverging run's operator looks at, so they come out of every step
        # (computed inside jit — no extra dispatches, no retraces).
        update = jax.tree.map(
            lambda n, o: n.astype(jnp.float32) - o.astype(jnp.float32),
            new_params, state["params"])
        metrics = {
            "total_loss": total,
            "grad_norm": global_norm(grads),
            "param_norm": global_norm(new_params),
            "update_norm": global_norm(update),
            **parts,
        }
        new_state = {
            "step": state["step"] + 1,
            "prng_key": state["prng_key"],
            "params": new_params,
            "opt_state": new_opt,
        }
        return new_state, metrics

    return train_step
