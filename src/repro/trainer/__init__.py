from repro.trainer.learner import Learner, aggregate_aux_losses
from repro.trainer.trainer import SpmdTrainer
