"""Goodput monitor: wall-time attribution for a training run (paper §6).

Every second of a run is attributed to a bucket:

  ``step``             — productive training compute (the only goodput)
  ``compile``          — the first invocation of the jitted step (trace +
                         XLA compile; includes that one step's compute)
  ``init``             — param init / device placement / mesh build
  ``restore``          — checkpoint read + device placement on resume
  ``input_stall``      — the training thread waiting on the data iterator
  ``checkpoint_stall`` — the training thread blocked inside ``save()``
                         (snapshot + wait-for-previous-in-flight)
  ``restart_loss``     — *virtual*: step time whose results were lost to a
                         crash (recomputed after restarting from the last
                         committed checkpoint); attributed by the supervisor

plus an ``untracked`` remainder (logging, host loop overhead).

``bucket(name)`` is a context manager; each exit appends a structured event
``{"bucket", "t_start", "dur_s", ...meta}`` (and forwards it to an optional
``sink`` callable for streaming telemetry). ``summary()`` folds events into
per-bucket totals and the goodput fraction

    goodput = (step_total - restart_loss) / wall_total.

``restart_loss`` events are flagged ``virtual``: they re-attribute time that
was already recorded under ``step``, so they are excluded from the
wall-clock bucket sum (and from ``untracked``) but subtracted from
productive time.

On asynchronously-dispatching backends the ``step`` bucket measures host
dispatch + any device sync the loop performs; on the CPU substrate (sync
dispatch) it is exact device time.
"""

from __future__ import annotations

import contextlib
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["GoodputMonitor"]

PRODUCTIVE_BUCKET = "step"
VIRTUAL_BUCKETS = ("restart_loss",)


class GoodputMonitor:
    def __init__(self, *, sink: Optional[Callable[[dict], None]] = None,
                 time_fn: Callable[[], float] = time.monotonic):
        self.events: List[Dict[str, Any]] = []
        # Default metadata merged into every event (e.g. the supervisor tags
        # the restart attempt so lost step time can be attributed later).
        self.context: Dict[str, Any] = {}
        self._sink = sink
        self._time = time_fn
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None

    # ------------------------------------------------------------- recording

    def _touch(self, t: float):
        if self._t0 is None:
            self._t0 = t
        self._t_last = t

    def add_event(self, bucket: str, dur_s: float, **meta):
        """Appends a pre-measured event (used for virtual buckets)."""
        t = self._time()
        self._touch(t)
        event = {"bucket": bucket, "t_start": t - dur_s, "dur_s": float(dur_s),
                 **self.context, **meta}
        self.events.append(event)
        if self._sink is not None:
            self._sink(event)

    @contextlib.contextmanager
    def bucket(self, name: str, **meta):
        """Attributes the wall time of the enclosed block to ``name``."""
        t_start = self._time()
        self._touch(t_start)
        try:
            yield
        finally:
            t_end = self._time()
            self._touch(t_end)
            event = {"bucket": name, "t_start": t_start,
                     "dur_s": t_end - t_start, **self.context, **meta}
            self.events.append(event)
            if self._sink is not None:
                self._sink(event)

    # ------------------------------------------------------------- reporting

    def bucket_totals(self) -> Dict[str, float]:
        totals: Dict[str, float] = {}
        for e in self.events:
            totals[e["bucket"]] = totals.get(e["bucket"], 0.0) + e["dur_s"]
        return totals

    def summary(self) -> Dict[str, Any]:
        """The run summary: per-bucket seconds, wall total, goodput."""
        totals = self.bucket_totals()
        wall = ((self._t_last - self._t0)
                if self._t0 is not None and self._t_last is not None else 0.0)
        tracked = sum(v for k, v in totals.items() if k not in VIRTUAL_BUCKETS)
        productive = totals.get(PRODUCTIVE_BUCKET, 0.0)
        lost = sum(totals.get(k, 0.0) for k in VIRTUAL_BUCKETS)
        goodput = (productive - lost) / wall if wall > 0 else 0.0
        return {
            "wall_s": wall,
            "buckets": totals,
            "untracked_s": max(wall - tracked, 0.0),
            "productive_s": productive,
            "lost_s": lost,
            "goodput_fraction": max(goodput, 0.0),
            "num_events": len(self.events),
        }
