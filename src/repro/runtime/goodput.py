"""Goodput monitor: wall-time attribution for a training run (paper §6).

Every second of a run is attributed to a bucket:

  ``step``             — productive training compute (the only goodput)
  ``compile``          — the first invocation of the jitted step (trace +
                         XLA compile; includes that one step's compute)
  ``init``             — param init / device placement / mesh build
  ``restore``          — checkpoint read + device placement on resume
  ``input_stall``      — the training thread waiting on the data iterator
  ``checkpoint_stall`` — the training thread blocked inside ``save()``
                         (snapshot + wait-for-previous-in-flight)
  ``restart_loss``     — *virtual*: step time whose results were lost to a
                         crash (recomputed after restarting from the last
                         committed checkpoint); attributed by the supervisor

plus an ``untracked`` remainder (logging, host loop overhead).

``bucket(name)`` is a context manager; each exit appends a structured event
``{"bucket", "t_start", "dur_s", ...meta}`` (and forwards it to an optional
``sink`` callable for streaming telemetry). ``summary()`` folds events into
per-bucket totals and the goodput fraction

    goodput = (step_total - restart_loss) / wall_total.

``restart_loss`` events are flagged ``virtual``: they re-attribute time that
was already recorded under ``step``, so they are excluded from the
wall-clock bucket sum (and from ``untracked``) but subtracted from
productive time.

On asynchronously-dispatching backends the ``step`` bucket measures host
dispatch + any device sync the loop performs; on the CPU substrate (sync
dispatch) it is exact device time.
"""

from __future__ import annotations

import contextlib
import time
from typing import Any, Callable, Dict, List, Optional

__all__ = ["GoodputMonitor", "fleet_summary"]

PRODUCTIVE_BUCKET = "step"
VIRTUAL_BUCKETS = ("restart_loss",)
# One-time costs excluded from steady-state goodput: raw goodput on a short
# benchmark run is dominated by compile+init (e.g. 66%+27% of a 21 s run),
# which says nothing about the fraction a long production run would sustain.
STARTUP_BUCKETS = ("init", "compile", "restore")


class GoodputMonitor:
    def __init__(self, *, sink: Optional[Callable[[dict], None]] = None,
                 time_fn: Callable[[], float] = time.monotonic):
        self.events: List[Dict[str, Any]] = []
        # Default metadata merged into every event (e.g. the supervisor tags
        # the restart attempt so lost step time can be attributed later).
        self.context: Dict[str, Any] = {}
        self._sink = sink
        self._time = time_fn
        self._t0: Optional[float] = None
        self._t_last: Optional[float] = None

    # ------------------------------------------------------------- recording

    def _touch(self, t: float):
        if self._t0 is None:
            self._t0 = t
        self._t_last = t

    def add_event(self, bucket: str, dur_s: float, **meta):
        """Appends a pre-measured event (used for virtual buckets)."""
        t = self._time()
        self._touch(t)
        event = {"bucket": bucket, "t_start": t - dur_s, "dur_s": float(dur_s),
                 **self.context, **meta}
        self.events.append(event)
        if self._sink is not None:
            self._sink(event)

    @contextlib.contextmanager
    def bucket(self, name: str, **meta):
        """Attributes the wall time of the enclosed block to ``name``."""
        t_start = self._time()
        self._touch(t_start)
        try:
            yield
        finally:
            t_end = self._time()
            self._touch(t_end)
            event = {"bucket": name, "t_start": t_start,
                     "dur_s": t_end - t_start, **self.context, **meta}
            self.events.append(event)
            if self._sink is not None:
                self._sink(event)

    # ------------------------------------------------------------- reporting

    def bucket_totals(self) -> Dict[str, float]:
        totals: Dict[str, float] = {}
        for e in self.events:
            totals[e["bucket"]] = totals.get(e["bucket"], 0.0) + e["dur_s"]
        return totals

    def summary(self) -> Dict[str, Any]:
        """The run summary: per-bucket seconds, wall total, goodput."""
        totals = self.bucket_totals()
        wall = ((self._t_last - self._t0)
                if self._t0 is not None and self._t_last is not None else 0.0)
        tracked = sum(v for k, v in totals.items() if k not in VIRTUAL_BUCKETS)
        productive = totals.get(PRODUCTIVE_BUCKET, 0.0)
        lost = sum(totals.get(k, 0.0) for k in VIRTUAL_BUCKETS)
        goodput = (productive - lost) / wall if wall > 0 else 0.0
        # Steady state: what a long run would sustain once the one-time
        # startup costs (compile, init, restore) are amortized away.
        steady_wall = wall - sum(totals.get(b, 0.0) for b in STARTUP_BUCKETS)
        steady = (productive - lost) / steady_wall if steady_wall > 0 else 0.0
        return {
            "wall_s": wall,
            "buckets": totals,
            "untracked_s": max(wall - tracked, 0.0),
            "productive_s": productive,
            "lost_s": lost,
            "goodput_fraction": max(goodput, 0.0),
            "steady_wall_s": max(steady_wall, 0.0),
            "steady_goodput_fraction": min(max(steady, 0.0), 1.0),
            "num_events": len(self.events),
        }


def fleet_summary(rank_events: Dict[Any, List[Dict[str, Any]]], *,
                  lost_s: float = 0.0) -> Dict[str, Any]:
    """Folds per-rank goodput event streams into ONE fleet-level number.

    ``rank_events`` maps a stream id (e.g. ``(attempt, rank)``) to that
    worker's structured events. Fleet goodput is productive rank-seconds
    over total rank-seconds — the fraction of the fleet's aggregate
    capacity that trained: a rank idling in a barrier, recompiling after a
    restart, or recomputing lost steps all drag it down. ``lost_s`` is
    step time whose results a crash threw away (the supervisor computes it
    from the restart point), subtracted from the productive numerator like
    the monitor's virtual ``restart_loss`` bucket.
    """
    rank_seconds = 0.0
    totals: Dict[str, float] = {}
    for events in rank_events.values():
        if not events:
            continue
        t0 = min(e["t_start"] for e in events)
        t1 = max(e["t_start"] + e["dur_s"] for e in events)
        rank_seconds += max(t1 - t0, 0.0)
        for e in events:
            if e["bucket"] not in VIRTUAL_BUCKETS:
                totals[e["bucket"]] = totals.get(e["bucket"], 0.0) + e["dur_s"]
    productive = totals.get(PRODUCTIVE_BUCKET, 0.0)
    goodput = ((productive - lost_s) / rank_seconds) if rank_seconds > 0 \
        else 0.0
    steady_rank_s = rank_seconds - sum(totals.get(b, 0.0)
                                       for b in STARTUP_BUCKETS)
    steady = ((productive - lost_s) / steady_rank_s) if steady_rank_s > 0 \
        else 0.0
    return {
        "num_streams": len(rank_events),
        "rank_seconds": rank_seconds,
        "buckets": totals,
        "productive_s": productive,
        "lost_s": lost_s,
        "fleet_goodput_fraction": max(goodput, 0.0),
        "fleet_steady_goodput_fraction": min(max(steady, 0.0), 1.0),
    }
