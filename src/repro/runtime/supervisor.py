"""Restart supervisor: crash/preemption injection + resume verification (§5).

An in-process harness that runs :class:`SpmdTrainer` the way a cluster
controller would run a job: on a crash it "restarts the process" (a fresh
trainer instance — new jit cache, new iterator, nothing carried over except
the checkpoint directory) and lets the trainer resume from the latest
COMMITTED checkpoint; on a preemption it delivers the signal event and
expects an emergency checkpoint + zero lost steps.

Faults are injected from the trainer's ``step_hook`` so they land at exact
step boundaries:

  ``crash``   — raises :class:`SimulatedCrash` after the step (and, if a
                save was just launched, while that async write is in
                flight); the supervisor then ``abort()``s the checkpointer
                so the half-written step can never commit — the same
                observable outcome as SIGKILL, since shard writes are
                atomic and COMMITTED is written last.
  ``preempt`` — sets the trainer's preemption event; the loop notices at
                the next step boundary, takes a synchronous
                ``emergency_save()`` and raises :class:`Preempted`.

The supervisor attributes the step time lost to each crash (productive work
past the last committed checkpoint, which the restart recomputes) to the
goodput monitor's virtual ``restart_loss`` bucket, and keeps ONE monitor
across attempts so the summary spans the whole supervised run.

``run()`` returns the final trainer result plus ``losses`` — per-step loss
from whichever attempt last executed that step — and
:func:`assert_continuity` checks them against an uninterrupted reference:
with exact state restore and exactly-once data, the curves must match.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

from repro.runtime.goodput import GoodputMonitor
from repro.runtime.signals import Preempted, SimulatedCrash

__all__ = ["Fault", "Supervisor", "assert_continuity"]


@dataclasses.dataclass
class Fault:
    """One injected fault: fires once, after ``step`` executes."""

    step: int
    kind: str = "crash"  # "crash" | "preempt"
    fired: bool = False

    def __post_init__(self):
        if self.kind not in ("crash", "preempt"):
            raise ValueError(f"Unknown fault kind {self.kind!r}")


def assert_continuity(losses: Dict[int, float], reference: Dict[int, float],
                      *, atol: float = 0.0):
    """Asserts the supervised run's loss curve matches the reference run's
    (same steps, same losses) — the end-to-end correctness signal for
    checkpoint restore + exactly-once data delivery."""
    if set(losses) != set(reference):
        raise AssertionError(
            f"step sets differ: only-supervised={sorted(set(losses) - set(reference))[:5]} "
            f"only-reference={sorted(set(reference) - set(losses))[:5]}")
    bad = {s: (losses[s], reference[s]) for s in sorted(losses)
           if abs(losses[s] - reference[s]) > atol}
    if bad:
        first = list(bad.items())[:3]
        raise AssertionError(
            f"loss curve diverged at {len(bad)} step(s) (atol={atol}): {first}")


class Supervisor:
    """Runs a trainer config under fault injection with auto-restart."""

    def __init__(self, trainer_cfg, *, max_restarts: int = 8,
                 monitor: Optional[GoodputMonitor] = None):
        self.trainer_cfg = trainer_cfg
        self.max_restarts = max_restarts
        self.monitor = monitor if monitor is not None else GoodputMonitor()

    def run(self, num_steps: Optional[int] = None,
            faults: Sequence[Fault] = ()) -> Dict[str, Any]:
        faults = [dataclasses.replace(f, fired=False) for f in faults]
        losses: Dict[int, float] = {}
        restarts = 0
        attempts: List[Dict[str, Any]] = []
        while True:
            self.monitor.context["attempt"] = restarts
            trainer = self.trainer_cfg.clone().instantiate()
            executed: List[int] = []

            def hook(*, step, state, metrics, trainer=trainer,
                     executed=executed, **_):
                losses[step] = float(metrics["loss"])
                executed.append(step)
                for f in faults:
                    if not f.fired and f.step == step:
                        f.fired = True
                        if f.kind == "crash":
                            raise SimulatedCrash(step)
                        trainer.preemption_event.set()

            try:
                result = trainer.run(num_steps, monitor=self.monitor,
                                     step_hook=hook)
            except SimulatedCrash as e:
                ckpt = getattr(trainer, "checkpointer", None)
                latest = None
                if ckpt is not None:
                    # Process death: the in-flight async write never commits
                    # (abort joins the write thread, so latest_step() below
                    # cannot race a still-live committer).
                    ckpt.abort()
                    latest = ckpt.latest_step()
                lost_steps = [s for s in executed if s >= (latest or 0)]
                lost_s = sum(
                    ev["dur_s"] for ev in self.monitor.events
                    if ev["bucket"] == "step"
                    and ev.get("attempt") == restarts
                    and ev.get("step") in lost_steps)
                self.monitor.add_event("restart_loss", lost_s, virtual=True,
                                       crash_step=e.step,
                                       resumed_from=latest or 0,
                                       lost_steps=len(lost_steps))
                attempts.append({"outcome": "crash", "at_step": e.step,
                                 "resumed_from": latest or 0})
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                continue
            except Preempted as e:
                attempts.append({"outcome": "preempt", "at_step": e.step,
                                 "resumed_from": e.step})
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                continue
            attempts.append({"outcome": "completed"})
            result["losses"] = losses
            result["restarts"] = restarts
            result["attempts"] = attempts
            result["goodput"] = self.monitor.summary()
            return result
