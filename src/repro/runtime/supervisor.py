"""Restart supervisor: crash/preemption injection + resume verification (§5).

An in-process harness that runs :class:`SpmdTrainer` the way a cluster
controller would run a job: on a crash it "restarts the process" (a fresh
trainer instance — new jit cache, new iterator, nothing carried over except
the checkpoint directory) and lets the trainer resume from the latest
COMMITTED checkpoint; on a preemption it delivers the signal event and
expects an emergency checkpoint + zero lost steps.

Faults are injected from the trainer's ``step_hook`` so they land at exact
step boundaries:

  ``crash``   — raises :class:`SimulatedCrash` after the step (and, if a
                save was just launched, while that async write is in
                flight); the supervisor then ``abort()``s the checkpointer
                so the half-written step can never commit — the same
                observable outcome as SIGKILL, since shard writes are
                atomic and COMMITTED is written last.
  ``preempt`` — sets the trainer's preemption event; the loop notices at
                the next step boundary, takes a synchronous
                ``emergency_save()`` and raises :class:`Preempted`.

The supervisor attributes the step time lost to each crash (productive work
past the last committed checkpoint, which the restart recomputes) to the
goodput monitor's virtual ``restart_loss`` bucket, and keeps ONE monitor
across attempts so the summary spans the whole supervised run.

``run()`` returns the final trainer result plus ``losses`` — per-step loss
from whichever attempt last executed that step — and
:func:`assert_continuity` checks them against an uninterrupted reference:
with exact state restore and exactly-once data, the curves must match.
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.runtime.goodput import GoodputMonitor, fleet_summary
from repro.runtime.signals import Preempted, SimulatedCrash

__all__ = ["Fault", "Supervisor", "assert_continuity",
           "FleetFault", "FleetSupervisor", "latest_committed_step",
           "step_boundary_skew"]


def step_boundary_skew(rank_events: Dict[Tuple[int, int], List[dict]]
                       ) -> Dict[str, Any]:
    """Straggler gauge from per-rank goodput streams: for every step that
    more than one rank reported, the spread (max − min) across ranks of the
    step's *completion* time (``t_start + dur_s``, ``time.monotonic`` —
    comparable across processes on one host). A persistently large skew
    means one rank finishes its step late every iteration and the others
    burn that time waiting in the gradient collective."""
    by_step: Dict[Tuple[int, int], Dict[int, float]] = {}
    for (attempt, rank), evs in rank_events.items():
        for e in evs:
            if e.get("bucket") != "step" or "step" not in e:
                continue
            if "t_start" not in e or "dur_s" not in e:
                continue
            by_step.setdefault((attempt, e["step"]), {})[rank] = (
                e["t_start"] + e["dur_s"])
    skews: Dict[Tuple[int, int], float] = {
        key: max(by_rank.values()) - min(by_rank.values())
        for key, by_rank in by_step.items() if len(by_rank) > 1}
    if not skews:
        return {"num_steps": 0, "max_skew_s": 0.0, "mean_skew_s": 0.0,
                "max_skew_step": None}
    worst = max(skews, key=skews.get)
    return {
        "num_steps": len(skews),
        "max_skew_s": skews[worst],
        "mean_skew_s": sum(skews.values()) / len(skews),
        "max_skew_step": worst[1],
    }


@dataclasses.dataclass
class Fault:
    """One injected fault: fires once, after ``step`` executes."""

    step: int
    kind: str = "crash"  # "crash" | "preempt"
    fired: bool = False

    def __post_init__(self):
        if self.kind not in ("crash", "preempt"):
            raise ValueError(f"Unknown fault kind {self.kind!r}")


def assert_continuity(losses: Dict[int, float], reference: Dict[int, float],
                      *, atol: float = 0.0):
    """Asserts the supervised run's loss curve matches the reference run's
    (same steps, same losses) — the end-to-end correctness signal for
    checkpoint restore + exactly-once data delivery."""
    if set(losses) != set(reference):
        raise AssertionError(
            f"step sets differ: only-supervised={sorted(set(losses) - set(reference))[:5]} "
            f"only-reference={sorted(set(reference) - set(losses))[:5]}")
    bad = {s: (losses[s], reference[s]) for s in sorted(losses)
           if abs(losses[s] - reference[s]) > atol}
    if bad:
        first = list(bad.items())[:3]
        raise AssertionError(
            f"loss curve diverged at {len(bad)} step(s) (atol={atol}): {first}")


class Supervisor:
    """Runs a trainer config under fault injection with auto-restart."""

    def __init__(self, trainer_cfg, *, max_restarts: int = 8,
                 monitor: Optional[GoodputMonitor] = None):
        self.trainer_cfg = trainer_cfg
        self.max_restarts = max_restarts
        self.monitor = monitor if monitor is not None else GoodputMonitor()

    def run(self, num_steps: Optional[int] = None,
            faults: Sequence[Fault] = ()) -> Dict[str, Any]:
        faults = [dataclasses.replace(f, fired=False) for f in faults]
        losses: Dict[int, float] = {}
        restarts = 0
        attempts: List[Dict[str, Any]] = []
        while True:
            self.monitor.context["attempt"] = restarts
            trainer = self.trainer_cfg.clone().instantiate()
            executed: List[int] = []

            def hook(*, step, state, metrics, trainer=trainer,
                     executed=executed, **_):
                losses[step] = float(metrics["loss"])
                executed.append(step)
                for f in faults:
                    if not f.fired and f.step == step:
                        f.fired = True
                        if f.kind == "crash":
                            raise SimulatedCrash(step)
                        trainer.preemption_event.set()

            try:
                result = trainer.run(num_steps, monitor=self.monitor,
                                     step_hook=hook)
            except SimulatedCrash as e:
                ckpt = getattr(trainer, "checkpointer", None)
                latest = None
                if ckpt is not None:
                    # Process death: the in-flight async write never commits
                    # (abort joins the write thread, so latest_step() below
                    # cannot race a still-live committer).
                    ckpt.abort()
                    latest = ckpt.latest_step()
                lost_steps = [s for s in executed if s >= (latest or 0)]
                lost_s = sum(
                    ev["dur_s"] for ev in self.monitor.events
                    if ev["bucket"] == "step"
                    and ev.get("attempt") == restarts
                    and ev.get("step") in lost_steps)
                self.monitor.add_event("restart_loss", lost_s, virtual=True,
                                       crash_step=e.step,
                                       resumed_from=latest or 0,
                                       lost_steps=len(lost_steps))
                attempts.append({"outcome": "crash", "at_step": e.step,
                                 "resumed_from": latest or 0})
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                continue
            except Preempted as e:
                attempts.append({"outcome": "preempt", "at_step": e.step,
                                 "resumed_from": e.step})
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                continue
            attempts.append({"outcome": "completed"})
            result["losses"] = losses
            result["restarts"] = restarts
            result["attempts"] = attempts
            result["goodput"] = self.monitor.summary()
            return result


# ---------------------------------------------------------------------------
# Fleet supervision: real worker *processes*, elastic world size
# ---------------------------------------------------------------------------


def latest_committed_step(checkpoint_dir: str) -> Optional[int]:
    """The newest ``step_*`` dir containing COMMITTED, or None."""
    latest = None
    if not os.path.isdir(checkpoint_dir):
        return None
    for name in os.listdir(checkpoint_dir):
        if not name.startswith("step_"):
            continue
        if not os.path.exists(os.path.join(checkpoint_dir, name, "COMMITTED")):
            continue
        try:
            step = int(name[len("step_"):])
        except ValueError:
            continue
        latest = step if latest is None else max(latest, step)
    return latest


@dataclasses.dataclass
class FleetFault:
    """One injected fleet fault, fired during attempt ``attempt``.

    ``sigkill``   — SIGKILL rank ``rank`` at the boundary of ``step`` (the
                    worker kills itself in its step hook, so the kill lands
                    at an exact step — and mid-async-save if ``step`` just
                    launched one). Peers block in the next collective until
                    it times out; the supervisor reaps everyone.
    ``sigterm``   — cluster preemption notice: EVERY rank sets its
                    preemption event at ``step`` (an individual-rank SIGTERM
                    would deadlock peers waiting in step collectives while
                    the victim sits in the emergency-save barrier). All
                    ranks emergency-save through the commit barrier and
                    exit 143 with zero lost steps.
    ``save_kill`` — rank ``rank`` dies INSIDE the checkpoint write of the
                    save launched at ``step``, after leaving a torn tmp
                    shard behind: the torn-commit drill. COMMITTED must
                    never appear for that step.
    """

    attempt: int
    step: int
    kind: str = "sigkill"  # "sigkill" | "sigterm" | "save_kill"
    rank: int = 0

    def __post_init__(self):
        if self.kind not in ("sigkill", "sigterm", "save_kill"):
            raise ValueError(f"Unknown fleet fault kind {self.kind!r}")


def _read_jsonl(path: str) -> List[dict]:
    """Reads a worker result stream, tolerating a torn final line (the
    worker may be SIGKILLed mid-write)."""
    records: List[dict] = []
    if not os.path.exists(path):
        return records
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                records.append(json.loads(line))
            except json.JSONDecodeError:
                pass
    return records


class FleetSupervisor:
    """Supervises an elastic fleet of real worker OS processes.

    Each *attempt* launches ``schedule[min(attempt, len-1)]`` workers
    (``python -m repro.launch.distributed``) against one shared checkpoint
    directory and a fresh per-attempt coordination directory. When the
    attempt dies — a rank SIGKILLed, a torn save, a fleet-wide preemption —
    the next attempt restarts from the latest COMMITTED checkpoint, possibly
    at a *different* world size (that is the elasticity drill: the schedule
    IS the resharding plan).

    ``run()`` merges per-rank result streams into one loss curve (asserting
    every step's loss is identical across the ranks that executed it — the
    SPMD replication invariant), attributes recomputed step time to
    ``restart_loss``, and aggregates per-rank goodput events into a single
    fleet number via :func:`~repro.runtime.goodput.fleet_summary`.
    """

    def __init__(self, workdir: str, *,
                 schedule: Sequence[int] = (1,),
                 steps: int = 12,
                 grad_microbatches: int = 0,
                 builder: str =
                 "repro.launch.distributed:build_tiny_fleet_config",
                 builder_kwargs: Optional[dict] = None,
                 collective_timeout_s: float = 20.0,
                 max_restarts: int = 8,
                 trace: bool = False):
        if not schedule:
            raise ValueError("schedule needs at least one world size")
        self.workdir = workdir
        self.schedule = tuple(schedule)
        self.steps = steps
        self.grad_microbatches = grad_microbatches
        self.builder = builder
        self.builder_kwargs = dict(builder_kwargs or {})
        self.collective_timeout_s = collective_timeout_s
        self.max_restarts = max_restarts
        # trace=True arms per-rank Chrome traces (pid lane = rank) and
        # merges them into <workdir>/trace.json when the fleet completes.
        self.trace = trace
        self.checkpoint_dir = os.path.join(workdir, "ckpt")
        os.makedirs(self.checkpoint_dir, exist_ok=True)

    # ------------------------------------------------------------- internals

    def _spawn(self, attempt: int, world: int,
               fault: Optional[FleetFault]) -> List[subprocess.Popen]:
        from repro.launch.distributed import worker_argv

        import repro

        coord = os.path.join(self.workdir, f"coord{attempt}")
        os.makedirs(coord, exist_ok=True)
        env = dict(os.environ)
        # repro may be a namespace package (__file__ is None) — resolve the
        # import root from __path__ so workers see the same tree we do.
        src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        procs = []
        for rank in range(world):
            kw: Dict[str, Any] = {}
            if fault is not None:
                if fault.kind == "sigterm":
                    kw["sigterm_at_step"] = fault.step
                elif fault.kind == "sigkill" and rank == fault.rank:
                    kw["sigkill_at_step"] = fault.step
                elif fault.kind == "save_kill" and rank == fault.rank:
                    kw["kill_during_save_step"] = fault.step
            argv = worker_argv(
                sys.executable, builder=self.builder,
                builder_kwargs=self.builder_kwargs,
                coordinator_dir=coord, process_index=rank,
                process_count=world,
                grad_microbatches=self.grad_microbatches,
                checkpoint_dir=self.checkpoint_dir,
                result=self._result_path(attempt, rank),
                steps=self.steps,
                collective_timeout_s=self.collective_timeout_s,
                trace=self._trace_path(attempt, rank) if self.trace else "",
                **kw)
            procs.append(subprocess.Popen(
                argv, env=env, stdout=subprocess.DEVNULL,
                stderr=subprocess.DEVNULL))
        return procs

    def _result_path(self, attempt: int, rank: int) -> str:
        return os.path.join(self.workdir, f"a{attempt}_r{rank}.jsonl")

    def _trace_path(self, attempt: int, rank: int) -> str:
        return os.path.join(self.workdir, f"a{attempt}_r{rank}_trace.json")

    def _merge_traces(self, num_attempts: int) -> Optional[str]:
        """Merge every per-rank trace written so far into one fleet trace
        (one pid lane per rank; a rank that died and came back continues on
        the same lane — SIGKILLed attempts may have no file to merge)."""
        from repro.observability.tracing import merge_traces

        paths = []
        for attempt in range(num_attempts):
            world = self.schedule[min(attempt, len(self.schedule) - 1)]
            for rank in range(world):
                p = self._trace_path(attempt, rank)
                if os.path.exists(p):
                    paths.append(p)
        if not paths:
            return None
        out = os.path.join(self.workdir, "trace.json")
        merge_traces(paths, out_path=out)
        return out

    def _babysit(self, procs: List[subprocess.Popen]) -> List[int]:
        """Waits the attempt out. A non-(0|143) exit is a crash: survivors
        are blocked in collectives doomed to time out, so they are reaped
        immediately. A clean/preempted exit starts a grace window for the
        rest (peers may still be draining their own emergency saves)."""
        grace = self.collective_timeout_s + 15.0
        deadline = None
        while True:
            codes = [p.poll() for p in procs]
            if all(c is not None for c in codes):
                break
            if any(c is not None and c not in (0, 143) for c in codes):
                for p in procs:
                    if p.poll() is None:
                        p.kill()
                for p in procs:
                    p.wait()
                break
            if any(c is not None for c in codes):
                if deadline is None:
                    deadline = time.monotonic() + grace
                elif time.monotonic() > deadline:
                    for p in procs:
                        if p.poll() is None:
                            p.kill()
                    for p in procs:
                        p.wait()
                    break
            time.sleep(0.05)
        return [p.returncode for p in procs]

    # ------------------------------------------------------------------- run

    def run(self, faults: Sequence[FleetFault] = ()) -> Dict[str, Any]:
        losses: Dict[int, float] = {}
        attempts: List[Dict[str, Any]] = []
        rank_events: Dict[Tuple[int, int], List[dict]] = {}
        lost_s_total = 0.0
        finals: List[dict] = []
        attempt = 0
        while True:
            world = self.schedule[min(attempt, len(self.schedule) - 1)]
            fault = next((f for f in faults if f.attempt == attempt), None)
            started_from = latest_committed_step(self.checkpoint_dir)
            procs = self._spawn(attempt, world, fault)
            codes = self._babysit(procs)

            attempt_steps: Dict[int, Dict[int, float]] = {}
            preempted = []
            for rank in range(world):
                records = _read_jsonl(self._result_path(attempt, rank))
                rank_events[(attempt, rank)] = [
                    {k: v for k, v in r.items() if k != "kind"}
                    for r in records if r.get("kind") == "event"]
                for r in records:
                    if r.get("kind") == "step":
                        attempt_steps.setdefault(
                            r["step"], {})[rank] = r["loss"]
                    elif r.get("kind") == "preempted":
                        preempted.append(r)
                    elif r.get("kind") == "final":
                        finals.append({"attempt": attempt, "rank": rank, **r})

            # SPMD replication invariant: a step's loss is identical on
            # every rank that reported it (the batch is global-view and the
            # fold is canonical).
            for step, by_rank in attempt_steps.items():
                vals = set(by_rank.values())
                if len(vals) > 1:
                    raise AssertionError(
                        f"attempt {attempt} step {step}: ranks disagree on "
                        f"loss: {by_rank}")
                losses[step] = next(iter(vals))

            crashed = any(c not in (0, 143) for c in codes)
            if crashed:
                committed = latest_committed_step(self.checkpoint_dir)
                resume_at = (committed if committed is not None else -1)
                lost_steps = [s for s in attempt_steps if s >= resume_at + 1]
                lost = sum(
                    e["dur_s"] for (a, _), evs in rank_events.items()
                    if a == attempt for e in evs
                    if e.get("bucket") == "step"
                    and e.get("step") in lost_steps)
                lost_s_total += lost
                attempts.append({
                    "outcome": "crash", "world_size": world,
                    "exit_codes": codes,
                    "resumed_from": committed,
                    "lost_steps": len(lost_steps),
                    "started_from": started_from})
            elif any(c == 143 for c in codes):
                committed = latest_committed_step(self.checkpoint_dir)
                attempts.append({
                    "outcome": "preempt", "world_size": world,
                    "exit_codes": codes,
                    "resumed_from": committed,
                    "preempted": preempted,
                    "started_from": started_from})
            else:
                attempts.append({
                    "outcome": "completed", "world_size": world,
                    "exit_codes": codes, "started_from": started_from})
                goodput = fleet_summary(rank_events, lost_s=lost_s_total)
                input_state = next(
                    (f.get("input_state") for f in finals
                     if f["attempt"] == attempt and f["rank"] == 0), None)
                return {
                    "losses": losses,
                    "restarts": attempt,
                    "attempts": attempts,
                    "goodput": goodput,
                    "input_state": input_state,
                    "finals": finals,
                    "straggler": step_boundary_skew(rank_events),
                    "trace_path": (self._merge_traces(attempt + 1)
                                   if self.trace else None),
                }
            attempt += 1
            if attempt - 1 >= self.max_restarts:
                raise RuntimeError(
                    f"fleet exceeded max_restarts={self.max_restarts}: "
                    f"{attempts}")
