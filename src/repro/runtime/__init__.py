"""Fault-tolerant training runtime (paper §5–§6).

The trainer runs *inside* this layer: goodput attribution
(:mod:`repro.runtime.goodput`), preemption signaling
(:mod:`repro.runtime.signals`), and the restart supervisor
(:mod:`repro.runtime.supervisor`). The supervisor drives trainer *configs*
(instantiating them per attempt), so nothing here imports the trainer and
the trainer can import this package freely.
"""

from repro.runtime.goodput import GoodputMonitor
from repro.runtime.signals import Preempted, SimulatedCrash, install_preemption_handler
from repro.runtime.supervisor import Fault, Supervisor, assert_continuity

__all__ = [
    "Fault",
    "GoodputMonitor",
    "Preempted",
    "SimulatedCrash",
    "Supervisor",
    "assert_continuity",
    "install_preemption_handler",
]
