"""Preemption signaling: typed runtime exceptions + SIGTERM wiring (§5).

Cloud schedulers announce a preemption by SIGTERM with a grace window. The
handler here only sets a ``threading.Event`` (async-signal-safe); the
training loop polls it between steps, takes a synchronous
``emergency_save()``, and raises :class:`Preempted` — so the expensive work
runs on the training thread with the full runtime available, never inside
the signal handler.
"""

from __future__ import annotations

import signal
import threading
from typing import Iterable, Optional

__all__ = ["Preempted", "SimulatedCrash", "install_preemption_handler"]


class Preempted(RuntimeError):
    """The run stopped for a preemption signal AFTER committing an emergency
    checkpoint (``.step`` = the resumable step)."""

    def __init__(self, step: int, committed: bool = True):
        super().__init__(f"preempted at step {step} "
                         f"({'emergency checkpoint committed' if committed else 'no checkpointer'})")
        self.step = step
        self.committed = committed


class SimulatedCrash(RuntimeError):
    """Fault-injection stand-in for a hard process death (supervisor tests)."""

    def __init__(self, step: int):
        super().__init__(f"simulated crash at step {step}")
        self.step = step


def install_preemption_handler(
        event: threading.Event,
        signals: Iterable[int] = (signal.SIGTERM,)) -> dict:
    """Routes ``signals`` to ``event.set()``; returns {signum: old_handler}
    so a launcher can restore them."""
    previous = {}

    def _handler(signum, frame):  # noqa: ARG001
        event.set()

    for s in signals:
        previous[s] = signal.signal(s, _handler)
    return previous
