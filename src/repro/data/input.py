"""Synthetic-but-deterministic input pipelines (one per modality).

The input module is replaceable like any other component (paper §1: "any
module is replaceable, including the input pipeline"). Each pipeline yields
host-local numpy batches; the trainer shards them onto the mesh.

Modalities:
  lm     -> {"input_ids", "labels"}                                 (text)
  vlm    -> + {"input_embeddings" (patch prefix)}                   (phi-3-vision)
  audio  -> {"input_embeddings", "mask_positions", "labels"}        (hubert)

For text, tokens follow a deterministic Zipfian-ish stream with a
learnable-structure component (token t depends on t-1) so tiny-model
overfit tests can actually reduce loss.
"""

from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.core.config import REQUIRED, Required, config_class
from repro.core.module import Module, no_context

__all__ = ["SyntheticInput", "SyntheticIterator"]


class SyntheticIterator:
    """Resumable batch iterator (explicit-state protocol, paper §5).

    Every input iterator in this repo implements ``state() -> dict`` (small,
    JSON-serializable) and ``restore(state)``; the trainer checkpoints the
    state alongside the model so a resume is *exactly-once* w.r.t. data —
    the old sequential-RNG ``batches()`` replayed from batch 0 after any
    restore. Batches are keyed by the batch index, so the state is just the
    cursor.
    """

    def __init__(self, input_module: "SyntheticInput"):
        self._input = input_module
        self._next = 0

    def __iter__(self) -> "SyntheticIterator":
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        batch = self._input.make_batch(self._next)
        self._next += 1
        return batch

    def state(self) -> dict:
        """State s.t. ``restore(state)`` makes the next batch this one."""
        return {"next_batch": self._next}

    def restore(self, state: dict):
        self._next = int(state["next_batch"])


class SyntheticInput(Module):
    @config_class
    class Config(Module.Config):
        task: str = "lm"  # lm | vlm | audio
        vocab_size: Required[int] = REQUIRED
        seq_len: Required[int] = REQUIRED
        global_batch_size: Required[int] = REQUIRED
        seed: int = 0
        model_dim: Optional[int] = None  # for vlm/audio embeddings
        num_patches: int = 16  # vlm prefix length
        mask_prob: float = 0.3  # audio masking
        # Data-parallel process sharding (paper: host-sharded input pipeline).
        process_index: int = 0
        process_count: int = 1

    @no_context
    def host_batch_size(self) -> int:
        cfg = self.config
        assert cfg.global_batch_size % cfg.process_count == 0
        return cfg.global_batch_size // cfg.process_count

    @no_context
    def batches(self) -> "SyntheticIterator":
        """A resumable iterator: each batch is generated from its index (not
        a sequentially-consumed RNG), so `state()`/`restore()` is exact."""
        return SyntheticIterator(self)

    @no_context
    def make_batch(self, step: int, rng: Optional[np.random.Generator] = None
                   ) -> Dict[str, np.ndarray]:
        cfg = self.config
        if rng is None:
            rng = np.random.default_rng(
                cfg.seed * 1000 + cfg.process_index + step * 7919)
        B, S, V = self.host_batch_size(), cfg.seq_len, cfg.vocab_size

        if cfg.task in ("lm", "vlm"):
            # Markov-ish stream: next = (3*prev + noise) % V -> learnable.
            start = rng.integers(0, V, size=(B, 1))
            noise = rng.integers(0, 7, size=(B, S))
            ids = np.zeros((B, S), np.int32)
            ids[:, 0] = start[:, 0]
            for t in range(1, S):
                ids[:, t] = (3 * ids[:, t - 1] + noise[:, t]) % V
            labels = np.concatenate([ids[:, 1:], np.full((B, 1), -100, np.int32)], 1)
            batch = {"input_ids": ids, "labels": labels.astype(np.int32)}
            if cfg.task == "vlm":
                assert cfg.model_dim, "vlm input needs model_dim"
                P = cfg.num_patches
                batch["input_embeddings"] = rng.standard_normal(
                    (B, P, cfg.model_dim)).astype(np.float32)
                # Text labels under the image prefix are ignored.
                batch["labels"][:, :P] = -100
            return batch

        if cfg.task == "audio":
            assert cfg.model_dim, "audio input needs model_dim"
            feats = rng.standard_normal((B, S, cfg.model_dim)).astype(np.float32)
            mask = rng.random((B, S)) < cfg.mask_prob
            # Unit targets correlated with the (pre-mask) features.
            labels = (np.abs(feats[..., 0] * 1000).astype(np.int64) % V).astype(np.int32)
            return {"input_embeddings": feats,
                    "mask_positions": mask,
                    "labels": labels}

        raise ValueError(f"Unknown task {cfg.task!r}")
