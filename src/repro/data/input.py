"""Synthetic-but-deterministic input pipelines (one per modality).

The input module is replaceable like any other component (paper §1: "any
module is replaceable, including the input pipeline"). Each pipeline yields
host-local numpy batches; the trainer shards them onto the mesh.

Modalities:
  lm     -> {"input_ids", "labels"}                                 (text)
  vlm    -> + {"input_embeddings" (patch prefix)}                   (phi-3-vision)
  audio  -> {"input_embeddings", "mask_positions", "labels"}        (hubert)

For text, tokens follow a deterministic Zipfian-ish stream with a
learnable-structure component (token t depends on t-1) so tiny-model
overfit tests can actually reduce loss.
"""

from __future__ import annotations

from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.core.config import REQUIRED, Required, config_class
from repro.core.module import Module, no_context

__all__ = ["SyntheticInput"]


class SyntheticInput(Module):
    @config_class
    class Config(Module.Config):
        task: str = "lm"  # lm | vlm | audio
        vocab_size: Required[int] = REQUIRED
        seq_len: Required[int] = REQUIRED
        global_batch_size: Required[int] = REQUIRED
        seed: int = 0
        model_dim: Optional[int] = None  # for vlm/audio embeddings
        num_patches: int = 16  # vlm prefix length
        mask_prob: float = 0.3  # audio masking
        # Data-parallel process sharding (paper: host-sharded input pipeline).
        process_index: int = 0
        process_count: int = 1

    @no_context
    def host_batch_size(self) -> int:
        cfg = self.config
        assert cfg.global_batch_size % cfg.process_count == 0
        return cfg.global_batch_size // cfg.process_count

    @no_context
    def batches(self) -> Iterator[Dict[str, np.ndarray]]:
        cfg = self.config
        rng = np.random.default_rng(cfg.seed * 1000 + cfg.process_index)
        B, S, V = self.host_batch_size(), cfg.seq_len, cfg.vocab_size
        step = 0
        while True:
            yield self.make_batch(step, rng)
            step += 1

    @no_context
    def make_batch(self, step: int, rng: Optional[np.random.Generator] = None
                   ) -> Dict[str, np.ndarray]:
        cfg = self.config
        if rng is None:
            rng = np.random.default_rng(
                cfg.seed * 1000 + cfg.process_index + step * 7919)
        B, S, V = self.host_batch_size(), cfg.seq_len, cfg.vocab_size

        if cfg.task in ("lm", "vlm"):
            # Markov-ish stream: next = (3*prev + noise) % V -> learnable.
            start = rng.integers(0, V, size=(B, 1))
            noise = rng.integers(0, 7, size=(B, S))
            ids = np.zeros((B, S), np.int32)
            ids[:, 0] = start[:, 0]
            for t in range(1, S):
                ids[:, t] = (3 * ids[:, t - 1] + noise[:, t]) % V
            labels = np.concatenate([ids[:, 1:], np.full((B, 1), -100, np.int32)], 1)
            batch = {"input_ids": ids, "labels": labels.astype(np.int32)}
            if cfg.task == "vlm":
                assert cfg.model_dim, "vlm input needs model_dim"
                P = cfg.num_patches
                batch["input_embeddings"] = rng.standard_normal(
                    (B, P, cfg.model_dim)).astype(np.float32)
                # Text labels under the image prefix are ignored.
                batch["labels"][:, :P] = -100
            return batch

        if cfg.task == "audio":
            assert cfg.model_dim, "audio input needs model_dim"
            feats = rng.standard_normal((B, S, cfg.model_dim)).astype(np.float32)
            mask = rng.random((B, S)) < cfg.mask_prob
            # Unit targets correlated with the (pre-mask) features.
            labels = (np.abs(feats[..., 0] * 1000).astype(np.int64) % V).astype(np.int32)
            return {"input_embeddings": feats,
                    "mask_positions": mask,
                    "labels": labels}

        raise ValueError(f"Unknown task {cfg.task!r}")
