from repro.data.input import SyntheticInput
