from repro.data.input import SyntheticInput, SyntheticIterator
from repro.data.streaming import (
    PrefetchIterator,
    StreamingTextInput,
    StreamingTextIterator,
)
