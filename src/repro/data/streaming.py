"""Streaming packed-sequence text input with exact resume + prefetch.

Models the production text path (AXLearn §5; Modalities' resumable
dataloaders): a document stream is packed into fixed-length training rows,
a background prefetch thread hides input latency behind the training step,
and the iterator exposes the explicit-state protocol (``state() -> dict`` /
``restore(state)``) so the trainer can checkpoint the data cursor alongside
the model — restore is exactly-once, no replayed or skipped tokens.

The document *source* here is synthetic-but-deterministic (document ``d``
is a pure function of ``d`` and the seed — the same Markov stream the
trainer overfits on), standing in for a tokenized corpus shard; swapping in
a real reader only changes ``_document()``.

Packing: documents are concatenated with an EOS separator into a flat token
buffer; each batch row is a ``seq_len + 1`` window (inputs = ``[:-1]``,
labels = ``[1:]``); the label at each EOS position is masked (-100) so the
model is never trained to predict across a document boundary from the
separator itself. Host-sharding assigns document ``d`` to process
``d % process_count``.
"""

from __future__ import annotations

import queue
import threading
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.config import REQUIRED, Required, config_class
from repro.core.module import Module, no_context

__all__ = ["StreamingTextInput", "StreamingTextIterator", "PrefetchIterator",
           "reshard_streaming_states"]

IGNORE_LABEL = -100


class StreamingTextIterator:
    """Packs the document stream into batches; state = (cursor, buffer)."""

    def __init__(self, input_module: "StreamingTextInput"):
        self._input = input_module
        self._next_doc = input_module.config.process_index
        self._buffer: List[int] = []
        self._emitted = 0

    def __iter__(self) -> "StreamingTextIterator":
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        cfg = self._input.config
        B = self._input.host_batch_size()
        S = cfg.seq_len
        need = B * (S + 1)
        while len(self._buffer) < need:
            self._buffer.extend(self._input.document_tokens(self._next_doc))
            self._buffer.append(cfg.eos_id)
            self._next_doc += cfg.process_count
        rows = np.asarray(self._buffer[:need], np.int32).reshape(B, S + 1)
        del self._buffer[:need]
        self._emitted += 1
        ids = rows[:, :-1]
        labels = rows[:, 1:].copy()
        labels[ids == cfg.eos_id] = IGNORE_LABEL
        return {"input_ids": ids, "labels": labels}

    def state(self) -> dict:
        """JSON-serializable; restore() makes the next batch this iterator's
        next batch — the leftover packing buffer is part of the cursor."""
        return {
            "next_doc": int(self._next_doc),
            "buffer": [int(t) for t in self._buffer],
            "emitted": int(self._emitted),
        }

    def restore(self, state: dict):
        self._next_doc = int(state["next_doc"])
        self._buffer = [int(t) for t in state["buffer"]]
        self._emitted = int(state.get("emitted", 0))


def reshard_streaming_states(input_cfg, states: List[dict],
                             new_count: int) -> List[dict]:
    """Recomputes streaming-iterator states for a new world size.

    ``states`` are the per-process iterator states saved by a checkpoint at
    world size P; the return value is one state per process at world size
    ``new_count``, positioned at the SAME global batch index — batch-level
    exactly-once across the reshard (no global batch is replayed or
    skipped).

    Works by replay: every saved state carries ``emitted`` (the number of
    batches this rank consumed, identical across ranks of a lockstep SPMD
    job — verified here); a fresh iterator per new rank is fast-forwarded
    that many batches. Document streams are pure functions of (seed, doc),
    so replay is cheap and deterministic.

    Content caveat: under ``doc % process_count`` host sharding, the
    document→rank assignment (and hence batch *content*) depends on world
    size, so resharded content differs even though positions line up.
    Elastic training instead runs inputs in the global-view contract
    (``process_count == 1`` on every rank — see
    :class:`~repro.trainer.mesh_rules.ElasticModifier`), where this
    function degenerates to an identity recompute and the loss curve is
    world-size invariant.
    """
    if not states:
        raise ValueError("need at least one saved iterator state")
    emitted = {int(s.get("emitted", 0)) for s in states}
    if len(emitted) != 1:
        raise ValueError(
            f"ranks out of lockstep: per-rank emitted counts "
            f"{sorted(emitted)} differ — refusing to reshard a torn "
            f"data cursor")
    n_batches = emitted.pop()
    out = []
    for rank in range(new_count):
        cfg = input_cfg.clone().set(process_index=rank,
                                    process_count=new_count, prefetch=0)
        it = StreamingTextIterator(cfg.instantiate())
        for _ in range(n_batches):
            next(it)
        out.append(it.state())
    return out


class PrefetchIterator:
    """Background-thread prefetch over any resumable iterator.

    The producer records the inner iterator's state *after* generating each
    batch and enqueues ``(batch, state)`` pairs, so ``state()`` on the
    consumer side reflects exactly the batches consumed — prefetched-but-
    unconsumed batches are never silently skipped by a checkpoint/restore.
    Producer exceptions re-raise on the consuming (training) thread.
    """

    _SENTINEL = object()

    def __init__(self, inner: Any, *, depth: int = 2):
        assert depth >= 1, depth
        self._inner = inner
        self._queue: "queue.Queue" = queue.Queue(maxsize=depth)
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._last_state: Optional[dict] = None
        self._error: Optional[BaseException] = None

    def _produce(self):
        try:
            while not self._stop.is_set():
                batch = next(self._inner)
                state = self._inner.state()
                while not self._stop.is_set():
                    try:
                        self._queue.put((batch, state), timeout=0.1)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:  # noqa: BLE001 — re-raised on consumer
            self._error = e
            # Keep trying to deliver the sentinel until it lands (or we are
            # closed): a full queue must not swallow the error and leave the
            # consumer blocked forever.
            while not self._stop.is_set():
                try:
                    self._queue.put(self._SENTINEL, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def _ensure_started(self):
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._produce, daemon=True, name="input-prefetch")
            self._thread.start()

    def __iter__(self) -> "PrefetchIterator":
        return self

    def __next__(self) -> Any:
        self._ensure_started()
        while True:
            try:
                item = self._queue.get(timeout=1.0)
                break
            except queue.Empty:
                # Liveness check: never block forever on a dead producer.
                if self._error is not None:
                    raise self._error
                if self._thread is not None and not self._thread.is_alive():
                    raise RuntimeError(
                        "prefetch producer thread died without an error")
        if item is self._SENTINEL:
            raise self._error
        batch, state = item
        self._last_state = state
        return batch

    def state(self) -> dict:
        """The inner state as of the last *consumed* batch."""
        if self._last_state is not None:
            return self._last_state
        return self._inner.state()

    def restore(self, state: dict):
        assert self._thread is None, \
            "restore() must be called before the first batch is consumed"
        self._inner.restore(state)

    def close(self):
        """Stops the producer thread (idempotent)."""
        self._stop.set()
        if self._thread is not None:
            # Unblock a producer waiting on a full queue.
            try:
                while True:
                    self._queue.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=5.0)
            self._thread = None


class StreamingTextInput(Module):
    @config_class
    class Config(Module.Config):
        vocab_size: Required[int] = REQUIRED
        seq_len: Required[int] = REQUIRED
        global_batch_size: Required[int] = REQUIRED
        seed: int = 0
        eos_id: int = 1
        # Document lengths are uniform in [min_doc_len, max_doc_len].
        min_doc_len: int = 8
        max_doc_len: int = 64
        # Prefetch-queue depth; 0 disables the background thread.
        prefetch: int = 2
        # Data-parallel process sharding (paper: host-sharded input pipeline).
        process_index: int = 0
        process_count: int = 1

    @no_context
    def host_batch_size(self) -> int:
        cfg = self.config
        assert cfg.global_batch_size % cfg.process_count == 0
        return cfg.global_batch_size // cfg.process_count

    @no_context
    def document_tokens(self, doc: int) -> List[int]:
        """Document ``doc`` as a token list — a pure function of (seed, doc),
        so any resume point regenerates identical data. Tokens live in
        [2, vocab) (0 reserved, 1 = EOS) and follow the same learnable
        Markov structure as SyntheticInput."""
        cfg = self.config
        rng = np.random.default_rng(cfg.seed * 7919 + doc)
        n = int(rng.integers(cfg.min_doc_len, cfg.max_doc_len + 1))
        lo, span = 2, max(cfg.vocab_size - 2, 1)
        noise = rng.integers(0, 7, size=n)
        toks = np.zeros(n, np.int64)
        toks[0] = lo + int(rng.integers(0, span))
        for t in range(1, n):
            toks[t] = lo + (3 * (toks[t - 1] - lo) + noise[t]) % span
        return toks.tolist()

    @no_context
    def batches(self):
        """A resumable (and, if ``prefetch > 0``, prefetched) iterator."""
        cfg = self.config
        it: Any = StreamingTextIterator(self)
        if cfg.prefetch > 0:
            it = PrefetchIterator(it, depth=cfg.prefetch)
        return it

    @no_context
    def make_batch(self, step: int, rng: Optional[np.random.Generator] = None
                   ) -> Dict[str, np.ndarray]:
        """Batch ``step`` of a fresh stream (trainer uses this for the
        sharding sample; O(step) — fine for step 0/tests)."""
        it = StreamingTextIterator(self)
        batch = next(it)
        for _ in range(step):
            batch = next(it)
        return batch
