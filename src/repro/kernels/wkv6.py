"""Chunked WKV6 (RWKV6 core) Pallas TPU kernel.

TPU adaptation of the RWKV6 CUDA kernel: instead of one-thread-per-channel
serial recurrence, the sequence is chunked; within a chunk everything is
dense (K x K / K x V) matmul work for the MXU, and the (H, K, V) state is
carried across the sequential chunk grid dimension in VMEM scratch — the
same carry pattern as the flash-attention kernel.

Grid: (B * H, num_chunks) with the chunk axis sequential ("arbitrary").
Per (b, h, chunk):
  logw        = log w (chunk, K)           decay logs
  cum/cum_ex  = inclusive/exclusive prefix sums
  o = (r * e^{cum_ex}) @ s                           state contribution
    + tril_strict((r e^{cum_ex - mid}) (k e^{mid - cum})^T) @ v   intra-chunk
    + ((r*u) . k) v                                   current-token bonus
  s = e^{total} * s + (k e^{total - cum})^T @ v       state update

Forward only (training uses the chunked jnp form which autodiffs); decode
uses the O(1) recurrent step. Validated in interpret mode vs
``ref.reference_wkv6_recurrent``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

__all__ = ["wkv6_forward"]


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, s_out_ref,
            s_scr, *, chunk: int, num_chunks: int, num_heads: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)  # (C, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (C, V)
    w = w_ref[0].astype(jnp.float32)  # (C, K), in (0, 1)
    u = u_ref[0].astype(jnp.float32)  # (1, K) -> broadcast

    logw = jnp.log(jnp.maximum(w, 1e-20))
    cum = jnp.cumsum(logw, axis=0)  # inclusive
    cum_ex = cum - logw  # exclusive
    total = cum[-1:]  # (1, K)
    mid = cum[chunk // 2][None]  # (1, K) re-centering for fp32 range

    s = s_scr[...]  # (K, V)
    # state contribution
    r_dec = r * jnp.exp(cum_ex)
    o = jax.lax.dot_general(r_dec, s, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk (strictly causal)
    ri = r * jnp.exp(cum_ex - mid)
    kj = k * jnp.exp(mid - cum)
    att = jax.lax.dot_general(ri, kj, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (C, C)
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    att = jnp.where(cols < rows, att, 0.0)
    o = o + jax.lax.dot_general(att, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # current-token bonus
    bonus = jnp.sum(r * u * k, axis=1, keepdims=True)  # (C, 1)
    o = o + bonus * v
    o_ref[0] = o.astype(o_ref.dtype)

    # state update
    k_dec = k * jnp.exp(total - cum)
    s_new = jnp.exp(total).T * s + jax.lax.dot_general(
        k_dec, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scr[...] = s_new

    @pl.when(ci == num_chunks - 1)
    def _finalize():
        s_out_ref[0] = s_new


def wkv6_forward(
    r: jax.Array,  # (B, T, H, K)
    k: jax.Array,
    v: jax.Array,  # (B, T, H, V)
    w: jax.Array,  # (B, T, H, K)
    u: jax.Array,  # (H, K)
    state: Optional[jax.Array] = None,  # (B, H, K, V)
    *,
    chunk_size: int = 64,
    interpret: bool = False,
):
    B, T, H, K = r.shape
    V = v.shape[-1]
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)
    if T % chunk_size != 0:
        from repro.kernels import ref as _ref

        return _ref.reference_wkv6(r, k, v, w, u, state, chunk_size=chunk_size)
    C = chunk_size
    n_chunks = T // C

    # Head-major: (B*H, T, *); chunk index becomes the sequential grid dim.
    def hm(x, d):
        return x.transpose(0, 2, 1, 3).reshape(B * H, T, d)

    rh, kh, wh = hm(r, K), hm(k, K), hm(w, K)
    vh = hm(v, V)
    sh = state.reshape(B * H, K, V)
    uh = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, 1, K)

    grid = (B * H, n_chunks)

    def seq_index(bh, ci):
        return (bh, ci, 0)

    def head_index(bh, ci):
        return (bh, 0, 0)

    kernel = functools.partial(_kernel, chunk=C, num_chunks=n_chunks,
                               num_heads=H)
    out, s_out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, C, K), seq_index),
            pl.BlockSpec((1, C, K), seq_index),
            pl.BlockSpec((1, C, V), seq_index),
            pl.BlockSpec((1, C, K), seq_index),
            pl.BlockSpec((1, 1, K), head_index),
            pl.BlockSpec((1, K, V), head_index),
        ],
        out_specs=[
            pl.BlockSpec((1, C, V), seq_index),
            pl.BlockSpec((1, K, V), head_index),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * H, T, V), r.dtype),
            jax.ShapeDtypeStruct((B * H, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(rh, kh, vh, wh, uh, sh)

    out = out.reshape(B, H, T, V).transpose(0, 2, 1, 3)
    return out, s_out.reshape(B, H, K, V)
