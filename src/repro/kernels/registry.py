"""Hardware-agnostic kernel registry: capability-based dispatch (paper §4.2).

The paper's claim is that per-backend kernel choices (cuDNN / NKI /
SplashAttention / Pallas) live in ~10 lines of mesh-rule config, never in
model code. This module is the mechanism: every kernel implementation
registers a :class:`KernelSpec` — op name, backend id, supported platforms,
and a *capability predicate* over the call's features — and
:func:`resolve` picks the highest-priority eligible implementation for the
detected platform. Layers never branch on impl strings; they carry one
:class:`KernelConfig` sub-config and call the dispatchers in
``repro.kernels.ops``.

Adding a backend = registering specs in one file + (optionally) one mesh
rule that rewrites ``KernelConfig`` — zero model-code changes.

Ops and backends registered here:

  op                 backends (priority order)
  ----------------   -----------------------------------------
  attention.fwd      pallas > pallas:interpret > blockwise > ref
  attention.decode   pallas > pallas:interpret > ref
  rmsnorm            pallas > pallas:interpret > ref
  wkv6               pallas > pallas:interpret > ref
  wkv6.decode        ref (O(1) recurrent step)

``ref`` backends are pure-XLA and eligible everywhere; they are also the
numerical oracles (``repro.kernels.ref``). ``pallas:interpret`` runs the
Mosaic kernels through the Pallas interpreter on any platform — it is never
auto-selected unless ``KernelConfig.interpret=True`` (it is slow), but can
always be requested explicitly.

Resolution is memoized: the (op, backend, features) triple is hashable and
the cached lookup is a single dict hit (<1µs — see ``bench_kernels``), so
dispatch adds no per-call or per-trace overhead on hot paths.
"""

from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.config import ConfigBase, config_class

__all__ = [
    "KernelConfig",
    "KernelFeatures",
    "KernelSpec",
    "KernelDispatchError",
    "register",
    "resolve",
    "resolve_backend",
    "registered_ops",
    "registered_backends",
    "clear_dispatch_cache",
    "dispatch_cache_stats",
]

logger = logging.getLogger(__name__)


# ---------------------------------------------------------------------------
# The one kernel config every kernel-calling layer shares (tentpole API).
# ---------------------------------------------------------------------------


@config_class
class KernelConfig(ConfigBase):
    """Unified kernel selection + tiling config (replaces the old scattered
    ``impl`` / ``decode_impl`` / ``kernel_interpret`` / ``blockwise_chunk_size``
    knobs).

    ``backend``: "auto" resolves per-op against the registry for the current
        platform; any registered backend id ("pallas", "pallas:interpret",
        "blockwise", "ref") forces that backend for every op this layer calls
        (resolution errors list each rejected candidate with its reason).
    ``op_overrides``: per-op backend ids, taking precedence over ``backend``
        (e.g. ``{"attention.decode": "pallas"}``).
    ``interpret``: run Pallas kernels through the interpreter (validation
        off-TPU). Also lets "auto" select the interpret backend, and turns an
        explicit "pallas" selection into "pallas:interpret".
    The remaining fields are per-backend tiling knobs — a per-hardware tiling
    table is one ``KernelModifier`` mesh rule away.
    """

    backend: str = "auto"
    op_overrides: Optional[Dict[str, str]] = None
    interpret: bool = False
    # Pallas flash-attention forward/backward tiles.
    block_q: int = 128
    block_k: int = 128
    # Pallas flash-decode KV tile.
    decode_block_k: int = 256
    # XLA blockwise attention (query-chunked scan).
    blockwise_chunk_size: int = 512
    blockwise_unroll: bool = False
    # WKV6 chunk length (Pallas grid / ref scan).
    wkv_chunk_size: int = 64
    wkv_unroll: bool = False
    # Pallas RMSNorm row tile.
    rmsnorm_block_rows: int = 256

    def backend_for(self, op: str) -> str:
        """The backend id this config requests for ``op`` ("auto" included).

        ``interpret=True`` turns a "pallas" request into "pallas:interpret"
        so explicit pallas selections stay runnable off-TPU.
        """
        backend = self.backend
        if self.op_overrides:
            backend = self.op_overrides.get(op, backend)
        if backend == "pallas" and self.interpret:
            backend = "pallas:interpret"
        return backend


# ---------------------------------------------------------------------------
# Features + specs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class KernelFeatures:
    """Hashable description of one kernel call site, as seen at trace time.

    Capability predicates accept/reject on these. ``explicit`` is set by
    :func:`resolve` when the caller named a backend — predicates may waive
    *heuristic* rejections (e.g. "1-token query is GEMV-bound") for explicit
    requests, but must keep *correctness* rejections unconditional.
    """

    platform: str = "cpu"  # jax.default_backend(): "cpu" | "tpu" | "gpu"
    dtype: str = "float32"
    interpret: bool = False
    explicit: bool = False
    needs_grad: bool = False
    # q/k positions are not provably the same contiguous stream.
    ragged_positions: bool = False
    # 1-token query (decode-shaped call into the full-sequence op).
    single_query: bool = False
    paged: bool = False
    sliding_window: bool = False
    # KV cache is replicated / unsharded across the mesh (decode ops).
    replicated_cache: bool = True
    # S' > 1 query into the decode op: a chunked-prefill or speculative
    # draft-verify window rather than a 1-token step. Lets backends pick
    # different tiling (the query dim becomes a real matmul dim) and lets
    # the dispatch cache keep verify- and decode-shaped resolutions apart.
    multi_query: bool = False
    # KV cache *storage* dtype (decode ops). Quantized pools ("int8",
    # "float8_e4m3fn") carry per-slot scales in a scale_pool leaf and need
    # a backend that dequantizes — in-kernel (pallas paged) or at gather
    # (ref); plain float caches are a pass-through astype.
    kv_dtype: str = "float32"

    def __post_init__(self):
        # Hash once at construction: dispatch-cache lookups are on the
        # trace hot path and must not re-hash 12 fields per call (<1µs
        # amortized resolve budget, see bench_kernels).
        object.__setattr__(self, "_hash", hash((
            self.platform, self.dtype, self.interpret, self.explicit,
            self.needs_grad, self.ragged_positions, self.single_query,
            self.paged, self.sliding_window, self.replicated_cache,
            self.multi_query, self.kv_dtype)))

    def __hash__(self):  # noqa: D105 — dataclass respects explicit __hash__
        return self._hash


# A predicate returns None (eligible) or a human-readable rejection reason.
CapabilityPredicate = Callable[[KernelFeatures], Optional[str]]


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered kernel implementation."""

    op: str
    backend: str
    fn: Optional[Callable]
    # Platform names this impl lowers on; "*" = any.
    platforms: Tuple[str, ...] = ("*",)
    priority: int = 0
    supports: Optional[CapabilityPredicate] = None
    # Import-time availability (satellite: wkv6 import failures are explicit
    # and logged, never silently swallowed into a ref fallback).
    available: bool = True
    unavailable_reason: str = ""

    def rejection_reason(self, features: KernelFeatures) -> Optional[str]:
        """None if eligible for ``features``, else why not."""
        if not self.available:
            return f"unavailable at import time: {self.unavailable_reason}"
        if "*" not in self.platforms and features.platform not in self.platforms:
            return (f"requires platform in {list(self.platforms)} "
                    f"(running on {features.platform!r})")
        if self.supports is not None:
            return self.supports(features)
        return None


class KernelDispatchError(RuntimeError):
    """No eligible kernel: the message enumerates every candidate and the
    reason it was rejected (the registry's debuggability contract)."""


_REGISTRY: Dict[str, Dict[str, KernelSpec]] = {}
_DISPATCH_CACHE: Dict[Tuple[str, str, KernelFeatures], KernelSpec] = {}
_CACHE_STATS = {"hits": 0, "misses": 0}


def register(spec: KernelSpec) -> KernelSpec:
    """Registers (or replaces) ``spec`` under (op, backend) and clears the
    dispatch cache. Replacement is what lets a new backend file override or
    extend the built-ins without editing this module."""
    _REGISTRY.setdefault(spec.op, {})[spec.backend] = spec
    _DISPATCH_CACHE.clear()
    if not spec.available:
        logger.warning("kernel %s/%s registered UNAVAILABLE: %s",
                       spec.op, spec.backend, spec.unavailable_reason)
    return spec


def registered_ops() -> List[str]:
    return sorted(_REGISTRY)


def registered_backends(op: str) -> List[str]:
    """Backend ids registered for ``op``, highest priority first."""
    specs = _op_specs(op)
    return [s.backend for s in sorted(specs.values(),
                                      key=lambda s: -s.priority)]


def clear_dispatch_cache() -> None:
    _DISPATCH_CACHE.clear()
    _CACHE_STATS["hits"] = _CACHE_STATS["misses"] = 0


def dispatch_cache_stats() -> Dict[str, int]:
    return dict(_CACHE_STATS, size=len(_DISPATCH_CACHE))


def _op_specs(op: str) -> Dict[str, KernelSpec]:
    if op not in _REGISTRY:
        raise KernelDispatchError(
            f"Unknown kernel op {op!r}; registered ops: {registered_ops()}")
    return _REGISTRY[op]


def resolve(op: str, features: KernelFeatures, *,
            backend: str = "auto") -> KernelSpec:
    """Picks the implementation of ``op`` for ``features``.

    ``backend="auto"``: the highest-priority eligible spec.
    ``backend=<id>``: that spec, eligibility still enforced (explicit
    requests set ``features.explicit`` so heuristic-only rejections are
    waived; correctness rejections still raise).

    Raises :class:`KernelDispatchError` listing every candidate and why it
    was rejected when nothing is eligible.
    """
    key = (op, backend, features)
    try:
        cached = _DISPATCH_CACHE[key]
        _CACHE_STATS["hits"] += 1
        return cached
    except KeyError:
        _CACHE_STATS["misses"] += 1

    specs = _op_specs(op)
    rejected: List[Tuple[KernelSpec, str]] = []
    chosen: Optional[KernelSpec] = None

    if backend != "auto":
        feats = dataclasses.replace(features, explicit=True)
        target = specs.get(backend)
        if target is None:
            raise KernelDispatchError(
                f"Unknown backend {backend!r} for op {op!r}; registered "
                f"backends: {registered_backends(op)}")
        reason = target.rejection_reason(feats)
        if reason is None:
            chosen = target
        else:
            rejected.append((target, reason))
            for spec in specs.values():
                if spec is not target:
                    rejected.append(
                        (spec, f"excluded by explicit backend={backend!r}"))
    else:
        for spec in sorted(specs.values(), key=lambda s: -s.priority):
            reason = spec.rejection_reason(features)
            if reason is None:
                chosen = spec
                break
            rejected.append((spec, reason))

    if chosen is None:
        lines = [f"No eligible kernel for op {op!r} "
                 f"(backend={backend!r}, platform={features.platform!r}). "
                 f"Candidates:"]
        for spec, reason in rejected:
            lines.append(f"  - {spec.backend} (priority {spec.priority}): "
                         f"{reason}")
        lines.append(f"  features: {features}")
        raise KernelDispatchError("\n".join(lines))

    _DISPATCH_CACHE[key] = chosen
    return chosen


def resolve_backend(op: str, features: KernelFeatures,
                    cfg: Optional[KernelConfig] = None) -> KernelSpec:
    """Convenience: resolve ``op`` under a :class:`KernelConfig` (or the
    defaults when ``cfg`` is None), folding the config's interpret flag and
    per-op override into the feature set.

    A *layer-wide* ``cfg.backend`` is a preference across heterogeneous ops:
    ops that don't register that backend at all (e.g. ``backend="blockwise"``
    on a layer that also dispatches ``attention.decode``, or ``"pallas"`` on
    the ref-only ``wkv6.decode`` recurrence) fall back to auto resolution
    instead of erroring. Per-op ``op_overrides`` stay strict — they name the
    op, so an unknown backend there is a config bug and raises.
    """
    cfg = cfg if cfg is not None else DEFAULT_CONFIG
    features = dataclasses.replace(features, interpret=cfg.interpret)
    backend = cfg.backend_for(op)
    if (backend != "auto"
            and not (cfg.op_overrides and op in cfg.op_overrides)
            and backend not in _op_specs(op)):
        backend = "auto"
    return resolve(op, features, backend=backend)


# Shared registry-default config for callers that pass kernel=None.
# Read-only by convention: never mutate (layers own their KernelConfig).
DEFAULT_CONFIG = KernelConfig()


def current_platform() -> str:
    return jax.default_backend()


# ---------------------------------------------------------------------------
# Shared predicate pieces
# ---------------------------------------------------------------------------


def _pallas_gate(features: KernelFeatures) -> Optional[str]:
    """Common gate for real (non-interpret) Mosaic kernels."""
    if features.interpret:
        return ("interpret mode requested (kernel.interpret=True): use "
                "backend 'pallas:interpret'")
    if features.platform != "tpu":
        return (f"Pallas Mosaic kernels lower on TPU only (running on "
                f"{features.platform!r}); use 'pallas:interpret' off-TPU")
    return None


def _interpret_gate(features: KernelFeatures) -> Optional[str]:
    """Interpret-mode kernels run anywhere but are validation-speed: never
    auto-selected unless the config asks for interpret mode."""
    if not (features.interpret or features.explicit):
        return ("interpret-mode backend is not auto-selected; set "
                "kernel.interpret=True or select 'pallas:interpret' "
                "explicitly")
    return None


def _flash_fwd_caps(features: KernelFeatures) -> Optional[str]:
    """Capabilities of the flash-attention forward kernel (either mode)."""
    if features.ragged_positions:
        # Correctness: the kernel assumes q/k share one contiguous position
        # stream. Unconditional, even for explicit requests.
        return ("q/k positions are not provably identical (ragged/decode "
                "call): the contiguous flash kernel does not apply")
    if features.paged:
        return "paged KV is a decode-op feature (use op 'attention.decode')"
    if features.single_query and not features.explicit:
        # Heuristic: a 1-token query is GEMV-bound, not a flash shape.
        return "1-token query is GEMV-bound; ref/blockwise is faster"
    return None


def _flash_decode_caps(features: KernelFeatures) -> Optional[str]:
    if not features.replicated_cache:
        # Correctness/perf cliff: no shard_map plumbing yet — a sharded KV
        # cache would silently all-gather per decode step.
        return ("flash-decode requires an unsharded/replicated KV cache "
                "(no shard_map plumbing); 'ref' keeps GSPMD in the "
                "partial-softmax layout for sequence-sharded caches")
    if features.needs_grad:
        return "flash-decode is forward-only (no custom VJP)"
    if features.kv_dtype == "int8" and not features.paged:
        # Correctness: int8 KV is only meaningful with the per-slot scale
        # rows that live in the paged pool; a dense int8 cache has no
        # scales to dequantize with. Unconditional.
        return ("int8 KV storage requires the paged layout (scale_pool "
                "carries the per-slot scales)")
    return None


def _forward_only(what: str) -> CapabilityPredicate:
    def pred(features: KernelFeatures) -> Optional[str]:
        if features.needs_grad:
            return f"{what} is forward-only (no custom VJP); ref autodiffs"
        return None

    return pred


def _chain(*preds: CapabilityPredicate) -> CapabilityPredicate:
    def pred(features: KernelFeatures) -> Optional[str]:
        for p in preds:
            reason = p(features)
            if reason is not None:
                return reason
        return None

    return pred


# ---------------------------------------------------------------------------
# Built-in registrations (the four ops). Adapters normalize every backend to
# one uniform per-op call signature so ops.py stays a thin dispatcher.
# ---------------------------------------------------------------------------


def _register_builtin_specs() -> None:
    from repro.kernels import ref as _ref
    from repro.kernels.flash_attention import flash_attention as _flash_vjp
    from repro.kernels.flash_decode import (
        flash_decode_forward,
        paged_flash_decode_forward,
    )
    from repro.kernels.rmsnorm import rmsnorm_forward

    # ---- attention.fwd --------------------------------------------------
    # fn(q, k, v, *, q_positions, k_positions, causal, sliding_window,
    #    logit_softcap, scale, cfg)

    def _fwd_pallas(interpret):
        def fn(q, k, v, *, q_positions, k_positions, causal, sliding_window,
               logit_softcap, scale, cfg):
            del q_positions, k_positions  # provably contiguous (predicate)
            return _flash_vjp(
                q, k, v, causal=causal, sliding_window=sliding_window,
                logit_softcap=logit_softcap, scale=scale,
                block_q=cfg.block_q, block_k=cfg.block_k, interpret=interpret)

        return fn

    def _fwd_blockwise(q, k, v, *, q_positions, k_positions, causal,
                       sliding_window, logit_softcap, scale, cfg):
        return _ref.blockwise_attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, sliding_window=sliding_window,
            logit_softcap=logit_softcap, scale=scale,
            chunk_size=cfg.blockwise_chunk_size, unroll=cfg.blockwise_unroll)

    def _fwd_ref(q, k, v, *, q_positions, k_positions, causal,
                 sliding_window, logit_softcap, scale, cfg):
        del cfg
        return _ref.reference_attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, sliding_window=sliding_window,
            logit_softcap=logit_softcap, scale=scale)

    register(KernelSpec(
        op="attention.fwd", backend="pallas", fn=_fwd_pallas(False),
        platforms=("tpu",), priority=100,
        supports=_chain(_pallas_gate, _flash_fwd_caps)))
    register(KernelSpec(
        op="attention.fwd", backend="pallas:interpret", fn=_fwd_pallas(True),
        platforms=("*",), priority=90,
        supports=_chain(_interpret_gate, _flash_fwd_caps)))
    register(KernelSpec(
        op="attention.fwd", backend="blockwise", fn=_fwd_blockwise,
        platforms=("*",), priority=50))
    register(KernelSpec(
        op="attention.fwd", backend="ref", fn=_fwd_ref,
        platforms=("*",), priority=0))

    # ---- attention.decode ----------------------------------------------
    # fn(q, k, v, *, q_positions, k_positions, page_tables, scale_pool,
    #    causal, sliding_window, logit_softcap, scale, logits_shard_fn, cfg)

    def _decode_pallas(interpret):
        def fn(q, k, v, *, q_positions, k_positions, page_tables, scale_pool,
               causal, sliding_window, logit_softcap, scale, logits_shard_fn,
               cfg):
            del logits_shard_fn  # replicated cache (predicate-enforced)
            if page_tables is not None:
                return paged_flash_decode_forward(
                    q, k, v, k_positions, page_tables, q_positions,
                    scale_pool=scale_pool,
                    causal=causal, sliding_window=sliding_window,
                    logit_softcap=logit_softcap, scale=scale,
                    interpret=interpret)
            # Contiguous (dense-cache) decode never carries scales (the
            # kv_dtype capability gate rejects quantized dense caches).
            return flash_decode_forward(
                q, k, v, q_positions, k_positions, causal=causal,
                sliding_window=sliding_window, logit_softcap=logit_softcap,
                scale=scale, block_k=cfg.decode_block_k, interpret=interpret)

        return fn

    def _decode_ref(q, k, v, *, q_positions, k_positions, page_tables,
                    scale_pool, causal, sliding_window, logit_softcap, scale,
                    logits_shard_fn, cfg):
        del cfg
        if page_tables is not None:
            # Portable paged path: materialize this batch's pages with an
            # XLA gather (dequantizing through scale_pool when the pool is
            # quantized), then run the reference oracle.
            from repro.kernels import ops as kernel_ops

            k, v, k_positions = kernel_ops.paged_gather_kv(
                k, v, k_positions, page_tables, scale_pool=scale_pool)
            k = k.astype(q.dtype)
            v = v.astype(q.dtype)
            logits_shard_fn = None
        return _ref.reference_attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, sliding_window=sliding_window,
            logit_softcap=logit_softcap, scale=scale,
            logits_shard_fn=logits_shard_fn)

    register(KernelSpec(
        op="attention.decode", backend="pallas", fn=_decode_pallas(False),
        platforms=("tpu",), priority=100,
        supports=_chain(_pallas_gate, _flash_decode_caps)))
    register(KernelSpec(
        op="attention.decode", backend="pallas:interpret",
        fn=_decode_pallas(True), platforms=("*",), priority=90,
        supports=_chain(_interpret_gate, _flash_decode_caps)))
    register(KernelSpec(
        op="attention.decode", backend="ref", fn=_decode_ref,
        platforms=("*",), priority=0))

    # ---- rmsnorm --------------------------------------------------------
    # fn(x, scale, *, eps, cfg)

    def _rmsnorm_pallas(interpret):
        def fn(x, scale, *, eps, cfg):
            return rmsnorm_forward(x, scale, eps=eps,
                                   block_rows=cfg.rmsnorm_block_rows,
                                   interpret=interpret)

        return fn

    def _rmsnorm_ref(x, scale, *, eps, cfg):
        del cfg
        return _ref.reference_rmsnorm(x, scale, eps=eps)

    register(KernelSpec(
        op="rmsnorm", backend="pallas", fn=_rmsnorm_pallas(False),
        platforms=("tpu",), priority=100,
        supports=_chain(_pallas_gate, _forward_only("rmsnorm kernel"))))
    register(KernelSpec(
        op="rmsnorm", backend="pallas:interpret", fn=_rmsnorm_pallas(True),
        platforms=("*",), priority=90,
        supports=_chain(_interpret_gate, _forward_only("rmsnorm kernel"))))
    register(KernelSpec(
        op="rmsnorm", backend="ref", fn=_rmsnorm_ref,
        platforms=("*",), priority=0))

    # ---- wkv6 -----------------------------------------------------------
    # fn(r, k, v, w, u, state, *, cfg)
    # Availability is decided HERE, at import time, with the real reason
    # logged and surfaced in resolution errors — the old ops.wkv6 wrapped
    # its import in `except ImportError`, silently swallowing genuine
    # failures *inside* kernels/wkv6.py into the slow ref path.

    wkv6_forward = None
    wkv6_reason = ""
    try:
        from repro.kernels.wkv6 import wkv6_forward as _wkv6_forward

        wkv6_forward = _wkv6_forward
    except ImportError as e:
        wkv6_reason = f"{type(e).__name__}: {e}"

    def _wkv6_pallas(interpret):
        def fn(r, k, v, w, u, state, *, cfg):
            return wkv6_forward(r, k, v, w, u, state,
                                chunk_size=cfg.wkv_chunk_size,
                                interpret=interpret)

        return fn

    def _wkv6_ref(r, k, v, w, u, state, *, cfg):
        return _ref.reference_wkv6(r, k, v, w, u, state,
                                   chunk_size=cfg.wkv_chunk_size,
                                   unroll=cfg.wkv_unroll)

    # wkv6.decode: the O(1) recurrent step (ref-only today — a Pallas
    # recurrent-step kernel registers here without touching rwkv.py).
    def _wkv6_decode_ref(r, k, v, w, u, state, *, cfg):
        del cfg
        return _ref.reference_wkv6_recurrent(r, k, v, w, u, state)

    register(KernelSpec(
        op="wkv6.decode", backend="ref", fn=_wkv6_decode_ref,
        platforms=("*",), priority=0))

    wkv_caps = _forward_only("wkv6 kernel")
    register(KernelSpec(
        op="wkv6", backend="pallas",
        fn=_wkv6_pallas(False) if wkv6_forward else None,
        platforms=("tpu",), priority=100,
        supports=_chain(_pallas_gate, wkv_caps),
        available=wkv6_forward is not None, unavailable_reason=wkv6_reason))
    register(KernelSpec(
        op="wkv6", backend="pallas:interpret",
        fn=_wkv6_pallas(True) if wkv6_forward else None,
        platforms=("*",), priority=90,
        supports=_chain(_interpret_gate, wkv_caps),
        available=wkv6_forward is not None, unavailable_reason=wkv6_reason))
    register(KernelSpec(
        op="wkv6", backend="ref", fn=_wkv6_ref,
        platforms=("*",), priority=0))


_register_builtin_specs()
