"""Row-tiled RMSNorm Pallas kernel (fused normalize + scale).

Memory-bound op: one HBM read + one write per element, fp32 accumulation in
VMEM. Grid tiles rows (tokens); the scale vector is re-fetched per tile (it
lives comfortably in VMEM).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

__all__ = ["rmsnorm_forward"]


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # (rows, D)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * scale_ref[0].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_forward(
    x: jax.Array,  # (..., D)
    scale: jax.Array,  # (D,)
    *,
    eps: float = 1e-6,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    orig_shape = x.shape
    D = orig_shape[-1]
    n = 1
    for s in orig_shape[:-1]:
        n *= s
    x2 = x.reshape(n, D)
    block_rows = min(block_rows, n)
    n_pad = -(-n // block_rows) * block_rows
    if n_pad != n:
        x2 = jnp.pad(x2, ((0, n_pad - n), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n_pad // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_pad, D), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x2, scale.reshape(1, D))
    return out[:n].reshape(orig_shape)
