"""Pure-jnp reference oracles for every kernel in this package.

These are the ground truth for kernel allclose tests AND the portable
fallback implementations the layer library dispatches to on backends without
the Pallas kernels (paper §4.2: per-backend kernel dispatch is a config
choice).

Conventions:
  q: (B, S, Hq, D), k/v: (B, T, Hkv, D) with Hq % Hkv == 0 (GQA).
  Masks are built from absolute positions so the same code serves full
  forward, prefill, and single-token decode.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

__all__ = [
    "attention_mask",
    "reference_attention",
    "blockwise_attention",
    "reference_rmsnorm",
    "reference_wkv6",
    "reference_wkv6_recurrent",
]

NEG_INF = -1e30


def attention_mask(
    q_positions: jax.Array,
    k_positions: jax.Array,
    *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
) -> jax.Array:
    """Boolean (..., S, T) mask; True = attend.

    ``q_positions``/``k_positions`` are absolute token positions (any
    broadcastable leading dims). Invalid cache slots should carry position
    -1 (masked by causality for q_pos >= 0 ... but also k_pos >= 0 check).
    """
    q = q_positions[..., :, None]
    k = k_positions[..., None, :]
    mask = k >= 0
    if causal:
        mask = mask & (k <= q)
    if sliding_window is not None:
        mask = mask & (k > q - sliding_window)
    return mask


def _norm_positions(p: jax.Array) -> jax.Array:
    """Normalizes positions to (B, S) (B=1 broadcast for shared positions)."""
    p = jnp.asarray(p)
    return p[None, :] if p.ndim == 1 else p


def _soft_cap(logits: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return logits
    return cap * jnp.tanh(logits / cap)


def reference_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_positions: Optional[jax.Array] = None,
    k_positions: Optional[jax.Array] = None,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    logits_shard_fn=None,
) -> jax.Array:
    """Full-materialization softmax attention (the oracle).

    ``logits_shard_fn`` (optional) constrains the (B,Hkv,G,S,T) logits
    sharding — used by decode with sequence-sharded KV caches so GSPMD keeps
    the flash-decoding layout (partial softmax + small all-reduces) instead
    of gathering the cache."""
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    q_positions = _norm_positions(q_positions if q_positions is not None else jnp.arange(S))
    k_positions = _norm_positions(k_positions if k_positions is not None else jnp.arange(T))
    scale = (D ** -0.5) if scale is None else scale

    # Native-dtype inputs, fp32 accumulation (MXU semantics; identical for
    # fp32 inputs, and no duplicated fp32 copies of bf16 KV caches).
    qg = q.reshape(B, S, Hkv, G, D) * scale
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k,
                        preferred_element_type=jnp.float32)
    logits = _soft_cap(logits, logit_softcap)
    mask = attention_mask(q_positions, k_positions, causal=causal,
                          sliding_window=sliding_window)  # (b|1, S, T)
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    if logits_shard_fn is not None:
        logits = logits_shard_fn(logits)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, S, Hq, D)
    return out.astype(q.dtype)


def blockwise_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_positions: Optional[jax.Array] = None,
    k_positions: Optional[jax.Array] = None,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    chunk_size: int = 512,
    unroll: bool = False,
) -> jax.Array:
    """Query-chunked attention: O(chunk * T) live memory, pure XLA.

    This is the portable production path (used by the multi-pod dry-run);
    mathematically identical to :func:`reference_attention`.
    """
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    q_positions = _norm_positions(q_positions if q_positions is not None else jnp.arange(S))
    k_positions = _norm_positions(k_positions if k_positions is not None else jnp.arange(T))
    scale = (D ** -0.5) if scale is None else scale

    if S % chunk_size != 0:
        # Fall back for ragged sizes (decode steps, tests).
        return reference_attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, sliding_window=sliding_window,
            logit_softcap=logit_softcap, scale=scale)

    n_chunks = S // chunk_size
    qc = q.reshape(B, n_chunks, chunk_size, Hkv, G, D)
    Bp = q_positions.shape[0]
    qp = q_positions.reshape(Bp, n_chunks, chunk_size)

    def one_chunk(args):
        # Inputs stay in their native dtype (bf16 in production); matmuls
        # accumulate in fp32 via preferred_element_type — TPU MXU semantics,
        # and half the HBM traffic of explicit fp32 upcasts.
        q_blk, qp_blk = args  # (B,c,Hkv,G,D), (Bp,c)
        logits = jnp.einsum("bskgd,btkd->bkgst", q_blk * scale, k,
                            preferred_element_type=jnp.float32)
        logits = _soft_cap(logits, logit_softcap)
        mask = attention_mask(qp_blk, k_positions, causal=causal,
                              sliding_window=sliding_window)  # (b|1, c, T)
        logits = jnp.where(mask[:, None, None], logits, NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bkgst,btkd->bskgd", probs.astype(v.dtype), v,
                          preferred_element_type=jnp.float32).astype(q.dtype)

    # Scanned so one chunk's logits are live at a time (unroll for AOT
    # analysis mode: exact cost_analysis).
    _, out = jax.lax.scan(lambda c, xs: (c, one_chunk(xs)), 0,
                          (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(qp, 1, 0)),
                          unroll=unroll)
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, Hq, D)
    return out


def reference_rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return y.astype(x.dtype)


# ----------------------------- RWKV6 (WKV) ----------------------------------


def reference_wkv6_recurrent(
    r: jax.Array,  # (B, T, H, K)
    k: jax.Array,  # (B, T, H, K)
    v: jax.Array,  # (B, T, H, V)
    w: jax.Array,  # (B, T, H, K)  per-step decay in (0, 1), data-dependent
    u: jax.Array,  # (H, K)        bonus for current token
    state: Optional[jax.Array] = None,  # (B, H, K, V)
):
    """Naive stepwise WKV6 recurrence (the oracle).

    s_t = diag(w_t) s_{t-1} + k_t v_t^T ;  o_t = r_t (s_{t-1} + diag(u) k_t v_t^T)
    Returns (out (B,T,H,V), final_state).
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(s, inputs):
        r_t, k_t, v_t, w_t = inputs  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        o_t = jnp.einsum("bhk,bhkv->bhv", r_t, s + uf[None, :, :, None] * kv)
        s = w_t[..., None] * s + kv
        return s, o_t

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    final, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), final


def reference_wkv6(
    r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array, u: jax.Array,
    state: Optional[jax.Array] = None, *, chunk_size: int = 64,
    unroll: bool = False,
):
    """Chunked (parallel-within-chunk) WKV6 — same math as the recurrence.

    Within a chunk of length C, with cumulative decays
    A_i = prod_{j<=i} w_j (exclusive of the state step ordering):
      contribution of state:  o_i += r_i diag(prod_{j<i} w_j) s_in
      intra-chunk:            o_i += sum_{j<i} r_i diag(prod_{j in (j, i)} w) k_j v_j^T
                                      + r_i diag(u) k_i v_i^T
      state update:           s_out = diag(prod_j w_j) s_in + sum_j diag(prod_{l>j} w_l) k_j v_j^T
    """
    B, T, H, K = r.shape
    V = v.shape[-1]
    C = chunk_size
    if T % C != 0:
        return reference_wkv6_recurrent(r, k, v, w, u, state)
    if state is None:
        state = jnp.zeros((B, H, K, V), jnp.float32)
    n = T // C
    rf, kf, vf, wf = (jnp.moveaxis(a.astype(jnp.float32).reshape(B, n, C, H, -1), 1, 0)
                      for a in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def chunk_step(s, inputs):
        r_c, k_c, v_c, w_c = inputs  # (B, C, H, *)
        logw = jnp.log(jnp.maximum(w_c, 1e-20))  # (B,C,H,K)
        cum = jnp.cumsum(logw, axis=1)  # inclusive prod_{j<=i}
        cum_excl = cum - logw  # exclusive prod_{j<i}
        total = cum[:, -1]  # (B,H,K)
        # state contribution: r_i * prod_{j<i} w_j  @ s
        r_dec = r_c * jnp.exp(cum_excl)
        o = jnp.einsum("bihk,bhkv->bihv", r_dec, s)
        # intra-chunk: pair (i, j<i): decay prod_{j<l<i} w_l = exp(cum_excl_i - cum_j)
        # Factorized intra-chunk decay exp(cum_excl_i - cum_j). The combined
        # exponent is <= 0 for j < i, but the split factors can overflow, so
        # re-center on the mid-chunk cumulative decay.
        mid = cum[:, C // 2][:, None]  # (B,1,H,K)
        ri = r_c * jnp.exp(cum_excl - mid)  # (B,i,H,K)
        kj = k_c * jnp.exp(mid - cum)  # (B,j,H,K)
        att = jnp.einsum("bihk,bjhk->bijh", ri, kj)
        mask = jnp.tril(jnp.ones((C, C), bool), k=-1)[None, :, :, None]
        att = jnp.where(mask, att, 0.0)
        o = o + jnp.einsum("bijh,bjhv->bihv", att, v_c)
        # current-token bonus
        bonus = jnp.einsum("bihk,bihk->bih", r_c * uf[None, None], k_c)
        o = o + bonus[..., None] * v_c
        # state update
        k_dec = k_c * jnp.exp(total[:, None] - cum)
        s = jnp.exp(total)[..., None] * s + jnp.einsum("bjhk,bjhv->bhkv", k_dec, v_c)
        return s, o

    final, out = jax.lax.scan(chunk_step, state, (rf, kf, vf, wf), unroll=unroll)
    out = jnp.moveaxis(out, 0, 1).reshape(B, T, H, V)
    return out.astype(r.dtype), final
