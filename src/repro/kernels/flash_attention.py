"""TPU Pallas flash attention (forward), AXLearn-style kernel dispatch target.

TPU-native adaptation of FlashAttention (paper §4.2 dispatches SplashAttention
on TPU): the grid's innermost dimension iterates KV blocks *sequentially*
(TPU grids are sequential in the last axis), carrying the online-softmax
running max / denominator / accumulator in VMEM scratch — the TPU analogue of
a CUDA thread-block's registers/SMEM. Block shapes default to (128, 128) to
align with the 128x128 MXU tile and 8x128 VREG lanes.

Supports: causal masking, sliding windows, logit soft-capping, and GQA
(q-head -> kv-head mapping happens in the BlockSpec index_map so each KV
block is fetched once per group, not once per q-head... per q-head grid step
still fetches its group's block; Mosaic coalesces repeats across sequential
steps).

Forward only: training uses the XLA blockwise path (differentiable); the
kernel is the serving/prefill hot path. Validated against
``repro.kernels.ref.reference_attention`` in interpret mode (CPU).
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

__all__ = ["flash_attention_forward"]

NEG_INF = -1e30
_LANES = 128  # VREG lane count: scratch second-minor dim


def _kernel(
    # prefetch-scalar-free refs:
    q_ref,  # (1, block_q, D)
    k_ref,  # (1, block_k, D)
    v_ref,  # (1, block_k, D)
    o_ref,  # (1, block_q, D)
    m_scr,  # (block_q, _LANES) f32
    l_scr,  # (block_q, _LANES) f32
    acc_scr,  # (block_q, D) f32
    *,
    block_q: int,
    block_k: int,
    kv_len: int,
    num_kv_blocks: int,
    causal: bool,
    sliding_window: Optional[int],
    logit_softcap: Optional[float],
    scale: float,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Skip fully-masked blocks (beyond the causal frontier / outside window).
    relevant = True
    if causal:
        relevant = jnp.logical_and(relevant, kj * block_k <= qi * block_q + block_q - 1)
    if sliding_window is not None:
        relevant = jnp.logical_and(
            relevant, (kj + 1) * block_k - 1 > qi * block_q - sliding_window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)

        mask = k_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if sliding_window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - sliding_window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0:1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # Guard fully-masked rows: keep exp argument finite.
        p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)

        l_new = alpha * l_scr[:, 0:1] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)  # (bk, D)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[:, 0:1]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_forward(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale
    block_q = min(block_q, S)
    block_k = min(block_k, T)

    # Pad sequence dims to block multiples (mask handles the tail).
    S_pad = -(-S // block_q) * block_q
    T_pad = -(-T // block_k) * block_k
    if S_pad != S:
        q = jnp.pad(q, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
    if T_pad != T:
        k = jnp.pad(k, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))

    # Head-major layout: (B*H, S, D).
    qh = q.transpose(0, 2, 1, 3).reshape(B * Hq, S_pad, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T_pad, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T_pad, D)

    num_q_blocks = S_pad // block_q
    num_kv_blocks = T_pad // block_k
    grid = (B * Hq, num_q_blocks, num_kv_blocks)

    def q_index(bh, qi, kj):
        return (bh, qi, 0)

    def kv_index(bh, qi, kj):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, kj, 0)

    kernel = functools.partial(
        _kernel,
        block_q=block_q,
        block_k=block_k,
        kv_len=T,
        num_kv_blocks=num_kv_blocks,
        causal=causal,
        sliding_window=sliding_window,
        logit_softcap=logit_softcap,
        scale=scale,
    )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_index),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_index),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qh, kh, vh)

    out = out.reshape(B, Hq, S_pad, D).transpose(0, 2, 1, 3)
    return out[:, :S]
