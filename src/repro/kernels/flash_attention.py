"""TPU Pallas flash attention (forward + recompute backward), AXLearn-style.

TPU-native adaptation of FlashAttention (paper §4.2 dispatches SplashAttention
on TPU): the grid's innermost dimension iterates KV blocks *sequentially*
(TPU grids are sequential in the last axis), carrying the online-softmax
running max / denominator / accumulator in VMEM scratch — the TPU analogue of
a CUDA thread-block's registers/SMEM. Block shapes default to (128, 128) to
align with the 128x128 MXU tile and 8x128 VREG lanes.

Supports: causal masking, sliding windows, logit soft-capping, and GQA
(q-head -> kv-head mapping happens in the BlockSpec index_map so each KV
block is fetched once per group).

Training: :func:`flash_attention` is a ``jax.custom_vjp`` whose backward is
the standard recompute scheme (FlashAttention-2): the forward additionally
emits the per-row logsumexp, and two Pallas passes recompute the probability
blocks from (q, k, lse) instead of materializing the (S, T) matrix —

  * **dKV pass**: grid over KV blocks; for each KV block it streams every
    query block of every q-head in the KV head's GQA group (innermost,
    sequential) and accumulates dK/dV in VMEM scratch.
  * **dQ pass**: grid mirrors the forward; dQ accumulates over KV blocks.

Both passes are GQA- and sliding-window-aware and validated against
``jax.grad`` of ``repro.kernels.ref.reference_attention`` in interpret mode
(CPU), so the flash path is legal under ``jax.grad`` on every platform.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

__all__ = ["flash_attention", "flash_attention_forward"]

NEG_INF = -1e30
_LANES = 128  # VREG lane count: scratch second-minor dim


def _block_relevant(qi, kj, *, block_q: int, block_k: int, causal: bool,
                    sliding_window: Optional[int]):
    """Whether the (qi, kj) block pair contains any unmasked entry."""
    relevant = True
    if causal:
        relevant = jnp.logical_and(relevant,
                                   kj * block_k <= qi * block_q + block_q - 1)
    if sliding_window is not None:
        relevant = jnp.logical_and(
            relevant, (kj + 1) * block_k - 1 > qi * block_q - sliding_window)
    return relevant


def _pair_mask(q_pos, k_pos, *, q_len: int, kv_len: int, causal: bool,
               sliding_window: Optional[int]):
    """(bq, bk) boolean mask; also masks q/k padding rows/cols."""
    mask = jnp.logical_and(k_pos < kv_len, q_pos < q_len)
    if causal:
        mask = jnp.logical_and(mask, k_pos <= q_pos)
    if sliding_window is not None:
        mask = jnp.logical_and(mask, k_pos > q_pos - sliding_window)
    return mask


# ---------------------------------------------------------------------------
# Forward kernel (emits per-row logsumexp for the recompute backward)
# ---------------------------------------------------------------------------


def _fwd_kernel(
    q_ref,  # (1, block_q, D)
    k_ref,  # (1, block_k, D)
    v_ref,  # (1, block_k, D)
    o_ref,  # (1, block_q, D)
    lse_ref,  # (1, block_q) f32
    m_scr,  # (block_q, _LANES) f32
    l_scr,  # (block_q, _LANES) f32
    acc_scr,  # (block_q, D) f32
    *,
    block_q: int,
    block_k: int,
    q_len: int,
    kv_len: int,
    num_kv_blocks: int,
    causal: bool,
    sliding_window: Optional[int],
    logit_softcap: Optional[float],
    scale: float,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Skip fully-masked blocks (beyond the causal frontier / outside window).
    relevant = _block_relevant(qi, kj, block_q=block_q, block_k=block_k,
                               causal=causal, sliding_window=sliding_window)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, D)
        k = k_ref[0].astype(jnp.float32)  # (bk, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
        if logit_softcap is not None:
            s = logit_softcap * jnp.tanh(s / logit_softcap)

        mask = _pair_mask(q_pos, k_pos, q_len=q_len, kv_len=kv_len,
                          causal=causal, sliding_window=sliding_window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0:1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        # Guard fully-masked rows: keep exp argument finite.
        p = jnp.exp(s - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - jnp.where(m_new <= NEG_INF / 2, 0.0, m_new))
        alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)

        l_new = alpha * l_scr[:, 0:1] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)  # (bk, D)
        pv = jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        l = l_scr[:, 0:1]
        denom = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)
        m = m_scr[:, 0]
        lvec = l_scr[:, 0]
        # lse = m + log(l); NEG_INF marks fully-masked (invalid) rows so the
        # backward can zero their probability blocks.
        lse_ref[0] = jnp.where(lvec > 0.0,
                               m + jnp.log(jnp.maximum(lvec, 1e-37)),
                               NEG_INF)


def _fwd_impl(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,
    *,
    causal: bool,
    sliding_window: Optional[int],
    logit_softcap: Optional[float],
    scale: float,
    block_q: int,
    block_k: int,
    interpret: bool,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B, S, Hq, D), lse (B, Hq, S) fp32)."""
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)

    # Pad sequence dims to block multiples (mask handles the tail).
    S_pad = -(-S // block_q) * block_q
    T_pad = -(-T // block_k) * block_k
    if S_pad != S:
        q = jnp.pad(q, ((0, 0), (0, S_pad - S), (0, 0), (0, 0)))
    if T_pad != T:
        k = jnp.pad(k, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))

    # Head-major layout: (B*H, S, D).
    qh = q.transpose(0, 2, 1, 3).reshape(B * Hq, S_pad, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * Hkv, T_pad, D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * Hkv, T_pad, D)

    num_q_blocks = S_pad // block_q
    num_kv_blocks = T_pad // block_k
    grid = (B * Hq, num_q_blocks, num_kv_blocks)

    def q_index(bh, qi, kj):
        return (bh, qi, 0)

    def kv_index(bh, qi, kj):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, kj, 0)

    def lse_index(bh, qi, kj):
        return (bh, qi)

    kernel = functools.partial(
        _fwd_kernel,
        block_q=block_q,
        block_k=block_k,
        q_len=S,
        kv_len=T,
        num_kv_blocks=num_kv_blocks,
        causal=causal,
        sliding_window=sliding_window,
        logit_softcap=logit_softcap,
        scale=scale,
    )

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_index),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, D), kv_index),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, D), q_index),
            pl.BlockSpec((1, block_q), lse_index),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hq, S_pad, D), q.dtype),
            jax.ShapeDtypeStruct((B * Hq, S_pad), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, _LANES), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qh, kh, vh)

    out = out.reshape(B, Hq, S_pad, D).transpose(0, 2, 1, 3)[:, :S]
    lse = lse.reshape(B, Hq, S_pad)[:, :, :S]
    return out, lse


# ---------------------------------------------------------------------------
# Backward kernels (recompute p from q, k, lse — FlashAttention-2 scheme)
# ---------------------------------------------------------------------------


def _recompute_p_ds(q, k, lse, do, v, delta, mask, *, logit_softcap, scale):
    """Shared recompute: returns (p, ds_raw), both (bq, bk) fp32.

    ``lse``/``delta`` are (bq, 1). Invalid rows carry lse = NEG_INF.
    """
    s = jax.lax.dot_general(q * scale, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    row_valid = lse > NEG_INF / 2  # (bq, 1)
    lse_safe = jnp.where(row_valid, lse, 0.0)
    p = jnp.exp(s - lse_safe)
    p = jnp.where(jnp.logical_and(mask, row_valid), p, 0.0)
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta)
    if logit_softcap is not None:
        ds = ds * (1.0 - jnp.square(s / logit_softcap))
    return p, ds


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dq_ref,
    dq_scr,  # (block_q, D) f32
    *,
    block_q: int,
    block_k: int,
    q_len: int,
    kv_len: int,
    num_kv_blocks: int,
    causal: bool,
    sliding_window: Optional[int],
    logit_softcap: Optional[float],
    scale: float,
):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    relevant = _block_relevant(qi, kj, block_q=block_q, block_k=block_k,
                               causal=causal, sliding_window=sliding_window)

    @pl.when(relevant)
    def _compute():
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = _pair_mask(q_pos, k_pos, q_len=q_len, kv_len=kv_len,
                          causal=causal, sliding_window=sliding_window)
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, None]
        delta = delta_ref[0][:, None]
        _, ds = _recompute_p_ds(q, k, lse, do, v, delta, mask,
                                logit_softcap=logit_softcap, scale=scale)
        # dq += scale * ds @ k
        dq_scr[...] = dq_scr[...] + scale * jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        dq_ref[0] = dq_scr[...].astype(dq_ref.dtype)


def _dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
    dk_ref, dv_ref,
    dk_scr, dv_scr,  # (block_k, D) f32
    *,
    block_q: int,
    block_k: int,
    q_len: int,
    kv_len: int,
    num_q_blocks: int,
    group_steps: int,  # G * num_q_blocks
    causal: bool,
    sliding_window: Optional[int],
    logit_softcap: Optional[float],
    scale: float,
):
    kj = pl.program_id(1)
    t = pl.program_id(2)  # g * num_q_blocks + qi
    qi = t % num_q_blocks

    @pl.when(t == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    relevant = _block_relevant(qi, kj, block_q=block_q, block_k=block_k,
                               causal=causal, sliding_window=sliding_window)

    @pl.when(relevant)
    def _compute():
        q_pos = qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = kj * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = _pair_mask(q_pos, k_pos, q_len=q_len, kv_len=kv_len,
                          causal=causal, sliding_window=sliding_window)
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        lse = lse_ref[0][:, None]
        delta = delta_ref[0][:, None]
        p, ds = _recompute_p_ds(q, k, lse, do, v, delta, mask,
                                logit_softcap=logit_softcap, scale=scale)
        # dv += p^T @ do ; dk += scale * ds^T @ q
        dv_scr[...] = dv_scr[...] + jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        dk_scr[...] = dk_scr[...] + scale * jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(t == group_steps - 1)
    def _finalize():
        dk_ref[0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_scr[...].astype(dv_ref.dtype)


def _bwd_impl(
    q, k, v, out, lse, do,
    *,
    causal: bool,
    sliding_window: Optional[int],
    logit_softcap: Optional[float],
    scale: float,
    block_q: int,
    block_k: int,
    interpret: bool,
):
    B, S, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    G = Hq // Hkv
    block_q = min(block_q, S)
    block_k = min(block_k, T)
    S_pad = -(-S // block_q) * block_q
    T_pad = -(-T // block_k) * block_k

    def pad_s(x):
        return jnp.pad(x, ((0, 0), (0, S_pad - S), (0, 0), (0, 0))) \
            if S_pad != S else x

    def pad_t(x):
        return jnp.pad(x, ((0, 0), (0, T_pad - T), (0, 0), (0, 0))) \
            if T_pad != T else x

    qh = pad_s(q).transpose(0, 2, 1, 3).reshape(B * Hq, S_pad, D)
    doh = pad_s(do).transpose(0, 2, 1, 3).reshape(B * Hq, S_pad, D)
    oh = pad_s(out).transpose(0, 2, 1, 3).reshape(B * Hq, S_pad, D)
    kh = pad_t(k).transpose(0, 2, 1, 3).reshape(B * Hkv, T_pad, D)
    vh = pad_t(v).transpose(0, 2, 1, 3).reshape(B * Hkv, T_pad, D)

    # delta_i = sum_d do_id * o_id (cheap elementwise preprocess, fp32).
    delta = jnp.sum(doh.astype(jnp.float32) * oh.astype(jnp.float32), axis=-1)
    lse_h = lse.reshape(B * Hq, S)
    if S_pad != S:
        # Padding rows are invalid: lse = NEG_INF zeroes their p blocks.
        lse_h = jnp.pad(lse_h, ((0, 0), (0, S_pad - S)),
                        constant_values=NEG_INF)

    num_q_blocks = S_pad // block_q
    num_kv_blocks = T_pad // block_k

    def q_index(bh, qi, kj):
        return (bh, qi, 0)

    def kv_index(bh, qi, kj):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // G, kj, 0)

    def lse_index(bh, qi, kj):
        return (bh, qi)

    common = dict(
        block_q=block_q, block_k=block_k, q_len=S, kv_len=T,
        causal=causal, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=scale,
    )

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, num_kv_blocks=num_kv_blocks, **common),
        grid=(B * Hq, num_q_blocks, num_kv_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_index),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_k, D), kv_index),
            pl.BlockSpec((1, block_q, D), q_index),
            pl.BlockSpec((1, block_q), lse_index),
            pl.BlockSpec((1, block_q), lse_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), q_index),
        out_shape=jax.ShapeDtypeStruct((B * Hq, S_pad, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, D), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qh, kh, vh, doh, lse_h, delta)

    # dKV pass: one sequential sweep over every (group head, q block) pair
    # per KV block, accumulating in VMEM scratch.
    def kv_self_index(bhkv, kj, t):
        return (bhkv, kj, 0)

    def q_group_index(bhkv, kj, t):
        row = (bhkv // Hkv) * Hq + (bhkv % Hkv) * G + t // num_q_blocks
        return (row, t % num_q_blocks, 0)

    def lse_group_index(bhkv, kj, t):
        row = (bhkv // Hkv) * Hq + (bhkv % Hkv) * G + t // num_q_blocks
        return (row, t % num_q_blocks)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, num_q_blocks=num_q_blocks,
                          group_steps=G * num_q_blocks, **common),
        grid=(B * Hkv, num_kv_blocks, G * num_q_blocks),
        in_specs=[
            pl.BlockSpec((1, block_q, D), q_group_index),
            pl.BlockSpec((1, block_k, D), kv_self_index),
            pl.BlockSpec((1, block_k, D), kv_self_index),
            pl.BlockSpec((1, block_q, D), q_group_index),
            pl.BlockSpec((1, block_q), lse_group_index),
            pl.BlockSpec((1, block_q), lse_group_index),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, D), kv_self_index),
            pl.BlockSpec((1, block_k, D), kv_self_index),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * Hkv, T_pad, D), k.dtype),
            jax.ShapeDtypeStruct((B * Hkv, T_pad, D), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, D), jnp.float32),
            pltpu.VMEM((block_k, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qh, kh, vh, doh, lse_h, delta)

    dq = dq.reshape(B, Hq, S_pad, D).transpose(0, 2, 1, 3)[:, :S]
    dk = dk.reshape(B, Hkv, T_pad, D).transpose(0, 2, 1, 3)[:, :T]
    dv = dv.reshape(B, Hkv, T_pad, D).transpose(0, 2, 1, 3)[:, :T]
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp plumbing
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash(q, k, v, causal, sliding_window, logit_softcap, scale,
           block_q, block_k, interpret):
    out, _ = _fwd_impl(
        q, k, v, causal=causal, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out


def _flash_fwd(q, k, v, causal, sliding_window, logit_softcap, scale,
               block_q, block_k, interpret):
    out, lse = _fwd_impl(
        q, k, v, causal=causal, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, sliding_window, logit_softcap, scale, block_q, block_k,
               interpret, residuals, do):
    q, k, v, out, lse = residuals
    dq, dk, dv = _bwd_impl(
        q, k, v, out, lse, do, causal=causal, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Differentiable flash attention (Pallas forward + Pallas backward)."""
    D = q.shape[-1]
    scale = (D ** -0.5) if scale is None else scale
    return _flash(q, k, v, causal, sliding_window, logit_softcap, float(scale),
                  block_q, block_k, interpret)


def flash_attention_forward(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Forward-only entry point (serving/prefill hot path)."""
    D = q.shape[-1]
    scale = (D ** -0.5) if scale is None else scale
    out, _ = _fwd_impl(
        q, k, v, causal=causal, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=float(scale),
        block_q=block_q, block_k=block_k, interpret=interpret)
    return out
