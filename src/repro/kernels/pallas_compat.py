"""Version shims for the Pallas TPU API surface.

Kept in one place (cf. ``repro.core.utils.make_mesh``) so a jax rename is
fixed once, not once per kernel module.
"""

from jax.experimental.pallas import tpu as pltpu

__all__ = ["CompilerParams"]

# jax renamed TPUCompilerParams -> CompilerParams across releases.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
