"""Jit'd dispatch wrappers for the Pallas kernels.

The layer library calls these; backend selection (real TPU kernel vs
interpret-mode validation on CPU vs pure-XLA fallback) is a *config* choice
threaded from mesh rules (paper §4.2), never a code change.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import (
    flash_attention as _flash_attention_vjp,
)
from repro.kernels.flash_decode import (
    flash_decode_forward,
    paged_flash_decode_forward,
)
from repro.kernels.rmsnorm import rmsnorm_forward

__all__ = ["flash_attention", "decode_attention", "paged_gather_kv",
           "rmsnorm", "wkv6"]


def _same_positions(q_positions, k_positions) -> bool:
    """True iff q/k positions are provably identical (so the contiguous
    self-attention kernel applies).

    Checks by *value* for concrete arrays — callers frequently pass
    equal-but-distinct position arrays (e.g. two ``jnp.arange(S)`` calls),
    which the old identity-only check silently sent down the
    O(S*T)-materializing reference path. Traced (abstract) values can't be
    value-compared, so they fall back to the identity check.
    """
    if q_positions is None and k_positions is None:
        return True
    if q_positions is k_positions:
        return True
    if q_positions is None or k_positions is None:
        return False
    q_shape = getattr(q_positions, "shape", None)
    if q_shape != getattr(k_positions, "shape", None):
        return False
    try:
        import numpy as np

        if isinstance(q_positions, jax.core.Tracer) or \
                isinstance(k_positions, jax.core.Tracer):
            return False
        return bool(np.array_equal(np.asarray(q_positions),
                                   np.asarray(k_positions)))
    except (TypeError, jax.errors.ConcretizationTypeError):
        return False


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_positions=None,
    k_positions=None,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention for contiguous self-attention (q/k share positions).

    Differentiable: the Pallas kernel carries a recompute-based custom_vjp
    (dKV + dQ passes), so this is legal under ``jax.grad`` and serves as the
    training kernel, not just the serving/prefill path.

    Decode steps (ragged cache positions) fall back to the reference path —
    a 1-token query is GEMV-bound, not a flash-kernel shape.
    """
    if not _same_positions(q_positions, k_positions) or q.shape[1] == 1:
        return _ref.reference_attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, sliding_window=sliding_window,
            logit_softcap=logit_softcap, scale=scale)
    return _flash_attention_vjp(
        q, k, v, causal=causal, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


def paged_gather_kv(k_pool: jax.Array, v_pool: jax.Array,
                    pos_pool: jax.Array, page_tables: jax.Array):
    """Materialize a paged pool into contiguous per-sequence (B, N*page, ...)
    K/V + positions via an XLA gather — the portable reference path for
    paged decode. Unmapped logical pages (table entry -1) gather physical
    page 0 but their positions are forced to -1, so masking drops them.
    """
    tbl = jnp.asarray(page_tables, jnp.int32)  # (B, N)
    B, N = tbl.shape
    P, page, Hkv, D = k_pool.shape
    safe = jnp.maximum(tbl, 0)
    k = k_pool[safe].reshape(B, N * page, Hkv, D)
    v = v_pool[safe].reshape(B, N * page, Hkv, D)
    kpos = jnp.where((tbl >= 0)[:, :, None], pos_pool[safe], -1)
    return k, v, kpos.reshape(B, N * page)


def decode_attention(
    q: jax.Array,  # (B, S', Hq, D)
    k: jax.Array,  # (B, T, Hkv, D) cache — or (P, page, Hkv, D) pool (paged)
    v: jax.Array,
    *,
    q_positions,  # (B, S') or (S',) absolute positions of the new tokens
    k_positions,  # (B, T)/(T,) slot positions — or (P, page) pos pool (paged)
    page_tables: Optional[jax.Array] = None,  # (B, N) int32, -1 = unmapped
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Flash-decode: split-KV online-softmax over a (ring-buffer) cache.

    Unlike :func:`flash_attention` this never materializes the
    ``(B, Hkv, G, S', T)`` logits tensor — the decode TPOT hot path streams
    the cache through VMEM once per KV group. Masking reads the cache's
    ``pos`` tensor directly, so sliding-window/ring layouts need no gather.

    With ``page_tables``, ``k``/``v`` are shared physical page *pools* and
    ``k_positions`` is the per-page position pool: the kernel DMAs exactly
    the pages named by each sequence's table row (scalar prefetch), so the
    pool is never gathered in HBM.
    """
    # Decode positions are never inferable (queries continue an absolute
    # position stream; cache slots hold arbitrary ring positions) — a
    # guessed default would silently mask nearly everything.
    if q_positions is None or k_positions is None:
        raise ValueError("decode_attention requires explicit q_positions "
                         "and k_positions (cache pos tensor)")
    if page_tables is not None:
        return paged_flash_decode_forward(
            q, k, v, k_positions, page_tables, q_positions, causal=causal,
            sliding_window=sliding_window, logit_softcap=logit_softcap,
            scale=scale, interpret=interpret)
    # flash_decode_forward broadcasts (S',)/(1,S')/(B,S') position shapes.
    return flash_decode_forward(
        q, k, v, q_positions, k_positions, causal=causal,
        sliding_window=sliding_window, logit_softcap=logit_softcap,
        scale=scale, block_k=block_k, interpret=interpret)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    return rmsnorm_forward(x, scale, eps=eps, block_rows=block_rows,
                           interpret=interpret)


def wkv6(r, k, v, w, u, state=None, *, chunk_size: int = 64,
         interpret: bool = False):
    """WKV6 core. Pallas chunked kernel when available; ref otherwise."""
    try:
        from repro.kernels.wkv6 import wkv6_forward

        return wkv6_forward(r, k, v, w, u, state, chunk_size=chunk_size,
                            interpret=interpret)
    except ImportError:
        return _ref.reference_wkv6(r, k, v, w, u, state, chunk_size=chunk_size)
