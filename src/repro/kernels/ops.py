"""Jit'd dispatch wrappers for the Pallas kernels.

The layer library calls these; backend selection (real TPU kernel vs
interpret-mode validation on CPU vs pure-XLA fallback) is a *config* choice
threaded from mesh rules (paper §4.2), never a code change.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.flash_attention import flash_attention_forward
from repro.kernels.rmsnorm import rmsnorm_forward

__all__ = ["flash_attention", "rmsnorm", "wkv6"]


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_positions=None,
    k_positions=None,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Flash attention for contiguous self-attention (q/k share positions).

    Decode steps (ragged cache positions) fall back to the reference path —
    a 1-token query is GEMV-bound, not a flash-kernel shape.
    """
    same_positions = q_positions is None or (q_positions is k_positions)
    if not same_positions or q.shape[1] == 1:
        return _ref.reference_attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, sliding_window=sliding_window,
            logit_softcap=logit_softcap, scale=scale)
    return flash_attention_forward(
        q, k, v, causal=causal, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=scale,
        block_q=block_q, block_k=block_k, interpret=interpret)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256, interpret: bool = False) -> jax.Array:
    return rmsnorm_forward(x, scale, eps=eps, block_rows=block_rows,
                           interpret=interpret)


def wkv6(r, k, v, w, u, state=None, *, chunk_size: int = 64,
         interpret: bool = False):
    """WKV6 core. Pallas chunked kernel when available; ref otherwise."""
    try:
        from repro.kernels.wkv6 import wkv6_forward

        return wkv6_forward(r, k, v, w, u, state, chunk_size=chunk_size,
                            interpret=interpret)
    except ImportError:
        return _ref.reference_wkv6(r, k, v, w, u, state, chunk_size=chunk_size)
