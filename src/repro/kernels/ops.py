"""Thin dispatchers over the kernel registry.

The layer library calls these; backend selection (real TPU kernel vs
interpret-mode validation vs pure-XLA fallback) is resolved per call site by
``repro.kernels.registry`` from one :class:`KernelConfig` — a *config*
choice threaded from mesh rules (paper §4.2), never a code change.

Each dispatcher only (a) derives the call's :class:`KernelFeatures` from its
arguments (the old ``_same_positions`` / 1-token / paged fallback branches
are now capability predicates in the registry) and (b) invokes the resolved
spec. Resolution is memoized, so the hot-path overhead is one dict lookup.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import registry
from repro.kernels.registry import (DEFAULT_CONFIG, KernelConfig,
                                    KernelFeatures)

__all__ = ["flash_attention", "decode_attention", "paged_gather_kv",
           "rmsnorm", "wkv6", "wkv6_decode"]


def _same_positions(q_positions, k_positions) -> bool:
    """True iff q/k positions are provably identical (so the contiguous
    self-attention kernel applies).

    Checks by *value* for concrete arrays — callers frequently pass
    equal-but-distinct position arrays (e.g. two ``jnp.arange(S)`` calls),
    which an identity-only check would silently send down the
    O(S*T)-materializing reference path. Traced (abstract) values can't be
    value-compared, so they fall back to the identity check.
    """
    if q_positions is None and k_positions is None:
        return True
    if q_positions is k_positions:
        return True
    if q_positions is None or k_positions is None:
        return False
    q_shape = getattr(q_positions, "shape", None)
    if q_shape != getattr(k_positions, "shape", None):
        return False
    try:
        import numpy as np

        if isinstance(q_positions, jax.core.Tracer) or \
                isinstance(k_positions, jax.core.Tracer):
            return False
        return bool(np.array_equal(np.asarray(q_positions),
                                   np.asarray(k_positions)))
    except (TypeError, jax.errors.ConcretizationTypeError):
        return False


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    q_positions=None,
    k_positions=None,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    kernel: Optional[KernelConfig] = None,
    needs_grad: bool = False,
) -> jax.Array:
    """Full-sequence attention (``attention.fwd``).

    The Pallas kernel carries a recompute-based custom_vjp, so it is legal
    under ``jax.grad`` and serves as the training kernel. Ragged-position or
    1-token calls resolve to blockwise/ref via capability predicates.
    """
    kernel = kernel if kernel is not None else DEFAULT_CONFIG
    feats = KernelFeatures(
        platform=registry.current_platform(),
        dtype=str(q.dtype),
        needs_grad=needs_grad,
        ragged_positions=not _same_positions(q_positions, k_positions),
        single_query=q.shape[1] == 1,
        sliding_window=sliding_window is not None,
    )
    spec = registry.resolve_backend("attention.fwd", feats, kernel)
    return spec.fn(
        q, k, v, q_positions=q_positions, k_positions=k_positions,
        causal=causal, sliding_window=sliding_window,
        logit_softcap=logit_softcap, scale=scale,
        cfg=kernel)


def paged_gather_kv(k_pool: jax.Array, v_pool: jax.Array,
                    pos_pool: jax.Array, page_tables: jax.Array,
                    scale_pool: Optional[jax.Array] = None):
    """Materialize a paged pool into contiguous per-sequence (B, N*page, ...)
    K/V + positions via an XLA gather — the portable reference path for
    paged decode. Unmapped logical pages (table entry -1) gather physical
    page 0 but their positions are forced to -1, so masking drops them.

    ``scale_pool`` (quantized pools: (P, page, 2) per-slot fp32 scales) is
    gathered through the same table and applied, so callers always receive
    dequantized fp32 K/V — the storage format stays opaque here.
    """
    tbl = jnp.asarray(page_tables, jnp.int32)  # (B, N)
    B, N = tbl.shape
    P, page, Hkv, D = k_pool.shape
    safe = jnp.maximum(tbl, 0)
    k = k_pool[safe].reshape(B, N * page, Hkv, D)
    v = v_pool[safe].reshape(B, N * page, Hkv, D)
    if scale_pool is not None:
        from repro.quantization import kv as kv_quant

        scales = scale_pool[safe].reshape(B, N * page, 2)
        k, v = kv_quant.dequantize_kv(k, v, scales)
    kpos = jnp.where((tbl >= 0)[:, :, None], pos_pool[safe], -1)
    return k, v, kpos.reshape(B, N * page)


def decode_attention(
    q: jax.Array,  # (B, S', Hq, D)
    k: jax.Array,  # (B, T, Hkv, D) cache — or (P, page, Hkv, D) pool (paged)
    v: jax.Array,
    *,
    q_positions,  # (B, S') or (S',) absolute positions of the new tokens
    k_positions,  # (B, T)/(T,) slot positions — or (P, page) pos pool (paged)
    page_tables: Optional[jax.Array] = None,  # (B, N) int32, -1 = unmapped
    scale_pool: Optional[jax.Array] = None,  # (P, page, 2) fp32 (quantized)
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    replicated_cache: bool = True,
    logits_shard_fn=None,
    kernel: Optional[KernelConfig] = None,
) -> jax.Array:
    """Decode-step attention over a (ring-buffer or paged) cache
    (``attention.decode``).

    The Pallas backend streams the cache through VMEM once per KV group and
    never materializes the ``(B, Hkv, G, S', T)`` logits tensor; with
    ``page_tables`` it DMAs exactly the pages named by each sequence's table
    row (scalar prefetch). The ref backend materializes logits (optionally
    constrained by ``logits_shard_fn`` for sequence-sharded caches) and
    gathers paged pools in XLA.

    ``replicated_cache=False`` declares a mesh-sharded KV cache; capability
    predicates then reject the Pallas backend (no shard_map plumbing yet).
    """
    # Decode positions are never inferable (queries continue an absolute
    # position stream; cache slots hold arbitrary ring positions) — a
    # guessed default would silently mask nearly everything.
    if q_positions is None or k_positions is None:
        raise ValueError("decode_attention requires explicit q_positions "
                         "and k_positions (cache pos tensor)")
    kernel = kernel if kernel is not None else DEFAULT_CONFIG
    feats = KernelFeatures(
        platform=registry.current_platform(),
        dtype=str(q.dtype),
        paged=page_tables is not None,
        sliding_window=sliding_window is not None,
        replicated_cache=replicated_cache,
        # Chunked-prefill / speculative verify windows (S' > 1) resolve
        # separately from 1-token decode steps: the query dim is a real
        # matmul dim there, so backends may tile it differently.
        multi_query=q.shape[1] > 1,
        # KV *storage* dtype as a capability: quantized pools (int8/fp8 +
        # scale_pool) resolve only to backends that dequantize in-kernel
        # (pallas) or gather-dequantize (ref).
        kv_dtype=str(k.dtype),
    )
    spec = registry.resolve_backend("attention.decode", feats, kernel)
    return spec.fn(
        q, k, v, q_positions=q_positions, k_positions=k_positions,
        page_tables=page_tables, scale_pool=scale_pool, causal=causal,
        sliding_window=sliding_window, logit_softcap=logit_softcap,
        scale=scale, logits_shard_fn=logits_shard_fn,
        cfg=kernel)


def rmsnorm(x: jax.Array, scale: jax.Array, *, eps: float = 1e-6,
            kernel: Optional[KernelConfig] = None,
            needs_grad: bool = False) -> jax.Array:
    """RMS normalization (``rmsnorm``). The Pallas kernel is forward-only;
    training resolves to the (autodiffable) ref path via predicates."""
    kernel = kernel if kernel is not None else DEFAULT_CONFIG
    feats = KernelFeatures(
        platform=registry.current_platform(),
        dtype=str(x.dtype),
        needs_grad=needs_grad,
    )
    spec = registry.resolve_backend("rmsnorm", feats, kernel)
    return spec.fn(x, scale, eps=eps,
                   cfg=kernel)


def wkv6_decode(r, k, v, w, u, state, *,
                kernel: Optional[KernelConfig] = None):
    """O(1) recurrent WKV6 step (``wkv6.decode``): one token against the
    carried (B, H, K, V) state."""
    kernel = kernel if kernel is not None else DEFAULT_CONFIG
    feats = KernelFeatures(platform=registry.current_platform(),
                           dtype=str(r.dtype))
    spec = registry.resolve_backend("wkv6.decode", feats, kernel)
    return spec.fn(r, k, v, w, u, state,
                   cfg=kernel)


def wkv6(r, k, v, w, u, state=None, *,
         kernel: Optional[KernelConfig] = None, needs_grad: bool = False):
    """WKV6 core (``wkv6``). Pallas chunked kernel where available and
    eligible (forward-only); chunked-jnp ref otherwise — availability is
    decided at registry import time with the reason surfaced in errors."""
    kernel = kernel if kernel is not None else DEFAULT_CONFIG
    feats = KernelFeatures(
        platform=registry.current_platform(),
        dtype=str(r.dtype),
        needs_grad=needs_grad,
    )
    spec = registry.resolve_backend("wkv6", feats, kernel)
    return spec.fn(r, k, v, w, u, state,
                   cfg=kernel)
