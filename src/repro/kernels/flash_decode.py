"""TPU Pallas flash-decode: split-KV online-softmax attention for decode.

The decode hot path (paper §6, Table 4 TPOT) is one-or-few query tokens
against a long KV cache. The reference path materializes the full
``(B, Hkv, G, S', T)`` logits tensor in f32 per token; this kernel never
does — it streams the cache in ``block_k`` chunks through VMEM, carrying
the online-softmax running max / denominator / accumulator in f32 VMEM
scratch (flash-decoding-style split-KV, with the split axis mapped to the
TPU grid's sequential innermost dimension).

Design points:
  * GQA-aware: the grid iterates (batch, kv_head, kv_block) and all G query
    heads of a group (x S' decode steps) are flattened into the rows of one
    q block — each KV block is fetched from HBM exactly once per group,
    not once per query head.
  * Masking comes directly from the cache's per-slot ``pos`` tensor
    (absolute positions, -1 = empty slot), so ring-buffer / sliding-window
    cache layouts need no gather or re-ordering: wrapped slots mask
    correctly wherever they physically live.
  * Fully-masked rows (e.g. empty continuous-batching slots) produce zeros
    (the reference path produces a degenerate uniform average instead; both
    are unused downstream, but zeros keep the kernel gather-free).
  * ``interpret=True`` runs the same kernel body under the Pallas
    interpreter for CPU validation (config choice, not code change: §4.2).

Forward only — decode is inference-only by construction. The kernel expects
a single-device or replicated KV cache: with sequence-sharded caches, use
the reference decode path (backend ``"ref"``), which constrains the
logits sharding so GSPMD keeps the flash-decoding layout; shard_map
plumbing for this kernel is future work.

Paged variant (:func:`paged_flash_decode_forward`): the KV cache is a shared
pool of fixed-size pages plus a per-sequence page table. The page table is a
*scalar-prefetch* operand (``pltpu.PrefetchScalarGridSpec``), so each grid
step DMAs exactly the physical page named by ``page_tables[b, j]`` — the
pool is never gathered or reordered in HBM. Unmapped logical pages
(table entry -1) are clamped to page 0 for the DMA and masked out entirely
in the kernel body (the mask reads the table, not the page contents, so no
"null page" content invariant is required for reads).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import CompilerParams as _CompilerParams

__all__ = ["flash_decode_forward", "paged_flash_decode_forward"]

NEG_INF = -1e30
_LANES = 128  # VREG lane count: scratch second-minor dim


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def _init_scratch(m_scr, l_scr, acc_scr):
    m_scr[...] = jnp.full_like(m_scr, NEG_INF)
    l_scr[...] = jnp.zeros_like(l_scr)
    acc_scr[...] = jnp.zeros_like(acc_scr)


def _online_block_update(q, k, v, mask, m_scr, l_scr, acc_scr, *,
                         logit_softcap: Optional[float]):
    """One KV block's online-softmax update against q rows (shared by the
    contiguous and paged kernels)."""
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    if logit_softcap is not None:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0:1]  # (R, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    # Guard fully-masked rows: keep the exp argument finite.
    m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
    p = jnp.exp(s - m_safe)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_safe)
    alpha = jnp.where(m_prev <= NEG_INF / 2, 0.0, alpha)

    l_new = alpha * l_scr[:, 0:1] + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * alpha + pv
    m_scr[...] = jnp.broadcast_to(m_new, m_scr.shape)
    l_scr[...] = jnp.broadcast_to(l_new, l_scr.shape)


def _finalize_output(o_ref, l_scr, acc_scr):
    l = l_scr[:, 0:1]
    denom = jnp.where(l == 0.0, 1.0, l)
    o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def _decode_mask(q_pos, k_pos, *, causal, sliding_window):
    # Empty slots (pos < 0) and padding rows are masked; ring wraparound is
    # handled for free because masking reads the slot's absolute position.
    mask = jnp.logical_and(k_pos >= 0, q_pos >= 0)
    if causal:
        mask = jnp.logical_and(mask, k_pos <= q_pos)
    if sliding_window is not None:
        mask = jnp.logical_and(mask, k_pos > q_pos - sliding_window)
    return mask


def _kernel(
    q_ref,  # (1, 1, R, D): rows = S' decode steps x G grouped query heads
    k_ref,  # (1, block_k, 1, D)
    v_ref,  # (1, block_k, 1, D)
    qpos_ref,  # (1, R) int32, -1 = padding row
    kpos_ref,  # (1, block_k) int32, -1 = empty cache slot
    o_ref,  # (1, 1, R, D)
    m_scr,  # (R, _LANES) f32
    l_scr,  # (R, _LANES) f32
    acc_scr,  # (R, D) f32
    *,
    num_kv_blocks: int,
    causal: bool,
    sliding_window: Optional[int],
    logit_softcap: Optional[float],
    scale: float,
):
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        _init_scratch(m_scr, l_scr, acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (R, D)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
    mask = _decode_mask(qpos_ref[0][:, None], kpos_ref[0][None, :],
                        causal=causal, sliding_window=sliding_window)
    _online_block_update(q, k, v, mask, m_scr, l_scr, acc_scr,
                         logit_softcap=logit_softcap)

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        _finalize_output(o_ref, l_scr, acc_scr)


def _paged_kernel(
    tbl_ref,  # (B, N) int32 scalar-prefetch page table, -1 = unmapped
    q_ref,  # (1, 1, R, D)
    k_ref,  # (1, page, 1, D): the physical page named by tbl[b, j]
    v_ref,  # (1, page, 1, D)
    qpos_ref,  # (1, R) int32, -1 = padding row
    kpos_ref,  # (1, page) int32 per-token positions of the page, -1 = empty
    *rest,  # [scale_ref (1, page, 2) f32 when has_scales,] o_ref, scratches
    num_logical_pages: int,
    causal: bool,
    sliding_window: Optional[int],
    logit_softcap: Optional[float],
    scale: float,
    has_scales: bool,
):
    if has_scales:
        scale_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        scale_ref = None
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        _init_scratch(m_scr, l_scr, acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    if scale_ref is not None:
        # Quantized pool: per-token-slot (k, v) scales ride in as a page-shaped
        # operand through the same scalar-prefetch table, so dequantization is
        # in-VMEM and the HBM pool stays in its storage dtype. (Real-TPU note:
        # int8 pools want page >= 32 for native tiling — min int8 tile is
        # (32, 128); the interpreter used in CI accepts any page size.)
        sc = scale_ref[0].astype(jnp.float32)  # (page, 2)
        k = k * sc[:, 0:1]
        v = v * sc[:, 1:2]
    mask = _decode_mask(qpos_ref[0][:, None], kpos_ref[0][None, :],
                        causal=causal, sliding_window=sliding_window)
    # Unmapped logical pages were clamped to physical page 0 for the DMA;
    # masking on the TABLE entry (not the page contents) drops them exactly.
    mask = jnp.logical_and(mask, tbl_ref[b, j] >= 0)
    _online_block_update(q, k, v, mask, m_scr, l_scr, acc_scr,
                         logit_softcap=logit_softcap)

    @pl.when(j == num_logical_pages - 1)
    def _finalize():
        _finalize_output(o_ref, l_scr, acc_scr)


def _pack_q_rows(q: jax.Array, q_positions: jax.Array, Hkv: int):
    """(B, S', Hq, D) -> (B, Hkv, R_pad, D) rows of (s', g) pairs per KV
    group, plus the per-row positions (-1 = padding row)."""
    B, Sq, Hq, D = q.shape
    G = Hq // Hkv
    R = Sq * G
    R_pad = _round_up(max(R, 8), 8)
    # q: (B, S', Hkv*G, D) -> (B, Hkv, S'*G, D); head h = kv * G + g.
    qr = q.reshape(B, Sq, Hkv, G, D).transpose(0, 2, 1, 3, 4).reshape(B, Hkv, R, D)
    qpos_rows = jnp.repeat(q_positions, G, axis=1)  # (B, R): row r -> q_pos[r // G]
    if R_pad != R:
        qr = jnp.pad(qr, ((0, 0), (0, 0), (0, R_pad - R), (0, 0)))
        qpos_rows = jnp.pad(qpos_rows, ((0, 0), (0, R_pad - R)),
                            constant_values=-1)
    return qr, qpos_rows, R, R_pad


def flash_decode_forward(
    q: jax.Array,  # (B, S', Hq, D), S' small (decode steps)
    k: jax.Array,  # (B, T, Hkv, D) — the cache, any slot order
    v: jax.Array,
    q_positions: jax.Array,  # (B, S') absolute positions of the new tokens
    k_positions: jax.Array,  # (B, T) per-slot absolute positions, -1 = empty
    *,
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, Sq, Hq, D = q.shape
    _, T, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    q_positions = jnp.broadcast_to(jnp.asarray(q_positions, jnp.int32), (B, Sq))
    k_positions = jnp.broadcast_to(jnp.asarray(k_positions, jnp.int32), (B, T))

    qr, qpos_rows, R, R_pad = _pack_q_rows(q, q_positions, Hkv)

    block_k = min(block_k, _round_up(T, 8))
    T_pad = _round_up(T, block_k)
    if T_pad != T:
        k = jnp.pad(k, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, T_pad - T), (0, 0), (0, 0)))
        # Padding slots carry pos = -1 and are masked like empty slots.
        k_positions = jnp.pad(k_positions, ((0, 0), (0, T_pad - T)),
                              constant_values=-1)
    num_kv_blocks = T_pad // block_k

    grid = (B, Hkv, num_kv_blocks)
    kernel = functools.partial(
        _kernel,
        num_kv_blocks=num_kv_blocks,
        causal=causal,
        sliding_window=sliding_window,
        logit_softcap=logit_softcap,
        scale=scale,
    )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, R_pad, D), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((1, R_pad), lambda b, h, j: (b, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, R_pad, D), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R_pad, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((R_pad, _LANES), jnp.float32),
            pltpu.VMEM((R_pad, _LANES), jnp.float32),
            pltpu.VMEM((R_pad, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qr, k, v, qpos_rows, k_positions)

    # (B, Hkv, R, D) -> (B, S', Hq, D).
    out = out[:, :, :R].reshape(B, Hkv, Sq, G, D).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, Sq, Hq, D)


def paged_flash_decode_forward(
    q: jax.Array,  # (B, S', Hq, D), S' small (decode steps)
    k_pool: jax.Array,  # (P, page, Hkv, D) — shared physical page pool
    v_pool: jax.Array,  # (P, page, Hkv, D)
    pos_pool: jax.Array,  # (P, page) int32 per-token positions, -1 = empty
    page_tables: jax.Array,  # (B, N) int32 physical page ids, -1 = unmapped
    q_positions: jax.Array,  # (B, S') absolute positions of the new tokens
    *,
    scale_pool: Optional[jax.Array] = None,  # (P, page, 2) f32 dequant scales
    causal: bool = True,
    sliding_window: Optional[int] = None,
    logit_softcap: Optional[float] = None,
    scale: Optional[float] = None,
    interpret: bool = False,
) -> jax.Array:
    """Flash-decode over a paged KV cache via scalar-prefetch page tables.

    The grid is (B, Hkv, N logical pages); for grid step (b, h, j) the
    BlockSpec index map reads ``page_tables[b, j]`` (a prefetched scalar) and
    DMAs that physical page — one page fetch per KV group, no HBM gather.
    Unmapped entries clamp to page 0 and are masked via the table entry.

    On real TPUs ``page`` (the pool's second axis) should be a multiple of
    the sublane count (8 for f32, 16 for bf16) for efficient tiling; the
    interpreter accepts any size.
    """
    B, Sq, Hq, D = q.shape
    P, page, Hkv, _ = k_pool.shape
    _, N = page_tables.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    scale = (D ** -0.5) if scale is None else scale

    q_positions = jnp.broadcast_to(jnp.asarray(q_positions, jnp.int32), (B, Sq))
    page_tables = jnp.asarray(page_tables, jnp.int32)
    pos_pool = jnp.asarray(pos_pool, jnp.int32)

    qr, qpos_rows, R, R_pad = _pack_q_rows(q, q_positions, Hkv)

    has_scales = scale_pool is not None
    kernel = functools.partial(
        _paged_kernel,
        num_logical_pages=N,
        causal=causal,
        sliding_window=sliding_window,
        logit_softcap=logit_softcap,
        scale=scale,
        has_scales=has_scales,
    )

    def phys(b, h, j, tbl):
        del h
        return jnp.maximum(tbl[b, j], 0)

    in_specs = [
        pl.BlockSpec((1, 1, R_pad, D), lambda b, h, j, tbl: (b, h, 0, 0)),
        pl.BlockSpec((1, page, 1, D),
                     lambda b, h, j, tbl: (phys(b, h, j, tbl), 0, h, 0)),
        pl.BlockSpec((1, page, 1, D),
                     lambda b, h, j, tbl: (phys(b, h, j, tbl), 0, h, 0)),
        pl.BlockSpec((1, R_pad), lambda b, h, j, tbl: (b, 0)),
        pl.BlockSpec((1, page),
                     lambda b, h, j, tbl: (phys(b, h, j, tbl), 0)),
    ]
    operands = [page_tables, qr, k_pool, v_pool, qpos_rows, pos_pool]
    if has_scales:
        # Dequant scales follow the same table-indexed page fetch as K/V.
        in_specs.append(pl.BlockSpec(
            (1, page, 2), lambda b, h, j, tbl: (phys(b, h, j, tbl), 0, 0)))
        operands.append(jnp.asarray(scale_pool, jnp.float32))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, N),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, R_pad, D), lambda b, h, j, tbl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((R_pad, _LANES), jnp.float32),
            pltpu.VMEM((R_pad, _LANES), jnp.float32),
            pltpu.VMEM((R_pad, D), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, R_pad, D), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)

    out = out[:, :, :R].reshape(B, Hkv, Sq, G, D).transpose(0, 2, 1, 3, 4)
    return out.reshape(B, Sq, Hq, D)
