"""Exact optimizer-state byte accounting.

Works on concrete arrays AND ``jax.ShapeDtypeStruct`` trees (the trainer
calls it on ``eval_shape`` output, so the gauges cost no device transfer).
"Exact" means counted from the realized state tree — every leaf of every
transform's state (moments, factored accumulators, quantization scales,
schedule counts), not an estimate from a formula.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import numpy as np

__all__ = ["state_bytes", "per_leaf_state_bytes", "per_device_state_bytes"]


def _leaf_bytes(leaf: Any) -> int:
    if not hasattr(leaf, "dtype"):
        return 0
    size = getattr(leaf, "size", None)
    if size is None:
        size = int(np.prod(getattr(leaf, "shape", ())))
    return int(size) * np.dtype(leaf.dtype).itemsize


def state_bytes(opt_state: Any) -> int:
    """Total bytes of every array leaf in an optimizer-state pytree."""
    return sum(_leaf_bytes(l) for l in jax.tree.leaves(opt_state))


def per_leaf_state_bytes(opt_state: Any) -> Dict[str, int]:
    """Exact bytes per state leaf, keyed by the leaf's tree path (e.g.
    ``.mu['decoder']['stack']['layer'][...]``) — the per-leaf report each
    GradientTransformation's state contributes."""
    flat, _ = jax.tree_util.tree_flatten_with_path(opt_state)
    return {jax.tree_util.keystr(path): _leaf_bytes(leaf)
            for path, leaf in flat}


def per_device_state_bytes(opt_state: Any, shardings: Any) -> Optional[int]:
    """Bytes of the optimizer state resident on ONE device under
    ``shardings`` (a matching tree of NamedShardings; replicated leaves count
    in full, ZeRO-1-partitioned leaves at 1/N). Returns None when any
    sharding is missing (no mesh)."""
    leaves = jax.tree.leaves(opt_state)
    shard_leaves = jax.tree.leaves(shardings, is_leaf=lambda s: s is None)
    if len(leaves) != len(shard_leaves):
        return None
    total = 0
    for leaf, sh in zip(leaves, shard_leaves):
        if not hasattr(leaf, "dtype"):
            continue
        if sh is None or not hasattr(sh, "shard_shape"):
            return None
        shape = sh.shard_shape(tuple(leaf.shape))
        total += int(np.prod(shape)) * np.dtype(leaf.dtype).itemsize
    return total
