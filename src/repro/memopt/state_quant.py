"""Quantized Adam EMA storage behind ``adamw(state_dtype=...)``.

The ONLY module that interprets optimizer state-dtype names:

  * ``"fp32"`` / ``"bf16"`` — plain low-precision moment storage
    (delegates to ``scale_by_adam(moment_dtype=...)``; bf16 halves the
    8 bytes/param EMA footprint).
  * ``"int8"`` — 8-bit Adam: moments stored int8 with per-row fp32 scales
    (last-axis symmetric quantization via
    :func:`repro.quantization.numerics.quantize_int8`); the EMA update
    dequantizes, accumulates in fp32, and requantizes, so the *update
    math* always runs full-precision on the freshly-accumulated values.

ZeRO-1 composition is a structural invariant: the int8 ``mu``/``nu`` trees
are built with the params treedef (same shapes, smaller dtype), so the
trainer's ``opt_state_shardings`` structure-match assigns them the ZeRO-1
NamedShardings and they keep sharding along the data axes. The fp32 scales
live in a flat dict keyed by leaf index — a tree that deliberately does NOT
match the params structure, so those (tiny, differently-shaped) leaves fall
through to replication instead of crashing on shape-mismatched param
shardings.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.quantization.numerics import dequantize, quantize_int8
from repro.trainer import optimizers as opt_lib

__all__ = [
    "resolve_state_dtype",
    "scale_by_adam_state_dtype",
    "scale_by_adam_int8",
    "QuantizedAdamState",
]

# Sanctioned state-dtype names -> (storage dtype, quantized?). Names, not
# raw dtypes, are the config surface (the grep contract keeps both the
# names' interpretation and the dtype literals inside memopt/).
_STATE_DTYPES = {
    "fp32": (jnp.float32, False),
    "float32": (jnp.float32, False),
    "bf16": (jnp.bfloat16, False),
    "bfloat16": (jnp.bfloat16, False),
    "int8": (jnp.int8, True),
}


def resolve_state_dtype(name: str) -> Tuple[Any, bool]:
    """``"fp32" | "bf16" | "int8"`` -> (storage dtype, quantized?)."""
    key = str(name).lower()
    if key not in _STATE_DTYPES:
        raise ValueError(
            f"Unknown optimizer state_dtype {name!r}; expected one of "
            f"{sorted(set(_STATE_DTYPES))}")
    return _STATE_DTYPES[key]


def scale_by_adam_state_dtype(b1: float, b2: float, eps: float,
                              state_dtype: str) -> opt_lib.GradientTransformation:
    """The ``adamw(state_dtype=...)`` implementation hook: resolves the
    state-dtype name and returns the matching Adam moment transform."""
    dtype, quantized = resolve_state_dtype(state_dtype)
    if quantized:
        return scale_by_adam_int8(b1=b1, b2=b2, eps=eps)
    return opt_lib.scale_by_adam(b1=b1, b2=b2, eps=eps, moment_dtype=dtype)


class QuantizedAdamState(NamedTuple):
    """``mu``/``nu``: int8, param-structured (ZeRO-1 shards them).
    ``scales``: flat ``{"mu0000": ..., "nu0000": ...}`` fp32 per-row scales
    (non-param-structured by design -> replicated, and tiny: 4/m bytes per
    moment element for a last-axis size of m)."""

    count: jax.Array
    mu: Any
    nu: Any
    scales: Dict[str, jax.Array]


def _qaxis(leaf) -> Optional[int]:
    return -1 if getattr(leaf, "ndim", 0) >= 1 else None


def _scale_shape(leaf) -> Tuple[int, ...]:
    if getattr(leaf, "ndim", 0) >= 1:
        return tuple(leaf.shape[:-1]) + (1,)
    return ()


def scale_by_adam_int8(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8
                       ) -> opt_lib.GradientTransformation:
    """8-bit Adam: int8 moments + per-row fp32 scales (~4x smaller EMA
    buffers than fp32, ~6/8 of total state bytes saved before masters).

    Accuracy note: the *current-step* m/v used for the update are the fp32
    EMA results (quantization error enters only through the carried state),
    which is what keeps short-horizon loss curves near the fp32 ones.
    """

    def init(params):
        leaves, treedef = jax.tree_util.tree_flatten(params)
        # Distinct arrays per leaf (no aliasing): the trainer donates the
        # whole state to the jitted step, and a buffer appearing twice in
        # the donation set is an XLA error.
        mu = jax.tree_util.tree_unflatten(
            treedef, [jnp.zeros(p.shape, jnp.int8) for p in leaves])
        nu = jax.tree_util.tree_unflatten(
            treedef, [jnp.zeros(p.shape, jnp.int8) for p in leaves])
        scales = {}
        for i, p in enumerate(leaves):
            scales[f"mu{i:04d}"] = jnp.ones(_scale_shape(p), jnp.float32)
            scales[f"nu{i:04d}"] = jnp.ones(_scale_shape(p), jnp.float32)
        return QuantizedAdamState(count=jnp.zeros((), jnp.int32),
                                  mu=mu, nu=nu, scales=scales)

    def update(grads, state, params):
        count = state.count + 1
        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        mu_leaves = jax.tree.leaves(state.mu)
        nu_leaves = jax.tree.leaves(state.nu)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        new_mu, new_nu, updates = [], [], []
        scales = dict(state.scales)
        for i, g in enumerate(g_leaves):
            k_mu, k_nu = f"mu{i:04d}", f"nu{i:04d}"
            g32 = g.astype(jnp.float32)
            m = b1 * dequantize(mu_leaves[i], scales[k_mu]) + (1 - b1) * g32
            v = (b2 * dequantize(nu_leaves[i], scales[k_nu])
                 + (1 - b2) * jnp.square(g32))
            updates.append((m / c1) / (jnp.sqrt(v / c2) + eps))
            q_m, s_m = quantize_int8(m, _qaxis(g))
            q_v, s_v = quantize_int8(v, _qaxis(g))
            new_mu.append(q_m)
            new_nu.append(q_v)
            scales[k_mu] = s_m.reshape(_scale_shape(g))
            scales[k_nu] = s_v.reshape(_scale_shape(g))
        new_state = QuantizedAdamState(
            count=count,
            mu=jax.tree_util.tree_unflatten(treedef, new_mu),
            nu=jax.tree_util.tree_unflatten(treedef, new_nu),
            scales=scales)
        return jax.tree_util.tree_unflatten(treedef, updates), new_state

    return opt_lib.GradientTransformation(init, update)
