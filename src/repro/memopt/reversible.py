"""Reversible two-stream residual stacks (RevNet / Reformer style).

A pre-norm TransformerLayer is the sum of two residual branches:
``F(x) = attn(norm(x))`` and ``G(x) = ffn(norm(x))``. Splitting the stream
in two makes the layer *invertible*::

    y1 = x1 + F(x2)          x2 = y2 - G(y1)
    y2 = x2 + G(y1)          x1 = y1 - F(x2)

so the backward pass can RECONSTRUCT every layer's inputs from its outputs
instead of saving them: activation memory is O(1) in depth (only the final
``(y1, y2)`` pair is a residual of the whole stack) where both the plain
scan and remat-"full" keep an O(L) stack of carries. Implemented as one
``jax.custom_vjp`` over the stacked-params scan; the backward runs its own
``reverse=True`` scan, inverting and then VJP-ing one layer at a time.

Composition and gating:
  * Requires a residual-decomposable inner layer — one exposing the
    ``attn_branch`` / ``ffn_branch`` interface (``TransformerLayer``, any
    mixer/FFN inside it). ``Block`` / heterogeneous / non-residual layouts
    cannot invert and fail at build time with a clear error.
  * ``residual_dropout`` must be 0: a sampled mask breaks exact inversion.
  * Supersedes ``remat_policy`` inside the stack (there is nothing left to
    checkpoint — inversion already recomputes from structure); remat still
    applies to everything outside the stack.
  * Training-only knob: the decode interface (``init_states`` / ``prefill``
    / ``extend_step``) is single-stream and raises on reversible stacks.
  * Side outputs (summaries, aux losses) from inner layers are dropped —
    the custom_vjp boundary cannot re-emit them.

Numerics: inversion recovers inputs up to one rounding of the residual add
(exact to ~1e-6 relative in fp32); gradients match the plain two-stream
autodiff to the same order.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.module import functional

__all__ = ["validate_reversible", "reversible_forward", "rev_stack"]


def validate_reversible(layer_module) -> None:
    """Build-time gate: raises ValueError for non-invertible layouts."""
    missing = [m for m in ("attn_branch", "ffn_branch")
               if not hasattr(layer_module, m)]
    if missing:
        raise ValueError(
            "reversible=True requires a residual-decomposable layer "
            "exposing the attn_branch/ffn_branch interface (e.g. "
            f"TransformerLayer); {type(layer_module).__name__} lacks "
            f"{missing}. Heterogeneous Blocks and non-residual mixers "
            "cannot be inverted — use remat_policy instead.")
    rate = getattr(layer_module.config, "residual_dropout", 0.0)
    if rate:
        raise ValueError(
            f"reversible=True is incompatible with residual_dropout={rate}: "
            "a sampled dropout mask cannot be reconstructed during "
            "inversion. Set residual_dropout=0 (or reversible=False).")


def _zero_cotangent(x):
    """Cotangent for a non-differentiated primal input: float0 for integer
    leaves (positions), zeros for float leaves."""
    if jnp.issubdtype(x.dtype, jnp.floating) or \
            jnp.issubdtype(x.dtype, jnp.complexfloating):
        return jnp.zeros_like(x)
    return np.zeros(x.shape, jax.dtypes.float0)


def rev_stack(layer, params, x1, x2, positions=None, *, is_training=True,
              unroll: Any = 1, use_custom_vjp: bool = True):
    """Runs the two-stream reversible scan over stacked ``params``.

    ``layer`` is the (shared) inner module; ``params`` its stacked weights
    with a leading layer axis. Returns ``(y1, y2)``. With
    ``use_custom_vjp=False`` the same math runs under plain autodiff — the
    reference the custom backward is tested against.
    """

    def call(params_i, method, h, pos):
        inputs = {"x": h}
        if method == "attn_branch":
            inputs["positions"] = pos
        out, _ = functional(layer, state=params_i, inputs=inputs,
                            prng_key=None, is_training=is_training,
                            method=method)
        return out

    def fwd_scan(params, x1, x2, pos):
        def step(carry, p_i):
            h1, h2 = carry
            y1 = h1 + call(p_i, "attn_branch", h2, pos)
            y2 = h2 + call(p_i, "ffn_branch", y1, pos)
            return (y1, y2), None

        (y1, y2), _ = jax.lax.scan(step, (x1, x2), params, unroll=unroll)
        return y1, y2

    if not use_custom_vjp:
        return fwd_scan(params, x1, x2, positions)

    @jax.custom_vjp
    def stack(params, x1, x2, pos):
        return fwd_scan(params, x1, x2, pos)

    def stack_fwd(params, x1, x2, pos):
        y1, y2 = fwd_scan(params, x1, x2, pos)
        # O(1)-in-depth residuals: the stacked params (already resident) and
        # the FINAL stream pair only — no per-layer activation stack.
        return (y1, y2), (params, y1, y2, pos)

    def stack_bwd(res, cts):
        params, y1, y2, pos = res
        dy1, dy2 = cts

        def back(carry, p_i):
            h1, h2, d1, d2 = carry
            # Invert: x2 = y2 - G(y1); x1 = y1 - F(x2) — recomputing each
            # branch under jax.vjp to get its pullback in the same pass.
            g_out, g_vjp = jax.vjp(
                lambda p, h: call(p, "ffn_branch", h, pos), p_i, h1)
            x2 = h2 - g_out
            f_out, f_vjp = jax.vjp(
                lambda p, h: call(p, "attn_branch", h, pos), p_i, x2)
            x1 = h1 - f_out
            # RevNet adjoint: y2 depends on y1 through G, so the total
            # y1-cotangent is dy1 + G^T dy2; x2 then collects dy2 + F^T of it.
            dp_g, dg_h1 = g_vjp(d2)
            t1 = d1 + dg_h1
            dp_f, df_x2 = f_vjp(t1)
            dx1 = t1
            dx2 = d2 + df_x2
            dp = jax.tree.map(jnp.add, dp_g, dp_f)
            return (x1, x2, dx1, dx2), dp

        (_, _, dx1, dx2), dparams = jax.lax.scan(
            back, (y1, y2, dy1, dy2), params, reverse=True, unroll=unroll)
        dpos = jax.tree.map(_zero_cotangent, pos)
        return dparams, dx1, dx2, dpos

    stack.defvjp(stack_fwd, stack_bwd)
    return stack(params, x1, x2, positions)


def reversible_forward(repeat, x, positions: Optional[jax.Array] = None):
    """The ``Repeat.forward`` path when ``cfg.reversible`` is set: duplicate
    the stream, run the reversible scan, merge. The same function runs in
    train and eval (custom_vjp is transparent when not differentiated), so
    the model computes identically in both modes."""
    validate_reversible(repeat.layer)
    params = repeat.state["layer"]
    ctx = repeat._ctx
    y1, y2 = rev_stack(
        repeat.layer, params, x, x, positions,
        is_training=ctx.is_training, unroll=repeat.config.scan_unroll)
    # Merge by averaging: keeps the output magnitude of one stream (the
    # final RMSNorm sees the same scale as a single-stream stack).
    return 0.5 * (y1 + y2)
