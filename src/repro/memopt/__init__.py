"""Memory-frugal training: optimizer-state and activation bytes as config.

Mirrors how :mod:`repro.quantization` made *precision* a config axis — this
subsystem does the same for *training memory*, the binding constraint on max
trainable model size per host (per-device optimizer + activation bytes):

  * :mod:`repro.memopt.factored` — Adafactor-style row/column-factored and
    SM3 rank-1 second moments: O(n+m) accumulators instead of Adam's two
    O(n*m) EMA buffers, in the trainer's ``GradientTransformation`` protocol.
  * :mod:`repro.memopt.state_quant` — bf16 / int8(+fp32-scale) storage for
    Adam's EMA buffers behind the ``state_dtype`` knob on ``adamw`` (the
    int8 path reuses :mod:`repro.quantization.numerics`). Quantized moment
    trees stay param-structured so ZeRO-1 keeps sharding them.
  * :mod:`repro.memopt.reversible` — reversible two-stream residual stacks
    (``Repeat.Config.reversible``): activations are *recomputed from the
    block's invertible structure* in the backward pass (``jax.custom_vjp``),
    so activation memory is O(1) in depth instead of O(L).
  * :mod:`repro.memopt.accounting` — exact state-bytes accounting
    (``state_bytes`` / ``per_leaf_state_bytes`` / ``per_device_state_bytes``)
    exported by the trainer as ``train/opt_state_bytes`` gauges.
  * :mod:`repro.memopt.modifier` — one :class:`MemoryModifier` (optimizer
    choice / state_dtype / reversible) wired into ``-frugal`` mesh rules.

Contract: optimizer-state dtype *names* ("fp32", "bf16", "int8") are
interpreted ONLY here (grep-enforced by tests/test_memopt.py) — everything
else threads them through config.
"""

from repro.memopt.accounting import (
    per_device_state_bytes,
    per_leaf_state_bytes,
    state_bytes,
)
from repro.memopt.factored import adafactor, sm3
from repro.memopt.state_quant import resolve_state_dtype, scale_by_adam_state_dtype

__all__ = [
    "adafactor",
    "sm3",
    "state_bytes",
    "per_leaf_state_bytes",
    "per_device_state_bytes",
    "resolve_state_dtype",
    "scale_by_adam_state_dtype",
]
