"""MemoryModifier: the whole memory-frugal recipe as one mesh-rule entry.

Mirrors ``QuantizationModifier``: a single ConfigModifier that rewrites the
trainer config — optimizer choice (adamw / adafactor / sm3, preserving the
schedule and decay already configured), quantized Adam state storage
(``state_dtype``), and reversible residual stacks — so an instance-type
suffix like ``-frugal`` is ~10 lines of config and zero model-code changes.
"""

from __future__ import annotations

from typing import Optional

from repro.core.config import (
    RequiredFieldValue,
    config_class,
    config_for_function,
    update_configs_recursively,
)
from repro.core.module import no_context
from repro.memopt import factored
from repro.trainer import optimizers as opt_lib
from repro.trainer.mesh_rules import ConfigModifier

__all__ = ["MemoryModifier"]

_OPTIMIZERS = {
    "adamw": opt_lib.adamw,
    "adafactor": factored.adafactor,
    "sm3": factored.sm3,
}

# Fields carried over when swapping the optimizer factory (schedule / decay
# are experiment choices, not memory choices).
_CARRY_FIELDS = ("learning_rate", "peak_lr", "weight_decay",
                 "weight_decay_scales", "max_grad_norm")


class MemoryModifier(ConfigModifier):
    @config_class
    class Config(ConfigModifier.Config):
        # "adamw" | "adafactor" | "sm3"; None keeps the configured optimizer.
        optimizer: Optional[str] = None
        # Adam EMA storage: "fp32" | "bf16" | "int8" (resolved inside
        # repro.memopt.state_quant). Requires an adamw-family optimizer.
        state_dtype: Optional[str] = None
        # Sets reversible=... on every Repeat stack in the model tree.
        reversible: Optional[bool] = None

    @no_context
    def apply(self, trainer_cfg):
        c = self.config
        if c.optimizer is not None:
            if c.optimizer not in _OPTIMIZERS:
                raise ValueError(
                    f"MemoryModifier.optimizer={c.optimizer!r}; expected one "
                    f"of {sorted(_OPTIMIZERS)}")
            old = trainer_cfg.learner.optimizer
            new = config_for_function(_OPTIMIZERS[c.optimizer])
            if old is not None:
                for field in _CARRY_FIELDS:
                    if field in old.keys() and field in new.keys():
                        value = getattr(old, field)
                        if value is not None and not isinstance(
                                value, RequiredFieldValue):
                            new.set(**{field: value})
            trainer_cfg.learner.optimizer = new
        if c.state_dtype is not None:
            opt = trainer_cfg.learner.optimizer
            if opt is None or "state_dtype" not in opt.keys():
                raise ValueError(
                    f"MemoryModifier.state_dtype={c.state_dtype!r} needs an "
                    "adamw-family optimizer (factored optimizers keep no "
                    f"Adam EMA buffers to quantize); got {opt}")
            opt.set(state_dtype=c.state_dtype)
        if c.reversible is not None:
            update_configs_recursively(
                trainer_cfg, {"reversible": c.reversible})
        return trainer_cfg
