"""Factored second-moment optimizers: Adafactor (row/column) and SM3 (rank-1).

Both follow the trainer's ``GradientTransformation`` protocol and compose
with the same ``chain`` pieces ``adamw`` uses (global-norm clip, decoupled
weight decay with per-param scales from ``ParameterSpec``, LR schedule).

Memory layout deliberately differs from Adam's: the accumulators are NOT
param-shaped, so they live in flat dicts keyed by leaf index. Under ZeRO-1
the trainer's ``opt_state_shardings`` then replicates them (their tree
structure never matches the params tree) — which is fine, because O(n+m)
row/column vectors ARE the memory win: for a stacked ``(L, n, m)`` weight,
Adafactor keeps ``L*(n+m)`` floats where Adam keeps ``2*L*n*m``.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.trainer.optimizers import (
    GradientTransformation,
    add_decayed_weights,
    chain,
    clip_by_global_norm,
    constant_schedule,
    scale_by_schedule,
)

__all__ = ["adafactor", "sm3", "scale_by_factored_rms", "scale_by_sm3"]


def _leaf_key(i: int) -> str:
    return f"{i:04d}"


# ------------------------------- Adafactor ----------------------------------


class FactoredState(NamedTuple):
    """Second-moment state: factored ``(v_row, v_col)`` pairs for >=2-d
    leaves, a full accumulator for the (tiny) rest. All three are flat dicts
    keyed by flattened-leaf index — intentionally not param-structured."""

    count: jax.Array
    v_row: Dict[str, jax.Array]
    v_col: Dict[str, jax.Array]
    v_full: Dict[str, jax.Array]


def _factors(shape: Tuple[int, ...], min_dim_size_to_factor: int) -> bool:
    return len(shape) >= 2 and min(shape[-2:]) >= min_dim_size_to_factor


def scale_by_factored_rms(b2_cap: float = 0.999, eps: float = 1e-30,
                          clip_threshold: float = 1.0,
                          min_dim_size_to_factor: int = 8
                          ) -> GradientTransformation:
    """Adafactor's factored RMS preconditioner (Shazeer & Stern 2018).

    For a ``(..., n, m)`` leaf the second moment is approximated by the
    rank-1 outer product of row/column EMAs (leading dims — e.g. Repeat's
    stacked layer axis — are batch dims, so each scanned layer keeps its own
    factors). Decay follows the paper's step-dependent schedule
    ``b2(t) = min(b2_cap, 1 - t^-0.8)``; updates are RMS-clipped at
    ``clip_threshold`` (the paper's update-clipping, which is why there is
    no global-norm clip in :func:`adafactor` by default).
    """

    def init(params):
        leaves = jax.tree.leaves(params)
        v_row, v_col, v_full = {}, {}, {}
        for i, p in enumerate(leaves):
            k = _leaf_key(i)
            if _factors(p.shape, min_dim_size_to_factor):
                v_row[k] = jnp.zeros(p.shape[:-1], jnp.float32)
                v_col[k] = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
            else:
                v_full[k] = jnp.zeros(p.shape, jnp.float32)
        return FactoredState(count=jnp.zeros((), jnp.int32),
                             v_row=v_row, v_col=v_col, v_full=v_full)

    def update(grads, state, params):
        count = state.count + 1
        t = count.astype(jnp.float32)
        b2 = jnp.minimum(b2_cap, 1.0 - t ** -0.8)
        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        v_row, v_col, v_full = (dict(state.v_row), dict(state.v_col),
                                dict(state.v_full))
        updates = []
        for i, g in enumerate(g_leaves):
            k = _leaf_key(i)
            g32 = g.astype(jnp.float32)
            g2 = jnp.square(g32) + eps
            if k in v_row:
                vr = b2 * v_row[k] + (1 - b2) * jnp.mean(g2, axis=-1)
                vc = b2 * v_col[k] + (1 - b2) * jnp.mean(g2, axis=-2)
                v_row[k], v_col[k] = vr, vc
                # V-hat = (vr/mean(vr)) (x) vc; precondition by rsqrt of it.
                r = jax.lax.rsqrt(
                    vr / jnp.mean(vr, axis=-1, keepdims=True))
                c = jax.lax.rsqrt(vc)
                u = g32 * r[..., :, None] * c[..., None, :]
            else:
                v = b2 * v_full[k] + (1 - b2) * g2
                v_full[k] = v
                u = g32 * jax.lax.rsqrt(v)
            # Update clipping: divide by max(1, RMS(u)/d).
            rms = jnp.sqrt(jnp.mean(jnp.square(u)))
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            updates.append(u)
        new_state = FactoredState(count=count, v_row=v_row, v_col=v_col,
                                  v_full=v_full)
        return jax.tree_util.tree_unflatten(treedef, updates), new_state

    return GradientTransformation(init, update)


def adafactor(
    learning_rate: Optional[Callable] = None,
    peak_lr: float = 1e-2,
    b2_cap: float = 0.999,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    min_dim_size_to_factor: int = 8,
    weight_decay: float = 0.0,
    weight_decay_scales: Optional[Any] = None,
    max_grad_norm: Optional[float] = None,
) -> GradientTransformation:
    """Adafactor: Adam-quality adaptivity at O(n+m) second-moment memory.

    No first moment and factored second moments: optimizer state shrinks
    from Adam's 8 bytes/param to ~``4*(n+m)/(n*m)`` bytes/param for matrix
    leaves. ``max_grad_norm`` defaults to None because the transform clips
    per-leaf update RMS instead (the paper's recommendation).
    """
    schedule = learning_rate or constant_schedule(peak_lr)
    parts = []
    if max_grad_norm is not None:
        parts.append(clip_by_global_norm(max_grad_norm))
    parts.append(scale_by_factored_rms(
        b2_cap=b2_cap, eps=eps, clip_threshold=clip_threshold,
        min_dim_size_to_factor=min_dim_size_to_factor))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, weight_decay_scales))
    parts.append(scale_by_schedule(lambda step: -schedule(step)))
    return chain(*parts)


# ---------------------------------- SM3 -------------------------------------


class SM3State(NamedTuple):
    """Rank-1 accumulators: one vector per tensor axis (``accumulators[leaf
    key][axis index]`` has shape ``(d_axis,)``), O(sum d_i) per leaf. Flat
    dict keyed by leaf index — intentionally not param-structured."""

    count: jax.Array
    accumulators: Dict[str, Dict[str, jax.Array]]


def _sm3_min(accs: Dict[str, jax.Array], shape: Tuple[int, ...]) -> jax.Array:
    """Elementwise min over the per-axis accumulators, each broadcast to the
    full tensor shape (the SM3 cover estimate of the second moment)."""
    ndim = len(shape)
    est = None
    for ax_s, a in accs.items():
        ax = int(ax_s)
        bshape = [1] * ndim
        bshape[ax] = shape[ax]
        b = a.reshape(bshape)
        est = b if est is None else jnp.minimum(est, b)
    return jnp.broadcast_to(est, shape)


def scale_by_sm3(eps: float = 1e-8) -> GradientTransformation:
    """SM3-II (Anil et al. 2019): AdaGrad-style adaptivity from one
    accumulator vector per tensor axis instead of a full-shape accumulator.

    nu <- min_i(broadcast a_i) + g^2; a_i <- max over the other axes of nu;
    update = g / sqrt(nu + eps). Memory is O(sum_i d_i) per leaf — the
    rank-1 cover — vs AdaGrad/Adam's O(prod_i d_i).
    """

    def init(params):
        accs: Dict[str, Dict[str, jax.Array]] = {}
        for i, p in enumerate(jax.tree.leaves(params)):
            shape = p.shape if p.ndim else (1,)
            accs[_leaf_key(i)] = {
                str(ax): jnp.zeros((shape[ax],), jnp.float32)
                for ax in range(len(shape))}
        return SM3State(count=jnp.zeros((), jnp.int32), accumulators=accs)

    def update(grads, state, params):
        g_leaves, treedef = jax.tree_util.tree_flatten(grads)
        new_accs = {}
        updates = []
        for i, g in enumerate(g_leaves):
            k = _leaf_key(i)
            g32 = g.astype(jnp.float32)
            shaped = g32.reshape((1,)) if g32.ndim == 0 else g32
            nu = _sm3_min(state.accumulators[k], shaped.shape)
            nu = nu + jnp.square(shaped)
            ndim = shaped.ndim
            new_accs[k] = {
                str(ax): jnp.max(nu, axis=tuple(a for a in range(ndim)
                                                if a != ax))
                for ax in range(ndim)}
            u = shaped * jax.lax.rsqrt(nu + eps)
            updates.append(u.reshape(g.shape))
        new_state = SM3State(count=state.count + 1, accumulators=new_accs)
        return jax.tree_util.tree_unflatten(treedef, updates), new_state

    return GradientTransformation(init, update)


def sm3(
    learning_rate: Optional[Callable] = None,
    peak_lr: float = 1e-1,
    eps: float = 1e-8,
    weight_decay: float = 0.0,
    weight_decay_scales: Optional[Any] = None,
    max_grad_norm: Optional[float] = 1.0,
) -> GradientTransformation:
    """SM3 with the trainer's usual clip/decay/schedule chain.

    AdaGrad-flavoured: typical peak LRs are ~100x Adam's (the accumulator
    sum grows unboundedly, shrinking the effective step over time).
    Momentum is deliberately not offered — it would re-add a param-sized
    buffer and erase the memory win this optimizer exists for.
    """
    schedule = learning_rate or constant_schedule(peak_lr)
    parts = []
    if max_grad_norm is not None:
        parts.append(clip_by_global_norm(max_grad_norm))
    parts.append(scale_by_sm3(eps=eps))
    if weight_decay:
        parts.append(add_decayed_weights(weight_decay, weight_decay_scales))
    parts.append(scale_by_schedule(lambda step: -schedule(step)))
    return chain(*parts)
