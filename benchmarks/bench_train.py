"""Paper Table 3: training performance.

Wall-clock MFU on real accelerators is out of scope for this CPU container;
this benchmark reports (a) measured CPU step time + tokens/s on the reduced
per-family models (regression tracking across the whole substrate: data ->
model -> grads -> optimizer), with the train step pre-compiled so step time
is warm, (b) an XLA-derived peak-HBM proxy per arch (argument + temp +
output bytes of the compiled train step), (c) fp32 vs bf16-dtype-policy step
time / loss parity on a subset of archs, and (d) the roofline-derived
step-time bound for the paper-size models from AOT dry-run records when
available (EXPERIMENTS.md §Roofline holds the full table).

``run.py`` persists ``LAST_JSON`` as ``BENCH_train.json`` so the training
perf trajectory is tracked across PRs.
"""

import glob
import json
import os
import time

import jax

from repro.configs import registry
from repro.core.config import config_for_function
from repro.observability.hardware import estimate_mfu
from repro.trainer import optimizers as opt_lib
from repro.trainer.trainer import SpmdTrainer

BENCH_ARCHS = ["qwen2-1.5b", "mixtral-8x7b", "jamba-1.5-large-398b",
               "rwkv6-7b", "hubert-xlarge"]
# Archs additionally benchmarked under the bf16 dtype policy (fp32 parity
# tolerance documented in README "Training path").
BF16_ARCHS = ["qwen2-1.5b", "rwkv6-7b"]
# Arch for the fp8-vs-bf16 delayed-scaling parity run (README
# "Low-precision end-to-end"; acceptance: <1% relative loss diff).
FP8_PARITY_ARCH = "qwen2-1.5b"
FP8_PARITY_STEPS = 60
# Memory ablation (README "Memory-frugal training"): one arch, deepened to
# MEMOPT_DEPTH (activation memory is the depth-scaling term reversible
# blocks remove), fixed global batch, 60-step loss parity columns.
MEMOPT_ARCH = "qwen2-1.5b"
MEMOPT_STEPS = 60
MEMOPT_DEPTH = 8
# name -> (MemoryModifier kwargs, peak_lr override). LR is a per-optimizer-
# family tuning constant (Adafactor/SM3 take ~10-100x Adam's LR), not part
# of the memory ablation itself.
MEMOPT_CONFIGS = [
    ("adamw", None, None),
    ("adamw-bf16-state", {"state_dtype": "bf16"}, None),
    ("adamw-int8-state", {"state_dtype": "int8"}, None),
    ("adafactor", {"optimizer": "adafactor"}, 1e-2),
    ("sm3", {"optimizer": "sm3"}, 1e-1),
    ("reversible", {"reversible": True}, None),
]

LAST_JSON = None


def _make_trainer(arch, *, policy=None, fp8=False, memopt=None, depth=None,
                  lr=1e-3, steps=8, batch=8, seq=32):
    spec = registry.get_spec(arch)
    model_cfg = spec.make_smoke()
    if depth is not None:
        from repro.core.config import update_configs_recursively

        update_configs_recursively(model_cfg, {"num_layers": depth})
    cfg = SpmdTrainer.default_config().set(
        name="t", model=model_cfg, max_steps=steps, log_every_n=steps)
    task = {"audio": "audio", "vlm": "vlm"}.get(spec.modality, "lm")
    cfg.input.set(task=task, vocab_size=model_cfg.decoder.vocab_size,
                  seq_len=seq, global_batch_size=batch,
                  model_dim=model_cfg.decoder.dim, num_patches=4)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(peak_lr=lr)
    if policy is not None:
        from repro.trainer.mesh_rules import DtypePolicyModifier

        modifier = DtypePolicyModifier.default_config().set(
            policy=policy).instantiate()
        cfg = modifier.apply(cfg)
    if fp8:
        from repro.quantization.modifier import QuantizationModifier

        cfg = QuantizationModifier.default_config().set(
            fp8=True).instantiate().apply(cfg)
    if memopt is not None:
        from repro.memopt.modifier import MemoryModifier

        cfg = MemoryModifier.default_config().set(
            **memopt).instantiate().apply(cfg)
    return cfg.instantiate()


def _step_cost(trainer):
    """Compiled-step cost via the trainer's own observability hook
    (``step_cost_analysis``: flops, bytes_accessed, peak_hbm_proxy_bytes),
    with a parameter-bytes fallback when the backend reports nothing."""
    cost = dict(trainer.step_cost_analysis())
    if not cost.get("peak_hbm_proxy_bytes"):
        state = jax.eval_shape(trainer.init_state)
        cost["peak_hbm_proxy_bytes"] = sum(
            l.size * l.dtype.itemsize
            for l in jax.tree.leaves(state) if hasattr(l, "size"))
    return cost


def _train_bench(arch, *, policy=None, fp8=False, steps=8, batch=8, seq=32):
    trainer = _make_trainer(arch, policy=policy, fp8=fp8, steps=steps,
                            batch=batch, seq=seq)
    t0 = time.perf_counter()
    trainer.run(num_steps=1)  # compile + warm (the jitted step is cached)
    first_run = time.perf_counter() - t0
    t0 = time.perf_counter()
    result = trainer.run(num_steps=steps)
    wall = time.perf_counter() - t0
    per_step = wall / steps
    cost = _step_cost(trainer)
    n_dev = max(len(jax.devices()), 1)
    mfu = estimate_mfu(cost.get("flops"), per_step, num_devices=n_dev)
    return {
        # Warm, steady-state step time: the trainer's engine-cached jit means
        # the step compiles exactly once per process (incl. resume), so this
        # — not the compile-inflated first run — is what repeats at scale.
        "step_us": per_step * 1e6,
        "first_run_us_incl_compile": first_run * 1e6,
        "tokens_per_s": batch * seq / per_step,
        "tokens_per_s_per_device": batch * seq / per_step / n_dev,
        "step_flops": cost.get("flops"),
        # Achieved/peak model FLOP/s on THIS backend (CPU here: tracks
        # relative movement, not an accelerator-meaningful absolute).
        "mfu": mfu,
        "num_params": int(result["num_params"]),
        "peak_hbm_proxy_bytes": cost["peak_hbm_proxy_bytes"],
        "final_loss": float(result["final"]["loss"]),
    }


def _memopt_bench(rows):
    """Memory-frugal training ablation (README "Memory-frugal training").

    One arch at depth MEMOPT_DEPTH, fixed global batch/seq, MEMOPT_STEPS
    steps per config. Tracked columns per config: exact optimizer state
    bytes (``train/opt_state_bytes`` accounting), XLA peak-HBM proxy of the
    compiled step, and 60-step final loss vs the fp32 adamw baseline. The
    memory ratios are backend-independent (dtype/shape arithmetic); the
    loss-parity column is the numerics signal.
    """
    out = {"arch": MEMOPT_ARCH, "depth": MEMOPT_DEPTH, "steps": MEMOPT_STEPS,
           "configs": {}}
    base = None
    for name, mod, lr in MEMOPT_CONFIGS:
        trainer = _make_trainer(
            MEMOPT_ARCH, memopt=mod, depth=MEMOPT_DEPTH, lr=lr or 1e-3,
            steps=MEMOPT_STEPS, batch=8, seq=64)
        trainer.run(num_steps=1)  # compile + warm
        t0 = time.perf_counter()
        result = trainer.run(num_steps=MEMOPT_STEPS)
        per_step = (time.perf_counter() - t0) / MEMOPT_STEPS
        cost = _step_cost(trainer)
        entry = {
            "opt_state_bytes": int(result["opt_state_bytes"]),
            "peak_hbm_proxy_bytes": cost["peak_hbm_proxy_bytes"],
            "final_loss": float(result["final"]["loss"]),
            "step_us": per_step * 1e6,
        }
        if base is None:
            base = entry
        else:
            entry["opt_bytes_ratio_vs_adamw"] = (
                base["opt_state_bytes"] / max(entry["opt_state_bytes"], 1))
            entry["hbm_ratio_vs_adamw"] = (
                entry["peak_hbm_proxy_bytes"]
                / max(base["peak_hbm_proxy_bytes"], 1))
            entry["loss_rel_diff_vs_adamw"] = (
                abs(entry["final_loss"] - base["final_loss"])
                / max(abs(base["final_loss"]), 1e-9))
        out["configs"][name] = entry
        detail = (f"opt_bytes={entry['opt_state_bytes']};"
                  f"peak_hbm_proxy={entry['peak_hbm_proxy_bytes']};"
                  f"loss={entry['final_loss']:.4f}")
        if base is not entry:
            detail += (f";opt_shrink={entry['opt_bytes_ratio_vs_adamw']:.1f}x;"
                       f"loss_rel_diff={entry['loss_rel_diff_vs_adamw']:.4f}")
        rows.append((f"train_memopt/{name}", entry["step_us"], detail))
    return out


def _fleet_bench(*, world=2, steps=6):
    """Elastic multi-process goodput: a real 2-worker fleet (subprocess
    workers, file-backed collectives) trained to completion; reports the
    aggregated fleet goodput. Returns None when the elastic path cannot run
    here (e.g. no subprocess spawning) — the fleet fields then simply do
    not appear in BENCH_train.json."""
    import shutil
    import tempfile

    from repro.runtime.supervisor import FleetSupervisor

    wd = tempfile.mkdtemp(prefix="bench_fleet_")
    try:
        sup = FleetSupervisor(
            wd, schedule=(world,), steps=steps, grad_microbatches=world,
            builder_kwargs={"steps": steps, "checkpoint_every_n": steps})
        res = sup.run()
        g = res["goodput"]
        return {
            "world_size": world,
            "steps": steps,
            "fleet_goodput_fraction": g["fleet_goodput_fraction"],
            "fleet_steady_goodput_fraction":
                g["fleet_steady_goodput_fraction"],
            "rank_seconds": g["rank_seconds"],
            "productive_s": g["productive_s"],
        }
    except Exception:  # noqa: BLE001 — elastic path unavailable: omit fields
        return None
    finally:
        shutil.rmtree(wd, ignore_errors=True)


def run():
    global LAST_JSON
    rows = []
    archs_json = {}
    for arch in BENCH_ARCHS:
        fp32 = _train_bench(arch)
        archs_json[arch] = {"fp32": fp32}
        mfu_str = (f"{fp32['mfu']:.4f}" if fp32["mfu"] is not None
                   else "n/a")
        rows.append((f"train_step/{arch}", fp32["step_us"],
                     f"tokens_per_s={fp32['tokens_per_s']:.0f};"
                     f"mfu={mfu_str};"
                     f"peak_hbm_proxy={fp32['peak_hbm_proxy_bytes']};"
                     f"params={fp32['num_params']}"))
        if arch in BF16_ARCHS:
            from repro.layers.base import bf16_policy

            bf16 = _train_bench(arch, policy=bf16_policy())
            loss_rel = abs(bf16["final_loss"] - fp32["final_loss"]) / \
                max(abs(fp32["final_loss"]), 1e-9)
            bf16["loss_rel_diff_vs_fp32"] = loss_rel
            bf16["step_speedup_vs_fp32"] = fp32["step_us"] / bf16["step_us"]
            bf16["hbm_ratio_vs_fp32"] = (bf16["peak_hbm_proxy_bytes"]
                                         / max(fp32["peak_hbm_proxy_bytes"], 1))
            if jax.default_backend() == "cpu":
                # The loss-parity number is the tracked signal here: this
                # container's CPU backend EMULATES bf16 (upcasts every op),
                # so wall-clock/bytes do not reflect accelerator behaviour.
                bf16["note"] = ("cpu backend emulates bf16; speedup/HBM "
                                "ratios are not meaningful off-accelerator")
            archs_json[arch]["bf16"] = bf16
            rows.append((f"train_step_bf16/{arch}", bf16["step_us"],
                         f"speedup={bf16['step_speedup_vs_fp32']:.2f}x;"
                         f"hbm_ratio={bf16['hbm_ratio_vs_fp32']:.2f};"
                         f"loss_rel_diff={loss_rel:.4f}"))
    # Roofline-bound step times from dry-run records (paper-size models).
    roofline = {}
    for path in sorted(glob.glob("experiments/dryrun/*__train_4k__single.json")):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        r = rec["roofline"]
        bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        mfu_bound = r["model_flops_global"] / (
            rec["chips"] * 197e12 * bound_s) if bound_s else 0
        roofline[rec["arch"]] = {"bound_us": bound_s * 1e6,
                                 "dominant": r["dominant"],
                                 "mfu_bound": mfu_bound}
        rows.append((f"train_roofline_bound/{rec['arch']}", bound_s * 1e6,
                     f"dominant={r['dominant']};mfu_bound={mfu_bound:.3f}"))
    # fp8 delayed-scaling parity: bf16 policy vs bf16 + fp8 boundaries on
    # one arch over a longer horizon (amax histories need steps to settle).
    # Loss parity is the tracked signal — CPU emulates the fp8 casts, so
    # only numerics (not wall-clock) are meaningful here.
    from repro.layers.base import bf16_policy

    base = _train_bench(FP8_PARITY_ARCH, policy=bf16_policy(),
                        steps=FP8_PARITY_STEPS)
    fp8 = _train_bench(FP8_PARITY_ARCH, policy=bf16_policy(), fp8=True,
                       steps=FP8_PARITY_STEPS)
    loss_rel = abs(fp8["final_loss"] - base["final_loss"]) / \
        max(abs(base["final_loss"]), 1e-9)
    fp8_json = {
        "arch": FP8_PARITY_ARCH,
        "steps": FP8_PARITY_STEPS,
        "bf16_final_loss": base["final_loss"],
        "fp8_final_loss": fp8["final_loss"],
        "loss_rel_diff_vs_bf16": loss_rel,
        "step_us_bf16": base["step_us"],
        "step_us_fp8": fp8["step_us"],
    }
    rows.append((f"train_fp8_parity/{FP8_PARITY_ARCH}", fp8["step_us"],
                 f"steps={FP8_PARITY_STEPS};"
                 f"loss_rel_diff_vs_bf16={loss_rel:.4f}"))
    memopt_json = _memopt_bench(rows)
    LAST_JSON = {"archs": archs_json, "roofline": roofline,
                 "fp8_train_parity": fp8_json, "memopt": memopt_json}
    fleet = _fleet_bench()
    if fleet is not None:  # fleet fields only when the elastic path ran
        LAST_JSON["fleet"] = fleet
        rows.append((
            "train_fleet_goodput", fleet["rank_seconds"] * 1e6,
            f"world={fleet['world_size']};"
            f"goodput={fleet['fleet_goodput_fraction']:.3f};"
            f"steady={fleet['fleet_steady_goodput_fraction']:.3f}"))
    return rows
