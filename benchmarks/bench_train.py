"""Paper Table 3: training performance.

Wall-clock MFU on real accelerators is out of scope for this CPU container;
this benchmark reports (a) measured CPU step time + tokens/s on the reduced
per-family models (regression tracking across the whole substrate: data ->
model -> grads -> optimizer), and (b) the roofline-derived step-time bound
for the paper-size models from the AOT dry-run records when available
(EXPERIMENTS.md §Roofline holds the full table).
"""

import glob
import json
import os
import time

import jax

from repro.configs import registry
from repro.core.config import config_for_function
from repro.trainer import optimizers as opt_lib
from repro.trainer.trainer import SpmdTrainer

BENCH_ARCHS = ["qwen2-1.5b", "mixtral-8x7b", "jamba-1.5-large-398b",
               "rwkv6-7b", "hubert-xlarge"]


def _step_time(arch, steps=8, batch=8, seq=32):
    spec = registry.get_spec(arch)
    model_cfg = spec.make_smoke()
    cfg = SpmdTrainer.default_config().set(
        name="t", model=model_cfg, max_steps=steps, log_every_n=steps)
    task = {"audio": "audio", "vlm": "vlm"}.get(spec.modality, "lm")
    cfg.input.set(task=task, vocab_size=model_cfg.decoder.vocab_size,
                  seq_len=seq, global_batch_size=batch,
                  model_dim=model_cfg.decoder.dim, num_patches=4)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(peak_lr=1e-3)
    trainer = cfg.instantiate()
    t0 = time.perf_counter()
    result = trainer.run()
    wall = time.perf_counter() - t0
    per_step = wall / steps
    return per_step, batch * seq / per_step, result["num_params"]


def run():
    rows = []
    for arch in BENCH_ARCHS:
        per_step, tok_s, n_params = _step_time(arch)
        rows.append((f"train_step/{arch}", per_step * 1e6,
                     f"tokens_per_s={tok_s:.0f};params={n_params}"))
    # Roofline-bound step times from dry-run records (paper-size models).
    for path in sorted(glob.glob("experiments/dryrun/*__train_4k__single.json")):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok" or "roofline" not in rec:
            continue
        r = rec["roofline"]
        bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        mfu_bound = r["model_flops_global"] / (
            rec["chips"] * 197e12 * bound_s) if bound_s else 0
        rows.append((f"train_roofline_bound/{rec['arch']}", bound_s * 1e6,
                     f"dominant={r['dominant']};mfu_bound={mfu_bound:.3f}"))
    return rows
