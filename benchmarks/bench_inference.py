"""Paper Table 4 + Figure 5: inference latency (TTFT/TPOT) and throughput.

vLLM is not available in this container; we measure OUR engine's metrics on
reduced models across families — same metric definitions as the paper (TTFT:
prompt -> first token; TPOT: mean per-token decode latency; throughput:
output tokens/s in the batched setting) — plus continuous-batching overhead
vs plain batched generation.
"""

import time

import jax
import numpy as np

from repro.configs import registry
from repro.inference.engine import InferenceEngine, Request

BENCH_ARCHS = ["qwen2-1.5b", "mixtral-8x7b", "rwkv6-7b", "gemma2-27b"]


def _engine(arch, max_len=64, slots=4):
    spec = registry.get_spec(arch)
    cfg = spec.make_smoke()
    engine = InferenceEngine.default_config().set(
        name="engine", model=cfg, max_len=max_len, slots=slots).instantiate()
    params = engine.model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    engine.load(params)
    return engine, cfg.decoder.vocab_size


def run():
    rows = []
    rng = np.random.default_rng(0)
    for arch in BENCH_ARCHS:
        engine, vocab = _engine(arch)
        prompts = rng.integers(0, vocab, size=(4, 16))
        # Warm-up compile, then measure.
        engine.generate(prompts, max_new_tokens=2)
        tokens, m = engine.generate(prompts, max_new_tokens=16)
        rows.append((f"ttft/{arch}", m["ttft_s"] * 1e6, "batched prefill B=4 S=16"))
        rows.append((f"tpot/{arch}", m["tpot_s"] * 1e6,
                     f"throughput_tok_s={m['throughput_tok_s']:.0f}"))
        # Continuous batching: mixed lengths through slot scheduler.
        reqs = [Request(request_id=i, prompt=prompts[i % 4],
                        max_new_tokens=int(rng.integers(4, 12)))
                for i in range(6)]
        t0 = time.perf_counter()
        results = engine.serve(reqs)
        wall = time.perf_counter() - t0
        total_tokens = sum(len(r.tokens) for r in results)
        rows.append((f"continuous_batching/{arch}", wall / total_tokens * 1e6,
                     f"requests={len(reqs)};slots=4;tokens={total_tokens}"))
    return rows
