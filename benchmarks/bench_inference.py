"""Paper Table 4 + Figure 5: inference latency (TTFT/TPOT) and throughput.

vLLM is not available in this container; we measure OUR engine's metrics on
reduced models across families — same metric definitions as the paper (TTFT:
prompt -> first token; TPOT: mean per-token decode latency; throughput:
output tokens/s in the batched setting) — plus continuous-batching overhead
vs plain batched generation.

Both paths are warmed up before timing (jit compilation used to dominate the
continuous-batching row), so the numbers are steady-state serving latencies.
``run()`` additionally stashes a structured per-arch payload in ``LAST_JSON``
which ``benchmarks/run.py`` writes to ``BENCH_inference.json`` — the tracked
perf-trajectory artifact (TPOT and continuous-batching µs/token are the
regression metrics for the decode fast path).
"""

import time

import jax
import numpy as np

from repro.configs import registry
from repro.inference.engine import InferenceEngine, Request

BENCH_ARCHS = ["qwen2-1.5b", "mixtral-8x7b", "rwkv6-7b", "gemma2-27b"]

# Structured results from the last run(); run.py persists this as
# BENCH_inference.json.
LAST_JSON = None


def _engine(arch, max_len=64, slots=4):
    spec = registry.get_spec(arch)
    cfg = spec.make_smoke()
    engine = InferenceEngine.default_config().set(
        name="engine", model=cfg, max_len=max_len, slots=slots).instantiate()
    params = engine.model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    engine.load(params)
    return engine, cfg.decoder.vocab_size


def _mk_requests(rng, prompts, n=6):
    return [Request(request_id=i, prompt=prompts[i % len(prompts)],
                    max_new_tokens=int(rng.integers(4, 12)))
            for i in range(n)]


def run():
    global LAST_JSON
    rows = []
    payload = {}
    rng = np.random.default_rng(0)
    for arch in BENCH_ARCHS:
        engine, vocab = _engine(arch)
        prompts = rng.integers(0, vocab, size=(4, 16))
        # Warm-up: compiles prefill + the scan decode loop (jitted callables
        # are cached on the engine, so the measured call reuses them).
        engine.generate(prompts, max_new_tokens=16)
        tokens, m = engine.generate(prompts, max_new_tokens=16)
        rows.append((f"ttft/{arch}", m["ttft_s"] * 1e6, "batched prefill B=4 S=16"))
        rows.append((f"tpot/{arch}", m["tpot_s"] * 1e6,
                     f"throughput_tok_s={m['throughput_tok_s']:.0f}"))
        # Continuous batching: mixed lengths through the slot scheduler.
        # Warm-up serve compiles the bucketed admit_fn + fused decode step;
        # the timed pass measures steady-state scheduling, not compilation.
        engine.serve(_mk_requests(np.random.default_rng(1), prompts))
        reqs = _mk_requests(rng, prompts)
        t0 = time.perf_counter()
        results = engine.serve(reqs)
        wall = time.perf_counter() - t0
        total_tokens = sum(len(r.tokens) for r in results)
        cb_us = wall / total_tokens * 1e6
        rows.append((f"continuous_batching/{arch}", cb_us,
                     f"requests={len(reqs)};slots=4;tokens={total_tokens}"))
        payload[arch] = {
            "ttft_us": m["ttft_s"] * 1e6,
            "tpot_us": m["tpot_s"] * 1e6,
            "throughput_tok_s": m["throughput_tok_s"],
            "continuous_batching_us_per_token": cb_us,
            "continuous_batching_tokens": total_tokens,
        }
    LAST_JSON = payload
    return rows
