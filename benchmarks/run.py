"""Benchmark harness: one module per paper table/figure.

  bench_loc        — Table 2 (LoC-complexity of RoPE/MoE integration)
  bench_kernels    — kernel registry: per-op per-backend parity vs ref +
                     memoized dispatch overhead (<1µs budget)
  bench_train      — Table 3 (training step time / roofline bounds)
  bench_checkpoint — §5–§6: save/restore latency, training-thread stall per
                     async save, goodput under injected preemptions
  bench_inference  — Table 4 + Fig 5 (TTFT / TPOT / throughput / cont. batching)
  bench_serving    — serving load: Poisson arrivals through the paged
                     gateway (p50/p99 TTFT/TPOT, tokens/s, preemptions)
  bench_scaling    — Fig 4 (single-pod vs multi-pod scaling from dry-runs)
  bench_observability — metrics/span per-call cost + step-time delta with
                     full observability on vs off (the <1% budget)

Prints ``name,us_per_call,derived`` CSV. Modules may expose a ``LAST_JSON``
dict after ``run()``; it is persisted as ``BENCH_<suffix>.json`` next to the
CWD so the perf trajectory (e.g. decode TPOT) is tracked across PRs.
"""

import json
import sys
import traceback


def main() -> None:
    from benchmarks import (
        bench_checkpoint,
        bench_inference,
        bench_kernels,
        bench_loc,
        bench_observability,
        bench_scaling,
        bench_serving,
        bench_train,
    )

    print("name,us_per_call,derived")
    for mod in (bench_loc, bench_kernels, bench_train, bench_checkpoint,
                bench_inference, bench_serving, bench_scaling,
                bench_observability):
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            rows = [(f"{mod.__name__}/ERROR", -1, str(e)[:80])]
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived}")
            sys.stdout.flush()
        payload = getattr(mod, "LAST_JSON", None)
        if payload is not None:
            suffix = mod.__name__.rsplit("bench_", 1)[-1]
            path = f"BENCH_{suffix}.json"
            with open(path, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
            print(f"# wrote {path}", file=sys.stderr)


if __name__ == "__main__":
    main()
