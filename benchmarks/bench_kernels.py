"""Kernel registry benchmark: per-op per-backend numerical parity vs the ref
oracle, plus dispatch overhead.

Two guarantees tracked across PRs via ``BENCH_kernels.json``:

  * parity — for every op, every backend eligible on this platform (Pallas
    runs interpreted off-TPU) matches the ``ref`` oracle (max abs error);
  * dispatch — a cached ``resolve()`` is <1µs amortized, so the registry
    adds nothing to trace time on the decode/train hot paths (resolution
    never happens inside compiled code at all).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref
from repro.kernels import registry as reg
from repro.kernels.registry import KernelConfig, KernelFeatures

# Structured results from the last run(); run.py persists this as
# BENCH_kernels.json.
LAST_JSON = None

# Interpret-mode Pallas is slow; keep parity shapes small.
_B, _S, _T, _H, _HKV, _D = 2, 64, 64, 4, 2, 16


def _max_err(a, b):
    return float(np.max(np.abs(np.asarray(a, np.float32)
                               - np.asarray(b, np.float32))))


def _attention_inputs(decode=False):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    Sq = 1 if decode else _S
    q = jax.random.normal(ks[0], (_B, Sq, _H, _D))
    k = jax.random.normal(ks[1], (_B, _T, _HKV, _D))
    v = jax.random.normal(ks[2], (_B, _T, _HKV, _D))
    if decode:
        k_pos = jnp.broadcast_to(jnp.arange(_T), (_B, _T))
        q_pos = jnp.full((_B, 1), _T)
        return q, k, v, q_pos, k_pos
    return q, k, v, None, None


def _parity_cases():
    """(op, backend, fn(kernel_cfg) -> (out, expect)) for every non-ref
    backend of every op; Pallas backends run as pallas:interpret off-TPU."""
    cases = []

    q, k, v, _, _ = _attention_inputs()
    fwd_expect = ref.reference_attention(q, k, v)
    # The backend choice is carried by the KernelConfig that run() builds
    # (op_overrides={op: backend}); the lambda only threads it through.
    for backend in ("blockwise", "pallas"):
        cases.append(("attention.fwd", backend, lambda kc: (
            ops.flash_attention(q, k, v, kernel=kc), fwd_expect)))

    qd, kd, vd, q_pos, k_pos = _attention_inputs(decode=True)
    dec_expect = ref.reference_attention(qd, kd, vd, q_positions=q_pos,
                                         k_positions=k_pos)
    cases.append(("attention.decode", "pallas", lambda kc: (
        ops.decode_attention(qd, kd, vd, q_positions=q_pos,
                             k_positions=k_pos, kernel=kc), dec_expect)))

    x = jax.random.normal(jax.random.PRNGKey(1), (_B, _S, 64))
    scale = jax.random.normal(jax.random.PRNGKey(2), (64,))
    rms_expect = ref.reference_rmsnorm(x, scale)
    cases.append(("rmsnorm", "pallas", lambda kc: (
        ops.rmsnorm(x, scale, kernel=kc), rms_expect)))

    ksplit = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ksplit[0], (_B, _S, 2, 8))
    kk = jax.random.normal(ksplit[1], (_B, _S, 2, 8))
    vv = jax.random.normal(ksplit[2], (_B, _S, 2, 8))
    w = jax.random.uniform(ksplit[3], (_B, _S, 2, 8), minval=0.6, maxval=0.99)
    u = jax.random.normal(ksplit[4], (2, 8)) * 0.5
    wkv_expect, _ = ref.reference_wkv6(r, kk, vv, w, u, chunk_size=16)
    cases.append(("wkv6", "pallas", lambda kc: (
        ops.wkv6(r, kk, vv, w, u, kernel=kc)[0], wkv_expect)))
    return cases


def _dispatch_overhead_us(n=20000):
    """Amortized cost of one memoized resolve() on the hot feature set."""
    feats = KernelFeatures(platform=reg.current_platform())
    reg.resolve("attention.decode", feats)  # populate
    t0 = time.perf_counter()
    for _ in range(n):
        reg.resolve("attention.decode", feats)
    return (time.perf_counter() - t0) / n * 1e6


def run():
    global LAST_JSON
    rows = []
    payload = {"parity": {}, "dispatch": {}}
    on_tpu = reg.current_platform() == "tpu"

    for op, backend, fn in _parity_cases():
        # Off-TPU the pallas backends execute through the interpreter —
        # the same block decomposition Mosaic runs, at validation speed.
        kc = KernelConfig().set(
            op_overrides={op: backend}, interpret=(not on_tpu
                                                   and backend == "pallas"),
            blockwise_chunk_size=16, wkv_chunk_size=16)
        t0 = time.perf_counter()
        out, expect = fn(kc)
        out.block_until_ready()
        wall_us = (time.perf_counter() - t0) * 1e6
        err = _max_err(out, expect)
        resolved = kc.backend_for(op)
        rows.append((f"kernels/parity/{op}/{resolved}", wall_us,
                     f"max_abs_err={err:.2e}"))
        payload["parity"].setdefault(op, {})[resolved] = {
            "max_abs_err": err, "ok": bool(err < 5e-4)}

    us = _dispatch_overhead_us()
    rows.append(("kernels/dispatch/cached_resolve", us,
                 f"amortized over 20k resolves; budget 1.0us"))
    stats = reg.dispatch_cache_stats()
    payload["dispatch"] = {
        "cached_resolve_us": us,
        "under_1us": bool(us < 1.0),
        "cache_hits": stats["hits"],
        "cache_entries": stats["size"],
    }
    payload["platform"] = reg.current_platform()
    payload["ops"] = {op: reg.registered_backends(op)
                      for op in reg.registered_ops()}
    LAST_JSON = payload
    return rows
