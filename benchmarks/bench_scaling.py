"""Paper Figure 4: scaling study.

Reads the AOT dry-run records for single-pod (256 chips) and multi-pod
(512 chips) meshes and reports the roofline-model scaling efficiency per
architecture: with the global batch fixed (assignment shapes), going
single -> multi is a strong-scaling step; the roofline bound per chip should
ideally halve. Efficiency = bound(single) / (2 * bound(multi)).

(The paper's Fig. 4 is weak scaling on real TPUs; this is the dry-run
counterpart the container supports — the full per-arch tables live in
EXPERIMENTS.md.)
"""

import glob
import json
import os


def _load(arch, shape, mesh):
    path = f"experiments/dryrun/{arch}__{shape}__{mesh}.json"
    if not os.path.exists(path):
        return None
    with open(path) as f:
        rec = json.load(f)
    return rec if rec.get("status") == "ok" else None


def run():
    rows = []
    for path in sorted(glob.glob("experiments/dryrun/*__train_4k__single.json")):
        arch = os.path.basename(path).split("__")[0]
        single = _load(arch, "train_4k", "single")
        multi = _load(arch, "train_4k", "multi")
        if not single or "roofline" not in single:
            continue
        r = single["roofline"]
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        detail = f"dominant={r['dominant']}"
        if multi:
            m_fits = multi["memory"]["fits"]
            s_fits = single["memory"]["fits"]
            detail += f";fits_256={s_fits};fits_512={m_fits}"
            detail += (f";mem_512_over_256="
                       f"{multi['memory']['peak_per_device'] / max(single['memory']['peak_per_device'], 1):.2f}")
        rows.append((f"scaling/{arch}", bound * 1e6, detail))
    if not rows:
        rows.append(("scaling/no_dryrun_records", 0,
                     "run repro.launch.dryrun first"))
    return rows
