"""Observability overhead: instrument cost and end-to-end step-time delta.

The observability subsystem's contract is "free when off, ~free when on":
hot-path record calls are dict updates (no I/O — sinks see data at flush),
spans are two clock reads and a list append, and ALL instrumentation lives
outside jit (the compile-count tests prove zero added retraces). This
benchmark pins the numbers:

  * per-call cost of ``Counter.inc`` / ``Gauge.set`` / ``Histogram.record``
    (at reservoir steady state) / ``Tracer.span`` / ``add_span``,
  * the end-to-end warm step-time delta of the SAME tiny trainer run with
    observability off vs fully on (metrics JSONL + trace + MFU gauges) —
    the <1% budget the issue sets (the tests enforce it as an absolute
    per-log-step bound; this reports the A/B delta exactly).

``run.py`` persists ``LAST_JSON`` as ``BENCH_observability.json``.
"""

import os
import tempfile
import time

from repro.core.config import config_for_function

LAST_JSON = None


def _per_call_ns(fn, n=200_000):
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e9


def _instrument_costs():
    from repro.observability.metrics import MetricsRegistry
    from repro.observability.tracing import Tracer

    reg = MetricsRegistry()
    c = reg.counter("bench/c")
    g = reg.gauge("bench/g")
    h = reg.histogram("bench/h")
    for i in range(2048):  # past reservoir capacity: steady-state record
        h.record(float(i))
    tracer = Tracer(pid=0)

    def span():
        with tracer.span("s"):
            pass

    out = {
        "counter_inc_ns": _per_call_ns(lambda: c.inc()),
        "gauge_set_ns": _per_call_ns(lambda: g.set(1.0)),
        "histogram_record_ns": _per_call_ns(lambda: h.record(0.5)),
        "tracer_span_ns": _per_call_ns(span, n=50_000),
        "tracer_add_span_ns": _per_call_ns(
            lambda: tracer.add_span("s", 0.0, 1.0), n=50_000),
    }
    tracer.events.clear()
    return out


def _tiny_trainer(*, observability, steps=12):
    from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
    from repro.trainer import optimizers as opt_lib
    from repro.trainer.trainer import SpmdTrainer

    dim = 32
    layer = TransformerLayer.default_config().set(input_dim=dim)
    layer.self_attention.set(num_heads=4, num_kv_heads=2)
    layer.feed_forward.set(hidden_dim=2 * dim)
    model = CausalLM.default_config().set(
        decoder=Decoder.default_config().set(
            vocab_size=32, dim=dim,
            stack=Repeat.default_config().set(layer=layer, num_layers=2,
                                              remat_policy=None)))
    cfg = SpmdTrainer.default_config().set(
        name="bench_obs", model=model, max_steps=steps, log_every_n=1,
        observability=observability)
    cfg.input.set(task="lm", vocab_size=32, seq_len=16, global_batch_size=8)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=1e-2)
    return cfg.instantiate()


def _step_time(trainer, steps):
    """Median warm-step time, measured per-step INSIDE one run via a
    ``step_hook`` timestamp at each step boundary. One-time costs —
    compile (before the first boundary), the end-of-run trace save (after
    the last) — cannot smear into the per-step number, and the median
    shrugs off GC/timer spikes that a mean amortizes in."""
    ts = []
    trainer.run(num_steps=steps,
                step_hook=lambda **kw: ts.append(time.perf_counter()))
    deltas = sorted(b - a for a, b in zip(ts, ts[1:]))
    return deltas[len(deltas) // 2]


def _step_delta(steps=24):
    from repro.observability.runtime import ObservabilityConfig

    with tempfile.TemporaryDirectory(prefix="bench_obs_") as tmp:
        obs_cfg = ObservabilityConfig(
            metrics_path=os.path.join(tmp, "metrics.jsonl"),
            trace_path=os.path.join(tmp, "trace.json"))
        # Interleave off/on pairs so drift (thermal, page cache) hits both.
        off_s, on_s = [], []
        for _ in range(3):
            off_s.append(_step_time(
                _tiny_trainer(observability=None, steps=steps), steps))
            on_s.append(_step_time(
                _tiny_trainer(observability=obs_cfg, steps=steps), steps))
        off, on = min(off_s), min(on_s)
        return {
            "step_us_observability_off": off * 1e6,
            "step_us_observability_on": on * 1e6,
            "step_time_delta_frac": (on - off) / off,
        }


def run():
    global LAST_JSON
    costs = _instrument_costs()
    delta = _step_delta()
    LAST_JSON = {**costs, **delta}
    return [
        ("obs_counter_inc", costs["counter_inc_ns"] / 1e3, "per-call"),
        ("obs_gauge_set", costs["gauge_set_ns"] / 1e3, "per-call"),
        ("obs_histogram_record", costs["histogram_record_ns"] / 1e3,
         "per-call (reservoir steady state)"),
        ("obs_tracer_span", costs["tracer_span_ns"] / 1e3, "per-call"),
        ("obs_step_overhead", delta["step_us_observability_on"]
         - delta["step_us_observability_off"],
         f"delta_frac={delta['step_time_delta_frac']:+.4f}"),
    ]
