"""Serving load benchmark: Poisson arrivals through the paged gateway.

Measures the serving subsystem end to end (paper §6 at serving
granularity): requests with mixed prompt lengths arrive as a Poisson
process at the :class:`ServingGateway`, which chunks prefills, pages KV,
and preempts under pressure. Reported per arch:

  * p50/p99 TTFT (submit -> first streamed token) and TPOT,
  * output tokens/s over the loaded window,
  * preemption/restore counts and peak KV-page utilization.

Both a warm-up pass (compilation) and the timed pass run the same
workload shape, so the numbers are steady-state scheduling + decode, not
jit. ``run()`` stashes the payload in ``LAST_JSON``; ``benchmarks/run.py``
persists it as ``BENCH_serving.json`` — the tracked perf artifact for the
serving path.
"""

import time

import jax
import numpy as np

from repro.configs import registry
from repro.core.config import visit_config
from repro.inference.engine import InferenceEngine
from repro.serving import SamplingParams, ServingGateway

BENCH_ARCHS = ["qwen2-1.5b", "gemma2-27b"]

N_REQUESTS = 12
MEAN_INTERARRIVAL_S = 0.02  # Poisson arrival rate ~50 req/s
PAGE_SIZE = 8
SLOTS = 6

LAST_JSON = None


def _paged_engine(arch, max_len=64, slots=SLOTS):
    """Registry smoke model with the paged-KV serving config: half the
    dense engine's full-residency pages, so the load exercises paging."""
    spec = registry.get_spec(arch)
    cfg = spec.make_smoke()
    n_logical = -(-max_len // PAGE_SIZE)
    num_pages = 1 + slots * n_logical // 2

    def to_paged(_, c):
        if getattr(c, "kv_cache_layout", None) == "dense" \
                and getattr(c, "sliding_window", None) is None:
            c.set(kv_cache_layout="paged", page_size=PAGE_SIZE,
                  num_pages=num_pages)

    visit_config(cfg, to_paged)
    engine = InferenceEngine.default_config().set(
        name="engine", model=cfg, max_len=max_len, slots=slots).instantiate()
    params = engine.model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    engine.load(params)
    return engine, cfg.decoder.vocab_size


def _drive(engine, vocab, seed):
    """One Poisson-arrival workload through a fresh gateway."""
    rng = np.random.default_rng(seed)
    gw = ServingGateway(engine, prefill_chunk=8, seed=seed)
    arrivals = np.cumsum(rng.exponential(MEAN_INTERARRIVAL_S, N_REQUESTS))
    prompts = [rng.integers(0, vocab, size=(int(rng.integers(3, 33)),))
               for _ in range(N_REQUESTS)]
    samplings = [SamplingParams(max_new_tokens=int(rng.integers(4, 12)),
                                temperature=0.8 * (i % 3 == 0))
                 for i in range(N_REQUESTS)]
    t0 = time.perf_counter()
    pending = list(range(N_REQUESTS))
    peak_util = 0.0
    while pending or gw.scheduler.has_work:
        now = time.perf_counter() - t0
        while pending and arrivals[pending[0]] <= now:
            i = pending.pop(0)
            gw.submit(prompts[i], sampling=samplings[i],
                      priority=int(i % 2))
        if gw.scheduler.has_work:
            gw.step()
        peak_util = max(peak_util, gw.scheduler.block_utilization)
    return gw, peak_util


def run():
    global LAST_JSON
    rows = []
    payload = {}
    for arch in BENCH_ARCHS:
        engine, vocab = _paged_engine(arch)
        _drive(engine, vocab, seed=1)  # warm-up: compiles chunk/decode fns
        gw, peak_util = _drive(engine, vocab, seed=2)
        m = gw.metrics()
        rows.append((f"serving_ttft_p50/{arch}", m["ttft_p50_s"] * 1e6,
                     f"p99_us={m['ttft_p99_s'] * 1e6:.0f}"))
        rows.append((f"serving_tpot_p50/{arch}", m["tpot_p50_s"] * 1e6,
                     f"p99_us={m['tpot_p99_s'] * 1e6:.0f}"))
        rows.append((f"serving_throughput/{arch}", m["tokens_per_s"],
                     f"preemptions={m['preemptions']};"
                     f"peak_block_util={peak_util:.2f}"))
        payload[arch] = {
            "ttft_p50_us": m["ttft_p50_s"] * 1e6,
            "ttft_p99_us": m["ttft_p99_s"] * 1e6,
            "tpot_p50_us": m["tpot_p50_s"] * 1e6,
            "tpot_p99_us": m["tpot_p99_s"] * 1e6,
            "tokens_per_s": m["tokens_per_s"],
            "completed": m["completed"],
            "preemptions": m["preemptions"],
            "restores": m["restores"],
            "peak_block_utilization": peak_util,
            "requests": N_REQUESTS,
            "slots": SLOTS,
            "page_size": PAGE_SIZE,
        }
    LAST_JSON = payload
    return rows
