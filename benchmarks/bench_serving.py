"""Serving load benchmark: Poisson arrivals through the paged gateway.

Measures the serving subsystem end to end (paper §6 at serving
granularity) at 10x the original load: 120 requests with a shared-system-
prompt mix arrive as a Poisson process at the :class:`ServingGateway`,
which chunks prefills, pages KV, shares prefix pages, drafts/verifies
speculative tokens, and preempts under pressure. Each arch runs the SAME
workload three times — a baseline gateway with both features off, a
prefix-cache-only gateway, and the full prefix+speculation gateway — an
ablation that attributes each win to its mechanism: the prefix cache cuts
TTFT (admission needs one chunk instead of five), while speculation cuts
TPOT / raises throughput (multiple tokens per dispatch). Reported per
arch:

  * p50/p99 TTFT and TPOT for every run, plus TTFT p50 restricted to
    prefix-hit-eligible requests (prompts starting with the shared system
    prompt) — the population the cache exists for; the headline
    ``prefix_hit_ttft_p50_speedup`` is prefix-only vs baseline,
  * output tokens/s over the loaded window,
  * ``prefix_hit_rate``, ``prefill_tokens_skipped``, ``drafted_tokens``,
    ``accepted_per_step``, preemption counts, peak KV-page utilization,
    and the post-drain leak check (``drain()`` raises on a nonzero page
    refcount).

Both a warm-up pass (compilation) and the timed passes run the same
workload shape, so the numbers are steady-state scheduling + decode, not
jit. ``run()`` stashes the payload in ``LAST_JSON``; ``benchmarks/run.py``
persists it as ``BENCH_serving.json`` — the tracked perf artifact for the
serving path.
"""

import gc
import time

import jax
import numpy as np

from repro.configs import registry
from repro.core.config import visit_config
from repro.inference.engine import InferenceEngine
from repro.serving import SamplingParams, ServingGateway

BENCH_ARCHS = ["qwen2-1.5b", "gemma2-27b"]

N_REQUESTS = 120  # 10x the original 12-request load
# ~20 req/s: above what the no-cache gateway can absorb (its backlog
# grows for the whole run) but within reach of the prefix-cached one —
# the regime the cache exists for, where skipped prefill is the
# difference between a growing queue and keeping up.
MEAN_INTERARRIVAL_S = 0.05
PAGE_SIZE = 8
SLOTS = 6
SYSTEM_PROMPT_LEN = 40  # 5 full pages of shareable prefix
SHARED_FRACTION = 0.75  # requests starting with the shared system prompt

LAST_JSON = None


def _paged_engine(arch, max_len=64, slots=SLOTS):
    """Registry smoke model with the paged-KV serving config: half the
    dense engine's full-residency pages, so the load exercises paging."""
    spec = registry.get_spec(arch)
    cfg = spec.make_smoke()
    n_logical = -(-max_len // PAGE_SIZE)
    num_pages = 1 + slots * n_logical // 2

    def to_paged(_, c):
        if getattr(c, "kv_cache_layout", None) == "dense" \
                and getattr(c, "sliding_window", None) is None:
            c.set(kv_cache_layout="paged", page_size=PAGE_SIZE,
                  num_pages=num_pages)

    visit_config(cfg, to_paged)
    engine = InferenceEngine.default_config().set(
        name="engine", model=cfg, max_len=max_len, slots=slots).instantiate()
    params = engine.model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    engine.load(params)
    return engine, cfg.decoder.vocab_size


def _workload(vocab, seed, n_requests):
    """Shared-system-prompt request mix: most requests are the system
    prompt plus a short unique tail (the millions-of-users shape), the
    rest fully distinct prompts. Every 3rd request samples (temperature
    0.8) so greedy/speculative and sampled rows batch together."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, vocab, size=(SYSTEM_PROMPT_LEN,))
    prompts, shared = [], []
    for i in range(n_requests):
        if rng.random() < SHARED_FRACTION:
            tail = rng.integers(0, vocab, size=(int(rng.integers(3, 9)),))
            prompts.append(np.concatenate([system, tail]))
            shared.append(True)
        else:
            prompts.append(rng.integers(0, vocab,
                                        size=(int(rng.integers(3, 33)),)))
            shared.append(False)
    samplings = [SamplingParams(max_new_tokens=int(rng.integers(4, 12)),
                                temperature=0.8 * (i % 3 == 0))
                 for i in range(n_requests)]
    arrivals = np.cumsum(rng.exponential(MEAN_INTERARRIVAL_S, n_requests))
    return prompts, shared, samplings, arrivals


def _drive(engine, vocab, seed, *, n_requests=N_REQUESTS,
           prefix_caching=True, spec_k=4):
    """One Poisson-arrival workload through a fresh gateway."""
    prompts, shared, samplings, arrivals = _workload(vocab, seed, n_requests)
    gw = ServingGateway(engine, prefill_chunk=8, seed=seed,
                        prefix_caching=prefix_caching, spec_k=spec_k)
    t0 = time.perf_counter()
    pending = list(range(n_requests))
    rids = [None] * n_requests
    peak_util = 0.0
    while pending or gw.scheduler.has_work:
        now = time.perf_counter() - t0
        while pending and arrivals[pending[0]] <= now:
            i = pending.pop(0)
            rids[i] = gw.submit(prompts[i], sampling=samplings[i],
                                priority=int(i % 2))
        if gw.scheduler.has_work:
            gw.step()
        peak_util = max(peak_util, gw.scheduler.block_utilization)
    gw.drain()  # raises on any leaked page reference
    # TTFT over the prefix-hit-eligible population (shared-prompt
    # requests), from per-request results.
    hit_ttfts = [gw.result(rid).ttft_s
                 for rid, is_shared in zip(rids, shared)
                 if is_shared and gw.result(rid) is not None
                 and not gw.result(rid).timed_out]
    hit_ttft_p50 = float(np.median(hit_ttfts)) if hit_ttfts else float("nan")
    return gw, peak_util, hit_ttft_p50


def run():
    global LAST_JSON
    rows = []
    payload = {}
    for arch in BENCH_ARCHS:
        engine, vocab = _paged_engine(arch)
        # Warm-up compiles every chunk bucket, the fused decode step, and
        # the verify step before anything is timed.
        _drive(engine, vocab, seed=1, n_requests=16)
        _drive(engine, vocab, seed=1, n_requests=16,
               prefix_caching=False, spec_k=0)

        def settle():
            # Decouple consecutive timed runs: drop garbage from the
            # previous gateway and give the host a beat so one run's CPU
            # burst cannot throttle the next (wall-clock TTFT under
            # Poisson arrivals is sensitive to iteration-rate drift).
            gc.collect()
            time.sleep(1.0)

        settle()
        base, base_util, base_hit_p50 = _drive(
            engine, vocab, seed=2, prefix_caching=False, spec_k=0)
        settle()
        pref, pref_util, pref_hit_p50 = _drive(
            engine, vocab, seed=2, spec_k=0)
        settle()
        full, full_util, full_hit_p50 = _drive(engine, vocab, seed=2)
        mb, mp, mf = base.metrics(), pref.metrics(), full.metrics()
        # The headline TTFT criterion isolates the prefix cache (the
        # mechanism that skips prefill work); the full run's speedup is
        # also recorded.
        speedup = base_hit_p50 / pref_hit_p50 if pref_hit_p50 > 0 else 0.0
        full_speedup = (base_hit_p50 / full_hit_p50
                        if full_hit_p50 > 0 else 0.0)
        rows.append((f"serving_ttft_p50/{arch}", mp["ttft_p50_s"] * 1e6,
                     f"baseline_us={mb['ttft_p50_s'] * 1e6:.0f};"
                     f"hit_speedup={speedup:.2f}x"))
        rows.append((f"serving_tpot_p50/{arch}", mf["tpot_p50_s"] * 1e6,
                     f"baseline_us={mb['tpot_p50_s'] * 1e6:.0f}"))
        rows.append((f"serving_throughput/{arch}", mf["tokens_per_s"],
                     f"baseline={mb['tokens_per_s']:.0f};"
                     f"prefix_hit_rate={mf['prefix_hit_rate']:.2f};"
                     f"accepted_per_step={mf['accepted_per_step']:.2f}"))

        def _run_payload(m, util, hit_p50):
            return {
                "ttft_p50_us": m["ttft_p50_s"] * 1e6,
                "ttft_p99_us": m["ttft_p99_s"] * 1e6,
                "ttft_p50_prefix_hit_us": hit_p50 * 1e6,
                "tpot_p50_us": m["tpot_p50_s"] * 1e6,
                "tpot_p99_us": m["tpot_p99_s"] * 1e6,
                "tokens_per_s": m["tokens_per_s"],
                "completed": m["completed"],
                "preemptions": m["preemptions"],
                "restores": m["restores"],
                "peak_block_utilization": util,
                "prefix_hit_rate": m["prefix_hit_rate"],
                "prefill_tokens_skipped": m["prefill_tokens_skipped"],
                "cow_forks": m["cow_forks"],
                "drafted_tokens": m["drafted_tokens"],
                "accepted_tokens": m["accepted_tokens"],
                "accepted_per_step": m["accepted_per_step"],
                "verify_steps": m["verify_steps"],
            }

        payload[arch] = {
            "baseline": _run_payload(mb, base_util, base_hit_p50),
            "prefix_only": _run_payload(mp, pref_util, pref_hit_p50),
            "prefix_spec": _run_payload(mf, full_util, full_hit_p50),
            "prefix_hit_ttft_p50_speedup": speedup,
            "prefix_spec_hit_ttft_p50_speedup": full_speedup,
            "requests": N_REQUESTS,
            "shared_fraction": SHARED_FRACTION,
            "system_prompt_len": SYSTEM_PROMPT_LEN,
            "slots": SLOTS,
            "page_size": PAGE_SIZE,
        }
    LAST_JSON = payload
    return rows
