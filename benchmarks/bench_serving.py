"""Serving load benchmark: Poisson arrivals through the paged gateway.

Measures the serving subsystem end to end (paper §6 at serving
granularity) at 10x the original load: 120 requests with a shared-system-
prompt mix arrive as a Poisson process at the :class:`ServingGateway`,
which chunks prefills, pages KV, shares prefix pages, drafts/verifies
speculative tokens, and preempts under pressure. Each arch runs the SAME
workload three times — a baseline gateway with both features off, a
prefix-cache-only gateway, and the full prefix+speculation gateway — an
ablation that attributes each win to its mechanism: the prefix cache cuts
TTFT (admission needs one chunk instead of five), while speculation cuts
TPOT / raises throughput (multiple tokens per dispatch). Reported per
arch:

  * p50/p99 TTFT and TPOT for every run, plus TTFT p50 restricted to
    prefix-hit-eligible requests (prompts starting with the shared system
    prompt) — the population the cache exists for; the headline
    ``prefix_hit_ttft_p50_speedup`` is prefix-only vs baseline,
  * output tokens/s over the loaded window,
  * ``prefix_hit_rate``, ``prefill_tokens_skipped``, ``drafted_tokens``,
    ``accepted_per_step``, preemption counts, peak KV-page utilization,
    and the post-drain leak check (``drain()`` raises on a nonzero page
    refcount).

Both a warm-up pass (compilation) and the timed passes run the same
workload shape, so the numbers are steady-state scheduling + decode, not
jit. ``run()`` stashes the payload in ``LAST_JSON``; ``benchmarks/run.py``
persists it as ``BENCH_serving.json`` — the tracked perf artifact for the
serving path.
"""

import gc
import time

import jax
import numpy as np

from repro.configs import registry
from repro.core.config import visit_config
from repro.inference.engine import InferenceEngine
from repro.quantization.modifier import set_kv_cache_dtype
from repro.serving import SamplingParams, ServingGateway

BENCH_ARCHS = ["qwen2-1.5b", "gemma2-27b"]

# kv_dtype ablation: same page-pool BYTE budget, different storage dtypes
# (the quantized-KV density claim — more sequences per HBM byte).
KV_ABLATION_ARCH = "qwen2-1.5b"
KV_ABLATION_DTYPES = ["fp32", "bf16", "int8", "fp8_e4m3"]
KV_ABLATION_SLOTS = 12  # page-limited, not slot-limited

N_REQUESTS = 120  # 10x the original 12-request load
# ~20 req/s: above what the no-cache gateway can absorb (its backlog
# grows for the whole run) but within reach of the prefix-cached one —
# the regime the cache exists for, where skipped prefill is the
# difference between a growing queue and keeping up.
MEAN_INTERARRIVAL_S = 0.05
PAGE_SIZE = 8
SLOTS = 6
SYSTEM_PROMPT_LEN = 40  # 5 full pages of shareable prefix
SHARED_FRACTION = 0.75  # requests starting with the shared system prompt

LAST_JSON = None


def _paged_engine(arch, max_len=64, slots=SLOTS, num_pages=None,
                  kv_dtype=None):
    """Registry smoke model with the paged-KV serving config: half the
    dense engine's full-residency pages (unless ``num_pages`` pins the
    pool), so the load exercises paging. ``kv_dtype`` retargets the paged
    pools' storage format by short name."""
    spec = registry.get_spec(arch)
    cfg = spec.make_smoke()
    n_logical = -(-max_len // PAGE_SIZE)
    if num_pages is None:
        num_pages = 1 + slots * n_logical // 2

    def to_paged(_, c):
        if getattr(c, "kv_cache_layout", None) == "dense" \
                and getattr(c, "sliding_window", None) is None:
            c.set(kv_cache_layout="paged", page_size=PAGE_SIZE,
                  num_pages=num_pages)

    visit_config(cfg, to_paged)
    if kv_dtype is not None:
        set_kv_cache_dtype(cfg, kv_dtype, paged_only=True)
    engine = InferenceEngine.default_config().set(
        name="engine", model=cfg, max_len=max_len, slots=slots).instantiate()
    params = engine.model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    engine.load(params)
    return engine, cfg.decoder.vocab_size


def _page_pool_bytes_per_page(engine):
    """Measured bytes of ONE physical page across every page-axis cache
    leaf (KV payload + positions + scale rows if quantized) — from the
    allocated arrays, so the density claim reflects real storage, not a
    dtype label."""
    gw = ServingGateway(engine, seed=0, prefix_caching=False, spec_k=0)
    mgr, cache = gw.scheduler.manager, gw.scheduler._cache
    leaves = jax.tree_util.tree_flatten(cache)[0]
    total = 0
    for leaf, info in zip(leaves, mgr._info):
        if info.page_axis >= 0:
            total += leaf.nbytes // leaf.shape[info.page_axis]
    return total, mgr.num_pages


def _decode_concurrency_probe(engine, vocab, seed):
    """Saturating capacity probe: 24 requests that each grow to a full
    max_len KV footprint (8-token prompt + 56 decoded tokens = 8 pages)
    all arrive at once. Decode dominates, so the time-averaged decode
    batch size — tokens produced per batched decode dispatch — settles at
    how many full sequences the page pool sustains simultaneously.
    (Peak-concurrency counters can't measure this: early in the run every
    admitted sequence holds one page, so peaks reflect queue depth.)"""
    from repro.serving import Scheduler, ServeRequest

    rng = np.random.default_rng(seed)
    sched = Scheduler(engine, prefill_chunk=8, spec_k=0,
                      prefix_caching=False)
    for i in range(24):
        sched.submit(ServeRequest(
            request_id=i, prompt=rng.integers(0, vocab, size=(8,)),
            max_new_tokens=56, arrival_time=0.0))
    while sched.step():
        pass
    total_tokens = sum(len(sched.result(i).tokens) for i in range(24)
                       if sched.result(i) is not None)
    return total_tokens / max(sched.stats["decode_steps"], 1)


def _kv_dtype_ablation():
    """Same arrival workload, same page-pool byte budget, four storage
    dtypes. The budget is the bf16 pool's bytes; each dtype gets as many
    pages as fit, and the scheduler's measured peak concurrency shows the
    density win (acceptance: int8 fits >= 1.8x the sequences)."""
    per_page = {}
    for name in KV_ABLATION_DTYPES:
        probe, _ = _paged_engine(KV_ABLATION_ARCH, slots=KV_ABLATION_SLOTS,
                                 num_pages=2, kv_dtype=name)
        per_page[name], _ = _page_pool_bytes_per_page(probe)
    # Budget = the bf16 pool at the benchmark's standard half residency;
    # every dtype gets as many pages as fit in those same bytes.
    n_logical = -(-64 // PAGE_SIZE)
    budget_pages_bf16 = KV_ABLATION_SLOTS * n_logical // 2
    budget_bytes = budget_pages_bf16 * per_page["bf16"]

    rows, payload = [], {}
    for name in KV_ABLATION_DTYPES:
        usable = int(budget_bytes // per_page[name])
        engine, vocab = _paged_engine(KV_ABLATION_ARCH,
                                      slots=KV_ABLATION_SLOTS,
                                      num_pages=1 + usable, kv_dtype=name)
        _drive(engine, vocab, seed=1, n_requests=16,
               prefix_caching=False, spec_k=0)  # warm-up
        gc.collect()
        time.sleep(1.0)
        gw, util, _ = _drive(engine, vocab, seed=3, n_requests=60,
                             prefix_caching=False, spec_k=0)
        decode_conc = _decode_concurrency_probe(engine, vocab, seed=4)
        m = gw.metrics()
        s = gw.scheduler.stats
        payload[name] = {
            "page_pool_bytes": usable * per_page[name],
            "bytes_per_page": per_page[name],
            "usable_pages": usable,
            # How many full-max_len sequences the pool holds fully
            # resident at once — the headline "concurrent sequences at
            # fixed page-pool bytes", from measured per-page bytes.
            "max_len_resident_seqs": usable // n_logical,
            "avg_decode_batch": decode_conc,
            "max_concurrent": s["max_concurrent"],
            "preemptions": s["preemptions"],
            "completed": m["completed"],
            "timeouts": s["timeouts"],
            "ttft_p50_us": m["ttft_p50_s"] * 1e6,
            "tpot_p50_us": m["tpot_p50_s"] * 1e6,
            "tokens_per_s": m["tokens_per_s"],
            "peak_block_utilization": util,
        }
        del engine, gw
        gc.collect()
    for name in ("int8", "fp8_e4m3"):
        payload[f"{name}_density_x_vs_bf16"] = (
            payload[name]["usable_pages"] / payload["bf16"]["usable_pages"])
        payload[f"{name}_concurrency_x_vs_bf16"] = (
            payload[name]["max_len_resident_seqs"]
            / max(payload["bf16"]["max_len_resident_seqs"], 1))
    rows.append((f"serving_kv_density/{KV_ABLATION_ARCH}",
                 payload["int8_density_x_vs_bf16"],
                 f"int8_pages={payload['int8']['usable_pages']};"
                 f"bf16_pages={payload['bf16']['usable_pages']};"
                 f"concurrency_x={payload['int8_concurrency_x_vs_bf16']:.2f}"))
    return rows, payload


def _workload(vocab, seed, n_requests):
    """Shared-system-prompt request mix: most requests are the system
    prompt plus a short unique tail (the millions-of-users shape), the
    rest fully distinct prompts. Every 3rd request samples (temperature
    0.8) so greedy/speculative and sampled rows batch together."""
    rng = np.random.default_rng(seed)
    system = rng.integers(0, vocab, size=(SYSTEM_PROMPT_LEN,))
    prompts, shared = [], []
    for i in range(n_requests):
        if rng.random() < SHARED_FRACTION:
            tail = rng.integers(0, vocab, size=(int(rng.integers(3, 9)),))
            prompts.append(np.concatenate([system, tail]))
            shared.append(True)
        else:
            prompts.append(rng.integers(0, vocab,
                                        size=(int(rng.integers(3, 33)),)))
            shared.append(False)
    samplings = [SamplingParams(max_new_tokens=int(rng.integers(4, 12)),
                                temperature=0.8 * (i % 3 == 0))
                 for i in range(n_requests)]
    arrivals = np.cumsum(rng.exponential(MEAN_INTERARRIVAL_S, n_requests))
    return prompts, shared, samplings, arrivals


def _drive(engine, vocab, seed, *, n_requests=N_REQUESTS,
           prefix_caching=True, spec_k=4):
    """One Poisson-arrival workload through a fresh gateway."""
    prompts, shared, samplings, arrivals = _workload(vocab, seed, n_requests)
    gw = ServingGateway(engine, prefill_chunk=8, seed=seed,
                        prefix_caching=prefix_caching, spec_k=spec_k)
    t0 = time.perf_counter()
    pending = list(range(n_requests))
    rids = [None] * n_requests
    peak_util = 0.0
    while pending or gw.scheduler.has_work:
        now = time.perf_counter() - t0
        while pending and arrivals[pending[0]] <= now:
            i = pending.pop(0)
            rids[i] = gw.submit(prompts[i], sampling=samplings[i],
                                priority=int(i % 2))
        if gw.scheduler.has_work:
            gw.step()
        peak_util = max(peak_util, gw.scheduler.block_utilization)
    gw.drain()  # raises on any leaked page reference
    # TTFT over the prefix-hit-eligible population (shared-prompt
    # requests), from per-request results.
    hit_ttfts = [gw.result(rid).ttft_s
                 for rid, is_shared in zip(rids, shared)
                 if is_shared and gw.result(rid) is not None
                 and not gw.result(rid).timed_out]
    hit_ttft_p50 = float(np.median(hit_ttfts)) if hit_ttfts else float("nan")
    return gw, peak_util, hit_ttft_p50


def run():
    global LAST_JSON
    rows = []
    payload = {}
    for arch in BENCH_ARCHS:
        engine, vocab = _paged_engine(arch)
        # Warm-up compiles every chunk bucket, the fused decode step, and
        # the verify step before anything is timed.
        _drive(engine, vocab, seed=1, n_requests=16)
        _drive(engine, vocab, seed=1, n_requests=16,
               prefix_caching=False, spec_k=0)

        def settle():
            # Decouple consecutive timed runs: drop garbage from the
            # previous gateway and give the host a beat so one run's CPU
            # burst cannot throttle the next (wall-clock TTFT under
            # Poisson arrivals is sensitive to iteration-rate drift).
            gc.collect()
            time.sleep(1.0)

        settle()
        base, base_util, base_hit_p50 = _drive(
            engine, vocab, seed=2, prefix_caching=False, spec_k=0)
        settle()
        pref, pref_util, pref_hit_p50 = _drive(
            engine, vocab, seed=2, spec_k=0)
        settle()
        full, full_util, full_hit_p50 = _drive(engine, vocab, seed=2)
        mb, mp, mf = base.metrics(), pref.metrics(), full.metrics()
        # The headline TTFT criterion isolates the prefix cache (the
        # mechanism that skips prefill work); the full run's speedup is
        # also recorded.
        speedup = base_hit_p50 / pref_hit_p50 if pref_hit_p50 > 0 else 0.0
        full_speedup = (base_hit_p50 / full_hit_p50
                        if full_hit_p50 > 0 else 0.0)
        rows.append((f"serving_ttft_p50/{arch}", mp["ttft_p50_s"] * 1e6,
                     f"baseline_us={mb['ttft_p50_s'] * 1e6:.0f};"
                     f"hit_speedup={speedup:.2f}x"))
        rows.append((f"serving_tpot_p50/{arch}", mf["tpot_p50_s"] * 1e6,
                     f"baseline_us={mb['tpot_p50_s'] * 1e6:.0f}"))
        rows.append((f"serving_throughput/{arch}", mf["tokens_per_s"],
                     f"baseline={mb['tokens_per_s']:.0f};"
                     f"prefix_hit_rate={mf['prefix_hit_rate']:.2f};"
                     f"accepted_per_step={mf['accepted_per_step']:.2f}"))

        def _run_payload(m, util, hit_p50):
            return {
                "ttft_p50_us": m["ttft_p50_s"] * 1e6,
                "ttft_p99_us": m["ttft_p99_s"] * 1e6,
                "ttft_p50_prefix_hit_us": hit_p50 * 1e6,
                "tpot_p50_us": m["tpot_p50_s"] * 1e6,
                "tpot_p99_us": m["tpot_p99_s"] * 1e6,
                "tokens_per_s": m["tokens_per_s"],
                "completed": m["completed"],
                "preemptions": m["preemptions"],
                "restores": m["restores"],
                "peak_block_utilization": util,
                "prefix_hit_rate": m["prefix_hit_rate"],
                "prefill_tokens_skipped": m["prefill_tokens_skipped"],
                "cow_forks": m["cow_forks"],
                "drafted_tokens": m["drafted_tokens"],
                "accepted_tokens": m["accepted_tokens"],
                "accepted_per_step": m["accepted_per_step"],
                "verify_steps": m["verify_steps"],
            }

        payload[arch] = {
            "baseline": _run_payload(mb, base_util, base_hit_p50),
            "prefix_only": _run_payload(mp, pref_util, pref_hit_p50),
            "prefix_spec": _run_payload(mf, full_util, full_hit_p50),
            "prefix_hit_ttft_p50_speedup": speedup,
            "prefix_spec_hit_ttft_p50_speedup": full_speedup,
            "requests": N_REQUESTS,
            "shared_fraction": SHARED_FRACTION,
            "system_prompt_len": SYSTEM_PROMPT_LEN,
            "slots": SLOTS,
            "page_size": PAGE_SIZE,
        }
    abl_rows, abl_payload = _kv_dtype_ablation()
    rows.extend(abl_rows)
    payload["kv_dtype_ablation"] = dict(
        abl_payload, arch=KV_ABLATION_ARCH, slots=KV_ABLATION_SLOTS,
        requests=60)
    LAST_JSON = payload
    return rows
