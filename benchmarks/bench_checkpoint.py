"""Checkpointing + goodput benchmark (paper §5–§6).

Measures, on a synthetic multi-leaf state (~tens of MB, shaped like a small
model + Adam moments):

* ``sync_save_us``   — full synchronous save (stage + serialize + write),
  i.e. what the training thread would stall WITHOUT async checkpointing;
* ``async_stall_us`` — what the training thread actually stalls per async
  ``save()`` (device-side snapshot only; staging + write run backstage).
  The acceptance signal is ``stall_ratio`` = stall / sync ≪ 1;
* ``restore_us``     — committed-checkpoint read + validation;
* goodput under injected preemptions — a tiny supervised run with two
  SIGTERM-style preemptions: resumable data + emergency saves mean zero
  recomputed steps (``lost_s == 0``), and the summary's bucket split shows
  where the wall time went.

``run.py`` persists ``LAST_JSON`` as ``BENCH_checkpoint.json``.
"""

import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer

LAST_JSON = None

STATE_LEAVES = 24
LEAF_SHAPE = (256, 1024)  # 24 MB of fp32 across 24 leaves
SAVE_REPS = 4


def _make_state(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {f"w{i}": jnp.asarray(
        rng.standard_normal(LEAF_SHAPE), jnp.float32)
        for i in range(STATE_LEAVES)}}


def _ckpt(directory, **overrides):
    return Checkpointer.default_config().set(
        directory=directory, keep_last_n=2, **overrides).instantiate()


def _bench_saves():
    state = _make_state()
    bytes_total = STATE_LEAVES * int(np.prod(LEAF_SHAPE)) * 4

    tmp = tempfile.mkdtemp(prefix="bench_ckpt_sync_")
    try:
        ckpt = _ckpt(tmp, async_save=False)
        ckpt.save(0, state)  # warm (jit'd snapshot identities compile once)
        times = []
        for i in range(1, SAVE_REPS + 1):
            t0 = time.perf_counter()
            ckpt.save(i, state)
            times.append(time.perf_counter() - t0)
        sync_us = float(np.mean(times)) * 1e6
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    tmp = tempfile.mkdtemp(prefix="bench_ckpt_async_")
    try:
        ckpt = _ckpt(tmp, async_save=True)
        ckpt.save(0, state)
        ckpt.wait()
        stalls, totals = [], []
        for i in range(1, SAVE_REPS + 1):
            # Training cadence: the previous write has drained (as it would
            # behind real steps), so the stall is the snapshot alone.
            t0 = time.perf_counter()
            ckpt.save(i, state)
            stalls.append(time.perf_counter() - t0)
            ckpt.wait()
            totals.append(time.perf_counter() - t0)
        stall_us = float(np.mean(stalls)) * 1e6
        total_us = float(np.mean(totals)) * 1e6

        t0 = time.perf_counter()
        restored = ckpt.restore(like=state)
        restore_us = (time.perf_counter() - t0) * 1e6
        np.testing.assert_array_equal(
            np.asarray(restored["params"]["w0"]),
            np.asarray(state["params"]["w0"]))
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    return {
        "state_bytes": bytes_total,
        "sync_save_us": sync_us,
        "async_stall_us": stall_us,
        "async_total_us": total_us,
        "stall_ratio": stall_us / sync_us,
        "restore_us": restore_us,
        "save_throughput_mb_s": bytes_total / 1e6 / (total_us / 1e6),
    }


def _bench_goodput_under_preemption():
    from repro.core.config import config_for_function
    from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
    from repro.runtime.supervisor import Fault, Supervisor
    from repro.trainer import optimizers as opt_lib
    from repro.trainer.trainer import SpmdTrainer

    tmp = tempfile.mkdtemp(prefix="bench_ckpt_goodput_")
    try:
        layer = TransformerLayer.default_config().set(input_dim=32)
        layer.self_attention.set(num_heads=4, num_kv_heads=2)
        layer.feed_forward.set(hidden_dim=64)
        model = CausalLM.default_config().set(
            decoder=Decoder.default_config().set(
                vocab_size=32, dim=32,
                stack=Repeat.default_config().set(layer=layer, num_layers=2,
                                                  remat_policy=None)))
        cfg = SpmdTrainer.default_config().set(name="t", model=model,
                                               max_steps=24, log_every_n=8)
        cfg.input.set(task="lm", vocab_size=32, seq_len=16,
                      global_batch_size=8)
        cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
            peak_lr=1e-2)
        cfg.checkpointer = Checkpointer.default_config().set(directory=tmp)
        cfg.checkpoint_every_n = 6
        result = Supervisor(cfg).run(24, faults=[
            Fault(step=7, kind="preempt"), Fault(step=15, kind="preempt")])
        g = result["goodput"]
        return {
            "steps": 24,
            "preemptions": result["restarts"],
            "goodput_fraction": g["goodput_fraction"],
            # Raw goodput on a ~20 s run is dominated by one-time compile +
            # init; the steady-state number (startup buckets excluded from
            # the denominator) is what a long run would sustain and is the
            # tracked signal.
            "steady_goodput_fraction": g["steady_goodput_fraction"],
            "steady_wall_s": g["steady_wall_s"],
            "lost_s": g["lost_s"],
            "wall_s": g["wall_s"],
            "buckets_s": {k: round(v, 4) for k, v in g["buckets"].items()},
        }
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def run():
    global LAST_JSON
    saves = _bench_saves()
    goodput = _bench_goodput_under_preemption()
    LAST_JSON = {"saves": saves, "goodput_under_preemption": goodput}
    return [
        ("checkpoint_save_sync", saves["sync_save_us"],
         f"bytes={saves['state_bytes']}"),
        ("checkpoint_save_async_stall", saves["async_stall_us"],
         f"stall_ratio={saves['stall_ratio']:.3f};"
         f"total_us={saves['async_total_us']:.0f}"),
        ("checkpoint_restore", saves["restore_us"],
         f"throughput_mb_s={saves['save_throughput_mb_s']:.0f}"),
        ("checkpoint_goodput_preempted", goodput["wall_s"] * 1e6,
         f"goodput={goodput['goodput_fraction']:.3f};"
         f"steady={goodput['steady_goodput_fraction']:.3f};"
         f"preemptions={goodput['preemptions']};lost_s={goodput['lost_s']:.3f}"),
    ]
