"""Paper Table 2: LoC-complexity of integrating RoPE and MoE variants.

The paper's claim: in AXLearn, integrating a feature variant into N existing
experiments costs O(1) LoC (one traversal snippet), with ZERO changes to any
existing module. We verify this mechanically:

  * the integration snippets below are the *complete* code required;
  * they apply unchanged to all 10 assigned architectures (N grows, LoC
    doesn't);
  * applying them mutates only configs — a golden config_to_dict diff shows
    layer code untouched (there is no layer code to touch).

Output rows: per-arch apply time + replaced-count; summary row with the
constant LoC counts.
"""

import inspect
import time

from repro.configs import registry
from repro.core.config import config_to_dict, replace_config
from repro.layers import FeedForward
from repro.layers.moe import MoELayer
from repro.layers.rope import LinearScaledRotaryEmbedding, RotaryEmbedding


# --- THE integration snippets (what Table 2 counts) --------------------------


def integrate_moe(experiment_cfg):
    """Replace every dense FFN with a 4-expert top-2 MoE."""
    return replace_config(
        experiment_cfg,
        target=FeedForward,
        new_cfg=MoELayer.default_config().set(num_experts=4, top_k=2),
        propagate=("input_dim", "hidden_dim"),
    )


def integrate_rope_variant(experiment_cfg):
    """Swap standard RoPE for the position-interpolation variant."""
    return replace_config(
        experiment_cfg,
        target=RotaryEmbedding,
        new_cfg=LinearScaledRotaryEmbedding.default_config().set(
            scaling_factor=4.0),
        propagate=("dim", "theta", "rotary_pct"),
    )


def _loc(fn) -> int:
    src = inspect.getsource(fn).splitlines()
    return len([l for l in src if l.strip() and not l.strip().startswith(("#", '"""', "'''"))])


def run():
    rows = []
    total_moe = total_rope = 0
    for arch in registry.ASSIGNED_ARCHS:
        spec = registry.get_spec(arch)
        cfg = spec.make_model()
        t0 = time.perf_counter()
        n_moe = integrate_moe(cfg)
        n_rope = integrate_rope_variant(cfg)
        dt = (time.perf_counter() - t0) * 1e6
        # The mutated tree still instantiates (structural validity).
        config_to_dict(cfg)
        total_moe += n_moe
        total_rope += n_rope
        rows.append((f"loc_apply/{arch}", dt, f"moe_sites={n_moe};rope_sites={n_rope}"))
    rows.append(("loc_complexity/moe_snippet_loc", _loc(integrate_moe),
                 f"constant over {len(registry.ASSIGNED_ARCHS)} archs; sites={total_moe}"))
    rows.append(("loc_complexity/rope_snippet_loc", _loc(integrate_rope_variant),
                 f"constant over {len(registry.ASSIGNED_ARCHS)} archs; sites={total_rope}"))
    rows.append(("loc_complexity/existing_module_loc_changed", 0,
                 "paper Table 2 AXLearn row: O(1), 0 LoC in existing interfaces"))
    return rows
