"""Fault-tolerant runtime tests: goodput attribution, crash/preemption
injection via the supervisor, loss-curve continuity across restarts,
exactly-once data delivery through checkpointed iterator state."""

import numpy as np
import pytest

from repro.core.config import config_for_function
from repro.checkpoint.checkpointer import Checkpointer
from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
from repro.runtime.goodput import GoodputMonitor
from repro.runtime.signals import Preempted, SimulatedCrash
from repro.runtime.supervisor import Fault, Supervisor, assert_continuity
from repro.trainer import optimizers as opt_lib
from repro.trainer.trainer import SpmdTrainer

STEPS = 12
CKPT_EVERY = 4


def _tiny_cfg(tmpdir=None, *, async_save=True):
    layer = TransformerLayer.default_config().set(input_dim=32)
    layer.self_attention.set(num_heads=4, num_kv_heads=2)
    layer.feed_forward.set(hidden_dim=64)
    model = CausalLM.default_config().set(
        decoder=Decoder.default_config().set(
            vocab_size=32, dim=32,
            stack=Repeat.default_config().set(layer=layer, num_layers=2,
                                              remat_policy=None)))
    cfg = SpmdTrainer.default_config().set(name="t", model=model,
                                           max_steps=STEPS, log_every_n=1,
                                           seed=1)
    cfg.input.set(task="lm", vocab_size=32, seq_len=16, global_batch_size=8)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(peak_lr=1e-2)
    if tmpdir is not None:
        cfg.checkpointer = Checkpointer.default_config().set(
            directory=str(tmpdir), async_save=async_save)
        cfg.checkpoint_every_n = CKPT_EVERY
    return cfg


@pytest.fixture(scope="module")
def reference(tmp_path_factory):
    """The uninterrupted run every fault scenario must reproduce."""
    cfg = _tiny_cfg(tmp_path_factory.mktemp("ref"))
    result = Supervisor(cfg).run(STEPS)
    assert result["restarts"] == 0
    return result


# ------------------------------------------------------------ goodput monitor


def test_goodput_bucket_attribution():
    t = {"now": 0.0}
    mon = GoodputMonitor(time_fn=lambda: t["now"])
    with mon.bucket("compile", step=0):
        t["now"] += 3.0
    for s in range(4):
        with mon.bucket("step", step=s):
            t["now"] += 1.0
        with mon.bucket("input_stall", step=s):
            t["now"] += 0.25
    with mon.bucket("checkpoint_stall", step=3):
        t["now"] += 0.5
    s = mon.summary()
    assert s["wall_s"] == pytest.approx(8.5)
    assert s["buckets"]["step"] == pytest.approx(4.0)
    assert s["buckets"]["input_stall"] == pytest.approx(1.0)
    assert s["untracked_s"] == pytest.approx(0.0)
    assert s["goodput_fraction"] == pytest.approx(4.0 / 8.5)


def test_goodput_restart_loss_is_virtual():
    """restart_loss re-attributes already-counted step time: it reduces
    goodput but is NOT part of the wall-clock bucket sum."""
    t = {"now": 0.0}
    mon = GoodputMonitor(time_fn=lambda: t["now"])
    for s in range(4):
        with mon.bucket("step", step=s):
            t["now"] += 1.0
    mon.add_event("restart_loss", 2.0, virtual=True)
    s = mon.summary()
    assert s["wall_s"] == pytest.approx(4.0)
    assert s["lost_s"] == pytest.approx(2.0)
    assert s["untracked_s"] == pytest.approx(0.0)  # virtual time excluded
    assert s["goodput_fraction"] == pytest.approx(2.0 / 4.0)


def test_goodput_sink_receives_structured_events():
    seen = []
    mon = GoodputMonitor(sink=seen.append)
    mon.context["attempt"] = 3
    with mon.bucket("step", step=7):
        pass
    assert len(seen) == 1
    assert seen[0]["bucket"] == "step"
    assert seen[0]["step"] == 7 and seen[0]["attempt"] == 3
    assert seen[0]["dur_s"] >= 0.0


# ------------------------------------------------- supervisor: crash/resume


@pytest.mark.parametrize("scenario", ["before_first_checkpoint",
                                      "during_async_save",
                                      "between_checkpoints_sync"])
def test_crash_resumes_with_identical_loss_curve(scenario, reference,
                                                 tmp_path):
    """The acceptance criterion: a run killed at an arbitrary point resumes
    from the latest COMMITTED checkpoint and reproduces the uninterrupted
    loss curve exactly — which also proves exactly-once data delivery (a
    replayed or skipped batch would shift every subsequent loss)."""
    if scenario == "before_first_checkpoint":
        cfg, fault = _tiny_cfg(tmp_path), Fault(step=1, kind="crash")
    elif scenario == "during_async_save":
        # The save for step 4 launches in step 3's iteration; the crash in
        # the same iteration kills the process mid-write.
        cfg, fault = _tiny_cfg(tmp_path), Fault(step=3, kind="crash")
    else:
        # Sync saves: the boundary save at step 4 is durable before the
        # crash at step 6, so the restart MUST resume from step 4.
        cfg, fault = (_tiny_cfg(tmp_path, async_save=False),
                      Fault(step=6, kind="crash"))
    sup = Supervisor(cfg)
    result = sup.run(STEPS, faults=[fault])
    assert result["restarts"] == 1
    assert result["attempts"][0]["outcome"] == "crash"
    if scenario == "between_checkpoints_sync":
        assert result["attempts"][0]["resumed_from"] == 4
    assert_continuity(result["losses"], reference["losses"])
    # Exactly-once data: both runs consumed precisely STEPS batches.
    assert result["input_state"] == reference["input_state"]
    assert result["input_state"]["next_batch"] == STEPS
    # Lost productive time was attributed to the virtual bucket.
    g = result["goodput"]
    assert g["lost_s"] > 0.0
    assert 0.0 <= g["goodput_fraction"] <= 1.0


def test_preemption_emergency_save_loses_zero_steps(reference, tmp_path):
    """SIGTERM-style preemption: the loop commits an emergency checkpoint at
    the very step it was interrupted, so the restart recomputes nothing."""
    sup = Supervisor(_tiny_cfg(tmp_path))
    result = sup.run(STEPS, faults=[Fault(step=5, kind="preempt")])
    assert result["restarts"] == 1
    att = result["attempts"][0]
    assert att["outcome"] == "preempt"
    # The event is polled at the NEXT step boundary after the hook sets it.
    assert att["at_step"] == 6 and att["resumed_from"] == 6
    # The resumed attempt starts exactly where the emergency save committed.
    resumed_steps = [e["step"] for e in sup.monitor.events
                     if e.get("attempt") == 1 and e["bucket"] in ("step", "compile")]
    assert min(resumed_steps) == 6
    assert_continuity(result["losses"], reference["losses"])
    assert result["goodput"]["lost_s"] == 0.0  # nothing recomputed


def test_double_fault_and_max_restarts(reference, tmp_path):
    sup = Supervisor(_tiny_cfg(tmp_path))
    result = sup.run(STEPS, faults=[Fault(step=2, kind="crash"),
                                    Fault(step=9, kind="preempt")])
    assert result["restarts"] == 2
    assert_continuity(result["losses"], reference["losses"])
    # max_restarts exhausted -> the fault propagates.
    crashy = Supervisor(_tiny_cfg(tmp_path / "crashy"), max_restarts=0)
    with pytest.raises(SimulatedCrash):
        crashy.run(STEPS, faults=[Fault(step=1, kind="crash")])


def test_preempted_without_checkpointer_reports_uncommitted():
    cfg = _tiny_cfg(None)
    trainer = cfg.instantiate()
    trainer.preemption_event.set()
    with pytest.raises(Preempted) as exc_info:
        trainer.run(2)
    assert exc_info.value.committed is False


def test_trainer_reports_goodput_buckets(tmp_path):
    result = _tiny_cfg(tmp_path).instantiate().run(6)
    g = result["goodput"]
    for bucket in ("init", "compile", "step", "input_stall",
                   "checkpoint_stall"):
        assert bucket in g["buckets"], g["buckets"]
    assert g["buckets"]["compile"] > g["buckets"]["step"] / 5  # compile real
    assert g["wall_s"] > 0
    assert len(result["goodput_events"]) >= 6
    # Structured events carry the step they belong to.
    steps = {e.get("step") for e in result["goodput_events"]
             if e["bucket"] == "step"}
    assert steps == {1, 2, 3, 4, 5}  # step 0 was the compile event


def test_fault_unwind_disarms_watchdog(tmp_path, monkeypatch):
    """Regression: a fault-injected unwind (crash/preemption) must cancel
    the armed watchdog timer — a leaked timer would interrupt_main() into
    the NEXT supervisor attempt."""
    import repro.trainer.trainer as trainer_mod

    created = []
    orig = trainer_mod._Watchdog

    class Recording(orig):
        def __init__(self, *args, **kwargs):
            super().__init__(*args, **kwargs)
            created.append(self)

    monkeypatch.setattr(trainer_mod, "_Watchdog", Recording)
    cfg = _tiny_cfg(tmp_path)
    cfg.watchdog_timeout_s = 60.0
    cfg.watchdog_on_timeout = "raise"

    def hook(**kwargs):
        raise SimulatedCrash(kwargs["step"])

    with pytest.raises(SimulatedCrash):
        cfg.instantiate().run(4, step_hook=hook)
    assert created and created[-1]._timer is None, "watchdog timer leaked"


def test_fault_validation():
    with pytest.raises(ValueError, match="kind"):
        Fault(step=1, kind="meteor")


def test_install_preemption_handler_routes_sigterm():
    """The launch/train.py wiring: SIGTERM only sets the event (the loop
    does the expensive emergency save on the training thread)."""
    import os
    import signal
    import threading

    from repro.runtime.signals import install_preemption_handler

    event = threading.Event()
    previous = install_preemption_handler(event)
    try:
        os.kill(os.getpid(), signal.SIGTERM)
        assert event.wait(timeout=5.0)
    finally:
        for signum, handler in previous.items():
            signal.signal(signum, handler)
