"""Resumable input pipeline tests: explicit-state iterators (exactly-once
resume), streaming packed sequences, prefetch thread + bounded queue."""

import numpy as np
import pytest

from repro.data.input import SyntheticInput
from repro.data.streaming import (
    IGNORE_LABEL,
    PrefetchIterator,
    StreamingTextInput,
    StreamingTextIterator,
)


def _synth(**overrides):
    cfg = SyntheticInput.default_config().set(
        name="in", task="lm", vocab_size=64, seq_len=16, global_batch_size=4)
    cfg.set(**overrides)
    return cfg.instantiate()


def _stream(**overrides):
    cfg = StreamingTextInput.default_config().set(
        name="in", vocab_size=64, seq_len=16, global_batch_size=4, prefetch=0)
    cfg.set(**overrides)
    return cfg.instantiate()


def _take(it, n):
    return [next(it) for _ in range(n)]


def _assert_batches_equal(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert x.keys() == y.keys()
        for k in x:
            np.testing.assert_array_equal(x[k], y[k])


# ------------------------------------------------------------ SyntheticInput


def test_synthetic_iterator_exactly_once_resume():
    src = _synth()
    it = src.batches()
    first = _take(it, 3)
    snap = it.state()
    rest = _take(it, 3)
    # A fresh iterator restored from the snapshot continues with batch 3 —
    # no replays, no skips.
    it2 = src.batches()
    it2.restore(snap)
    _assert_batches_equal(_take(it2, 3), rest)
    # And from scratch the whole stream reproduces.
    _assert_batches_equal(_take(src.batches(), 3), first)


def test_synthetic_state_is_json_serializable():
    import json

    it = _synth().batches()
    next(it)
    assert json.loads(json.dumps(it.state())) == it.state()


# --------------------------------------------------------- StreamingTextInput


def test_streaming_batches_shape_and_eos_masking():
    src = _stream()
    batch = next(src.batches())
    assert batch["input_ids"].shape == (4, 16)
    assert batch["labels"].shape == (4, 16)
    ids, labels = batch["input_ids"], batch["labels"]
    eos = src.config.eos_id
    # Wherever the input is the separator, the label is masked: the model
    # is never trained to predict across a document boundary from EOS.
    assert (labels[ids == eos] == IGNORE_LABEL).all()
    # Packing is dense: several documents per batch -> separators present.
    assert (ids == eos).sum() > 0
    # Non-EOS tokens live in [2, vocab).
    toks = ids[ids != eos]
    assert toks.min() >= 2 and toks.max() < 64


def test_streaming_documents_are_pure_functions_of_index():
    src = _stream()
    assert src.document_tokens(7) == src.document_tokens(7)
    assert src.document_tokens(7) != src.document_tokens(8)
    assert _stream(seed=1).document_tokens(7) != src.document_tokens(7)


def test_streaming_resume_mid_buffer_exactly_once():
    """The leftover packing buffer is part of the cursor: a restore must
    continue mid-document, token-exact."""
    src = _stream()
    it = src.batches()
    _take(it, 4)
    snap = it.state()
    assert snap["buffer"], "want a non-empty carry buffer for this test"
    rest = _take(it, 3)
    it2 = src.batches()
    it2.restore(snap)
    _assert_batches_equal(_take(it2, 3), rest)


def test_streaming_host_sharding_disjoint_documents():
    p0 = _stream(process_count=2, process_index=0, global_batch_size=4)
    p1 = _stream(process_count=2, process_index=1, global_batch_size=4)
    it0, it1 = p0.batches(), p1.batches()
    b0, b1 = next(it0), next(it1)
    # Different document shards -> different token streams.
    assert not np.array_equal(b0["input_ids"], b1["input_ids"])
    # Documents are assigned d % process_count == process_index.
    assert it0.state()["next_doc"] % 2 == 0
    assert it1.state()["next_doc"] % 2 == 1


# ----------------------------------------------------------------- prefetch


def test_prefetch_preserves_sequence_and_state():
    src = _stream()
    plain = _take(src.batches(), 6)
    pre = PrefetchIterator(StreamingTextIterator(src), depth=2)
    try:
        got = _take(pre, 3)
        snap = pre.state()
        got += _take(pre, 3)
    finally:
        pre.close()
    _assert_batches_equal(got, plain)
    # state() reflects CONSUMED batches only: restoring it must continue
    # with batch 3 even though more had been prefetched into the queue.
    it2 = src.batches()
    it2.restore(snap)
    _assert_batches_equal(_take(it2, 3), plain[3:])


def test_prefetch_restore_before_start_and_config_wiring():
    src = _stream(prefetch=2)
    it = src.batches()
    assert isinstance(it, PrefetchIterator)
    snapshot_src = _stream()
    ref_it = snapshot_src.batches()
    _take(ref_it, 2)
    it.restore(ref_it.state())
    try:
        _assert_batches_equal(_take(it, 2), _take(ref_it, 2))
    finally:
        it.close()


def test_prefetch_propagates_producer_errors():
    class Exploding:
        def __init__(self):
            self.n = 0

        def __next__(self):
            if self.n >= 2:
                raise RuntimeError("boom in producer")
            self.n += 1
            return {"x": np.zeros(1)}

        def state(self):
            return {"n": self.n}

    pre = PrefetchIterator(Exploding(), depth=1)
    try:
        _take(pre, 2)
        with pytest.raises(RuntimeError, match="boom in producer"):
            next(pre)
    finally:
        pre.close()


def test_prefetch_error_survives_full_queue():
    """Regression: with the queue full (slow consumer — the normal training
    case), the producer's error sentinel must still be delivered instead of
    being dropped after one timed put, which left the consumer blocked
    forever."""
    import time

    class Exploding:
        def __init__(self):
            self.n = 0

        def __next__(self):
            if self.n >= 2:
                raise RuntimeError("boom behind a full queue")
            self.n += 1
            return {"x": np.zeros(1)}

        def state(self):
            return {"n": self.n}

    pre = PrefetchIterator(Exploding(), depth=1)
    try:
        next(pre)  # batch 1; producer refills the queue (batch 2), raises
        time.sleep(0.4)  # > the producer's 0.1s put timeout, queue stays full
        with pytest.raises(RuntimeError, match="boom behind a full queue"):
            _take(pre, 2)  # drains batch 2, then must see the sentinel
    finally:
        pre.close()


def test_prefetch_close_is_idempotent_and_stops_thread():
    pre = PrefetchIterator(StreamingTextIterator(_stream()), depth=1)
    next(pre)
    thread = pre._thread
    pre.close()
    pre.close()
    assert pre._thread is None and not thread.is_alive()


# -------------------------------------------------- trainer integration


def test_trainer_runs_on_streaming_input():
    """The input pipeline is swappable like any module (paper §1): the
    trainer trains on StreamingTextInput and reports its iterator state."""
    from repro.core.config import config_for_function
    from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
    from repro.trainer import optimizers as opt_lib
    from repro.trainer.trainer import SpmdTrainer

    layer = TransformerLayer.default_config().set(input_dim=32)
    layer.self_attention.set(num_heads=4, num_kv_heads=2)
    layer.feed_forward.set(hidden_dim=64)
    model = CausalLM.default_config().set(
        decoder=Decoder.default_config().set(
            vocab_size=64, dim=32,
            stack=Repeat.default_config().set(layer=layer, num_layers=1,
                                              remat_policy=None)))
    cfg = SpmdTrainer.default_config().set(name="t", model=model,
                                           max_steps=6, log_every_n=2)
    cfg.input = StreamingTextInput.default_config().set(
        vocab_size=64, seq_len=16, global_batch_size=4, prefetch=2)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(peak_lr=1e-2)
    result = cfg.instantiate().run()
    assert np.isfinite(result["final"]["loss"])
    # 6 batches consumed, exactly once, through the prefetch queue.
    assert result["input_state"]["emitted"] == 6
    assert result["goodput"]["buckets"]["input_stall"] >= 0.0


# --------------------------------------------- reshard_streaming_states


def test_reshard_streaming_states_positions_and_exactly_once():
    """A data cursor saved at world size P recomputes to P' at the SAME
    global batch index: no batch replayed, none skipped."""
    from repro.data.streaming import reshard_streaming_states

    cfg = StreamingTextInput.default_config().set(
        name="in", vocab_size=64, seq_len=16, global_batch_size=4, prefetch=0)
    it = StreamingTextIterator(cfg.instantiate())
    _take(it, 3)
    saved = [it.state()]

    for new_count in (1, 2):
        states = reshard_streaming_states(cfg, saved, new_count)
        assert len(states) == new_count
        assert all(s["emitted"] == 3 for s in states)

    # Identity reshard (1 -> 1): the recomputed cursor continues with the
    # bitwise-identical next batch the original iterator would produce.
    (state,) = reshard_streaming_states(cfg, saved, 1)
    resumed = StreamingTextIterator(cfg.instantiate())
    resumed.restore(state)
    _assert_batches_equal([next(resumed)], [next(it)])


def test_reshard_streaming_states_rejects_torn_cursor():
    """Ranks whose emitted counts disagree were not in lockstep — resharding
    such a cursor would replay or drop batches, so it must refuse."""
    from repro.data.streaming import reshard_streaming_states

    cfg = StreamingTextInput.default_config().set(
        name="in", vocab_size=64, seq_len=16, global_batch_size=4, prefetch=0)
    with pytest.raises(ValueError, match="out of lockstep"):
        reshard_streaming_states(cfg, [{"emitted": 2}, {"emitted": 3}], 2)
    with pytest.raises(ValueError, match="at least one"):
        reshard_streaming_states(cfg, [], 1)
