"""Checkpointer v2 unit tests: error propagation, commit barrier, bounded
staging, memory tier / emergency save, shape validation, aux state."""

import json
import os
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer, CheckpointWriteError


def _state(seed=0, n=6, shape=(32, 16)):
    rng = np.random.default_rng(seed)
    return {"params": {f"w{i}": jnp.asarray(rng.standard_normal(shape), jnp.float32)
                       for i in range(n)},
            "step": jnp.asarray(seed, jnp.int32)}


def _ckpt(directory, **overrides):
    cfg = Checkpointer.default_config().set(directory=str(directory), **overrides)
    return cfg.instantiate()


# ----------------------------------------------------- async error propagation


def test_async_write_error_raises_from_wait(tmp_path):
    """Satellite: a failing background write must surface, not die in a
    daemon thread. An unwritable directory (parent is a FILE, so makedirs
    fails even for root) stands in for a read-only/full filesystem."""
    blocker = tmp_path / "blocker"
    blocker.write_text("not a directory")
    ckpt = _ckpt(blocker / "ckpts")
    ckpt.save(1, _state())
    with pytest.raises(CheckpointWriteError):
        ckpt.wait()
    # The error is consumed once; the checkpointer is usable afterwards.
    ckpt.wait()


def test_async_write_error_raises_from_next_save(tmp_path):
    blocker = tmp_path / "blocker"
    blocker.write_text("not a directory")
    ckpt = _ckpt(blocker / "ckpts")
    ckpt.save(1, _state())
    with pytest.raises(CheckpointWriteError):
        ckpt.save(2, _state())


def test_sync_write_error_raises_immediately(tmp_path):
    blocker = tmp_path / "blocker"
    blocker.write_text("not a directory")
    ckpt = _ckpt(blocker / "ckpts", async_save=False)
    with pytest.raises(CheckpointWriteError):
        ckpt.save(1, _state())


# ------------------------------------------------------------- commit barrier


def test_committed_requires_all_shards(tmp_path):
    """Satellite: process 0 must not commit after only its own shard (the
    old code made half-written multi-process checkpoints visible)."""
    state = _state()
    p0 = _ckpt(tmp_path, process_index=0, process_count=2)
    p1 = _ckpt(tmp_path, process_index=1, process_count=2)
    p0.save(1, state)  # p0's commit barrier now polls for shard_1
    time.sleep(0.2)
    assert p0.latest_step() is None, "committed with shard_1 missing"
    p1.save(1, state)
    p1.wait()
    p0.wait()  # barrier satisfied -> index + COMMITTED written
    assert p0.latest_step() == 1
    # Restore sees the union of both processes' leaves.
    restored = p0.restore(1, like=state)
    for a, b in zip(
            [np.asarray(x) for x in state["params"].values()],
            [np.asarray(x) for x in restored["params"].values()]):
        np.testing.assert_array_equal(a, b)


def test_commit_barrier_times_out_loudly(tmp_path):
    p0 = _ckpt(tmp_path, process_index=0, process_count=2,
               commit_timeout_s=0.2)
    p0.save(1, _state())
    with pytest.raises(CheckpointWriteError, match="missing shards"):
        p0.wait()


def test_abort_prevents_commit(tmp_path):
    """Simulated process death mid-save: no COMMITTED marker may appear, and
    the previous committed step stays the restore target."""
    ckpt = _ckpt(tmp_path, async_save=False)
    ckpt.save(1, _state(1))
    slow = _ckpt(tmp_path)

    gate = threading.Event()
    orig = slow._to_host

    def gated(leaf):
        gate.wait(timeout=5.0)
        return orig(leaf)

    slow._to_host = gated
    slow.save(2, _state(2))  # async write stuck in staging
    # abort() joins the write thread; release the gate from a timer so the
    # abort flag is set while staging is genuinely in flight.
    threading.Timer(0.2, gate.set).start()
    slow.abort()
    assert slow._save_thread is None  # joined inside abort()
    assert slow.latest_step() == 1
    assert not os.path.exists(tmp_path / "step_00000002" / "COMMITTED")


# ------------------------------------------------------------ bounded staging


def test_staging_concurrency_is_bounded(tmp_path):
    """Satellite: the old per-iteration ``with sem:`` bounded nothing. The
    staging pool must never have more than ``concurrency`` host copies in
    flight."""
    ckpt = _ckpt(tmp_path, concurrency=2, async_save=False)
    lock = threading.Lock()
    live = {"now": 0, "max": 0}
    orig = ckpt._to_host

    def counting(leaf):
        with lock:
            live["now"] += 1
            live["max"] = max(live["max"], live["now"])
        time.sleep(0.01)  # widen the overlap window
        try:
            return orig(leaf)
        finally:
            with lock:
                live["now"] -= 1

    ckpt._to_host = counting
    ckpt.save(1, _state(n=12))
    assert live["max"] <= 2, f"{live['max']} concurrent host copies"
    assert live["max"] == 2, "staging never overlapped; pool broken?"
    assert ckpt.latest_step() == 1


# --------------------------------------------------- memory tier + emergency


def test_memory_tier_flush_recovers_deleted_step(tmp_path):
    import shutil

    ckpt = _ckpt(tmp_path)
    state = _state(3)
    ckpt.save(5, state)
    ckpt.wait()
    shutil.rmtree(tmp_path / "step_00000005")  # durable tier gone
    assert ckpt.latest_step() is None
    assert ckpt.emergency_save() == 5  # flushed from the in-memory tier
    assert ckpt.latest_step() == 5
    restored = ckpt.restore(5, like=state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w0"]),
                                  np.asarray(state["params"]["w0"]))


def test_emergency_save_with_state_is_synchronous(tmp_path):
    ckpt = _ckpt(tmp_path)
    state = _state(7)
    assert ckpt.emergency_save(9, state, aux={"input": {"next_batch": 9}}) == 9
    # No wait() needed: committed before returning.
    assert ckpt.latest_step() == 9
    assert ckpt.restore_aux(9) == {"input": {"next_batch": 9}}


def test_emergency_save_noop_without_memory(tmp_path):
    assert _ckpt(tmp_path).emergency_save() is None


def test_save_after_abort_raises_loudly(tmp_path):
    """'Errors are never silent' extends to misuse: an aborted instance
    must reject saves it would otherwise drop on the floor."""
    ckpt = _ckpt(tmp_path)
    ckpt.abort()
    with pytest.raises(CheckpointWriteError, match="abort"):
        ckpt.save(1, _state())


def test_emergency_commit_barrier_uses_short_timeout(tmp_path):
    """A preemption emergency save on process 0 must not stall for the full
    commit_timeout_s waiting on a peer that died before its shard: the
    emergency barrier budget applies, the error surfaces, and the caller
    (trainer) downgrades to committed=False."""
    p0 = _ckpt(tmp_path, process_index=0, process_count=2,
               commit_timeout_s=60.0, emergency_commit_timeout_s=0.2)
    t0 = time.monotonic()
    with pytest.raises(CheckpointWriteError, match="missing shards"):
        p0.emergency_save(1, _state())
    assert time.monotonic() - t0 < 5.0, "emergency barrier used the full timeout"


def test_emergency_save_after_abort_reports_nothing_committed(tmp_path):
    """A dead (aborted) checkpointer must not claim an emergency commit:
    _write_step is a no-op after abort(), so the step must not be
    reported as resumable."""
    ckpt = _ckpt(tmp_path)
    ckpt.save(1, _state())
    ckpt.wait()
    ckpt.abort()
    assert ckpt.emergency_save(2, _state(2)) is None
    assert ckpt.emergency_save() is None  # memory-tier flush likewise
    assert ckpt.latest_step() == 1


# ------------------------------------------------------- restore validation


def test_restore_validates_shapes_not_just_dtypes(tmp_path):
    """Satellite: restoring into a differently-shaped model must fail with a
    clear error (the old code silently reshaped nothing and crashed later —
    or worse, broadcast)."""
    ckpt = _ckpt(tmp_path, async_save=False)
    ckpt.save(1, _state())
    wrong = _state()
    wrong["params"]["w0"] = jnp.zeros((8, 8), jnp.float32)
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(1, like=wrong)


def test_restore_missing_leaf_error(tmp_path):
    ckpt = _ckpt(tmp_path, async_save=False)
    ckpt.save(1, _state())
    like = _state()
    like["params"]["extra"] = jnp.zeros((2,), jnp.float32)
    with pytest.raises(ValueError, match="missing leaf"):
        ckpt.restore(1, like=like)


# ----------------------------------------------------------------- aux state


def test_aux_roundtrip_and_absence(tmp_path):
    ckpt = _ckpt(tmp_path)
    ckpt.save(2, _state(), aux={"input": {"next_doc": 17, "buffer": [1, 2]}})
    ckpt.wait()
    assert ckpt.restore_aux(2) == {"input": {"next_doc": 17, "buffer": [1, 2]}}
    assert ckpt.restore_aux() == ckpt.restore_aux(2)  # latest by default
    ckpt.save(3, _state())  # no aux
    ckpt.wait()
    assert ckpt.restore_aux(3) is None
    assert _ckpt(tmp_path / "empty").restore_aux() is None


def test_shard_files_written_atomically(tmp_path):
    ckpt = _ckpt(tmp_path, async_save=False)
    ckpt.save(1, _state())
    step_dir = tmp_path / "step_00000001"
    leftovers = [f for f in os.listdir(step_dir) if ".tmp" in f]
    assert not leftovers, leftovers
    with open(step_dir / "index.json") as f:
        assert json.load(f)["step"] == 1


# -------------------------------------------------------- gc + reshard restore


def test_gc_keeps_last_n_committed_steps(tmp_path):
    """Satellite: keep_last_n GC after each successful commit — older
    committed step dirs are deleted, never the newest, and the survivors
    still restore."""
    ckpt = _ckpt(tmp_path, async_save=False, keep_last_n=2)
    for step in range(5):
        ckpt.save(step, _state(step))
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000003", "step_00000004"]
    assert ckpt.latest_step() == 4
    restored = ckpt.restore(like=_state())
    np.testing.assert_array_equal(np.asarray(restored["step"]), 4)


def test_gc_collects_stale_uncommitted_debris_only(tmp_path):
    """Uncommitted dirs OLDER than the newest COMMITTED step are crash
    debris and get collected; an uncommitted dir at/beyond the newest commit
    may be an in-flight save and must be left alone."""
    ckpt = _ckpt(tmp_path, async_save=False, keep_last_n=10)
    ckpt.save(1, _state(1))
    os.makedirs(tmp_path / "step_00000000")  # torn older save
    (tmp_path / "step_00000000" / "shard_0.npz.tmp.npz").write_bytes(b"torn")
    os.makedirs(tmp_path / "step_00000004")  # "in-flight" newer save
    ckpt.save(3, _state(3))  # commit -> gc
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000001", "step_00000003", "step_00000004"]


def test_gc_runs_on_rank_zero_only(tmp_path):
    """One deleter per fleet: a non-zero rank must never GC (peers racing
    the same rmtree would trip each other)."""
    ckpt0 = _ckpt(tmp_path, async_save=False, keep_last_n=2)
    ckpt0.save(1, _state(1))
    ckpt0.save(2, _state(2))
    rank1 = _ckpt(tmp_path, process_index=1, process_count=2, keep_last_n=1)
    rank1._gc()
    dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert dirs == ["step_00000001", "step_00000002"]


def test_restore_aux_selects_other_ranks_file(tmp_path):
    """Resharding restore reads rank 0's aux regardless of own rank (the
    committing fleet may have been smaller than this one)."""
    state = _state()
    p0 = _ckpt(tmp_path, process_index=0, process_count=2, async_save=False)
    p1 = _ckpt(tmp_path, process_index=1, process_count=2, async_save=False)
    t = threading.Thread(target=lambda: p1.save(1, state, aux={"rank": 1}))
    t.start()
    p0.save(1, state, aux={"rank": 0})  # barrier: waits for p1's shard
    t.join(timeout=30)
    assert not t.is_alive()
    assert p1.restore_aux(1) == {"rank": 1}
    assert p1.restore_aux(1, process_index=0) == {"rank": 0}
    assert p0.restore_aux(1) == {"rank": 0}


def test_await_commit_times_out_when_committer_dies(tmp_path):
    """Non-zero ranks observe the barrier too: if process 0 never commits,
    the rank's save fails loudly instead of silently 'succeeding'."""
    p1 = _ckpt(tmp_path, process_index=1, process_count=2,
               commit_timeout_s=0.2)
    p1.save(1, _state())
    with pytest.raises(CheckpointWriteError, match="committer dead"):
        p1.wait()


def test_recommit_at_smaller_world_size_cleans_foreign_shards(tmp_path):
    """A step re-saved after restarting at a smaller world size: the commit
    sweeps shards/aux of ranks beyond the new process_count, so the
    COMMITTED dir is exactly its manifest."""
    state = _state()
    p0 = _ckpt(tmp_path, process_index=0, process_count=2, async_save=False)
    p1 = _ckpt(tmp_path, process_index=1, process_count=2, async_save=False)
    t = threading.Thread(target=lambda: p1.save(1, state, aux={"r": 1}))
    t.start()
    p0.save(1, state, aux={"r": 0})
    t.join(timeout=30)
    step_dir = tmp_path / "step_00000001"
    # Simulate the restart: wipe COMMITTED (as a torn re-save attempt dir
    # would lack it) and re-save the same step from a 1-process fleet.
    os.remove(step_dir / "COMMITTED")
    (step_dir / "shard_0.npz.tmp.npz").write_bytes(b"torn")
    solo = _ckpt(tmp_path, async_save=False)
    solo.save(1, state, aux={"r": "solo"})
    files = sorted(os.listdir(step_dir))
    assert files == ["COMMITTED", "aux_0.json", "index.json", "shard_0.npz"]
    assert solo.restore_aux(1) == {"r": "solo"}
    restored = solo.restore(1, like=state)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w0"]),
                                  np.asarray(state["params"]["w0"]))
