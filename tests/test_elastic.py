"""Elastic multi-host training drills: world-size-invariant numerics, the
file-backed collective, FSDP parameter sharding, and the fleet supervisor's
kill/reshard/resume scenarios with REAL worker subprocesses.

The fleet tests assert the tentpole acceptance property: a job trained at
world size P, killed at an exact step boundary, and restarted at world size
P' != P from the latest COMMITTED checkpoint produces a loss curve
IDENTICAL to an uninterrupted single-process reference — exact restore +
exactly-once data + canonical gradient fold, end to end across process
boundaries.
"""

import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.launch.distributed import DistributedTimeout, FileCollective
from repro.runtime.supervisor import (
    FleetFault,
    FleetSupervisor,
    assert_continuity,
    latest_committed_step,
)
from repro.trainer.train_step import (
    canonical_mean,
    combine_microbatch_grads,
    slice_microbatch,
)

STEPS = 10
CKPT_EVERY = 4
G = 2  # canonical microbatches: every tested world size divides it


def _sup(root, name, schedule, **kw):
    return FleetSupervisor(
        os.path.join(str(root), name), schedule=schedule, steps=STEPS,
        grad_microbatches=G,
        builder_kwargs={"steps": STEPS, "checkpoint_every_n": CKPT_EVERY},
        collective_timeout_s=30.0, **kw)


@pytest.fixture(scope="module")
def fleet_reference(tmp_path_factory):
    """The ground truth: one process, no faults, steps 0..STEPS-1."""
    result = _sup(tmp_path_factory.mktemp("fleet_ref"), "run", (1,)).run()
    assert sorted(result["losses"]) == list(range(STEPS))
    assert result["restarts"] == 0
    return result


# ----------------------------- unit: numerics --------------------------------


def test_slice_microbatch_rows_and_passthrough():
    batch = {"input_ids": np.arange(32).reshape(8, 4),
             "labels": np.arange(32, 64).reshape(8, 4),
             "positions": np.arange(4)}  # non-batch entry: passes through
    mb = slice_microbatch(batch, 1, 4)
    np.testing.assert_array_equal(mb["input_ids"], batch["input_ids"][2:4])
    np.testing.assert_array_equal(mb["labels"], batch["labels"][2:4])
    np.testing.assert_array_equal(mb["positions"], batch["positions"])
    # Microbatches tile the batch exactly.
    rows = np.concatenate([slice_microbatch(batch, m, 4)["input_ids"]
                           for m in range(4)])
    np.testing.assert_array_equal(rows, batch["input_ids"])
    with pytest.raises(ValueError, match="not divisible"):
        slice_microbatch(batch, 0, 3)


def test_combine_microbatch_grads_is_canonical_float32_fold():
    """The fold equals an explicit left-associative float32 accumulation,
    independent of the (bf16-ish) input dtype — the world-size-invariance
    workhorse."""
    rng = np.random.default_rng(0)
    G_ = 4
    per_mb = [[rng.standard_normal((3, 5)).astype(np.float32),
               rng.standard_normal(7).astype(np.float32)] for _ in range(G_)]
    treedef = None
    import jax

    flat, treedef = jax.tree_util.tree_flatten(
        {"a": per_mb[0][0], "b": per_mb[0][1]})
    combined = combine_microbatch_grads(
        [[mb[0], mb[1]] for mb in per_mb], treedef)
    for i, key in enumerate(["a", "b"]):
        acc = np.array(per_mb[0][i], np.float32, copy=True)
        for m in range(1, G_):
            acc += per_mb[m][i]
        acc *= np.float32(1.0 / G_)
        np.testing.assert_array_equal(np.asarray(combined[key]), acc)
    m = canonical_mean([np.float32([2.0, 4.0]), np.float32([4.0, 8.0])])
    np.testing.assert_array_equal(m, np.float32([3.0, 6.0]))


# ------------------------- unit: file collective -----------------------------


def test_file_collective_allgather_and_barrier(tmp_path):
    """Two threads rendezvous through the directory; payloads come back in
    rank order, bitwise, with per-rank key sets."""
    results = [None, None]

    def worker(rank):
        coll = FileCollective(str(tmp_path), process_index=rank,
                              process_count=2, timeout_s=20.0)
        for op in range(3):  # several ops: numbering + cleanup exercised
            payload = {f"r{rank}.op{op}": np.full((2, 2), rank * 10 + op,
                                                  np.float32)}
            results[rank] = coll.allgather(payload)
        coll.barrier()

    threads = [threading.Thread(target=worker, args=(r,)) for r in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=30)
        assert not t.is_alive()
    for rank in range(2):
        gathered = results[rank]
        assert len(gathered) == 2
        for src in range(2):
            np.testing.assert_array_equal(
                gathered[src][f"r{src}.op2"],
                np.full((2, 2), src * 10 + 2, np.float32))
    # Steady-state directory size is O(2N) files, not O(ops).
    assert len(os.listdir(tmp_path)) <= 8


def test_file_collective_dead_peer_times_out(tmp_path):
    coll = FileCollective(str(tmp_path), process_index=0, process_count=2,
                          timeout_s=0.2)
    with pytest.raises(DistributedTimeout, match="rank\\(s\\) \\[1\\]"):
        coll.allgather({"x": np.zeros(1)})


# --------------------------- fleet drills (subprocess) -----------------------


@pytest.mark.multiprocess
def test_fleet_two_process_run_matches_single_process(fleet_reference,
                                                      tmp_path):
    """World-size invariance, no faults: a 2-process fleet's loss curve is
    bitwise identical to the single-process reference."""
    result = _sup(tmp_path, "w2", (2,)).run()
    assert result["restarts"] == 0
    assert_continuity(result["losses"], fleet_reference["losses"])
    assert result["input_state"] == fleet_reference["input_state"]


@pytest.mark.multiprocess
def test_fleet_sigkill_reshard_2_to_1(fleet_reference, tmp_path):
    """Rank 1 of a 2-process fleet is SIGKILLed at step 5; the restart runs
    at world size 1 from the step-4 COMMITTED checkpoint and the merged
    curve matches the uninterrupted reference exactly."""
    sup = _sup(tmp_path, "kill21", (2, 1))
    result = sup.run(faults=[FleetFault(attempt=0, step=5, kind="sigkill",
                                        rank=1)])
    first = result["attempts"][0]
    assert first["outcome"] == "crash"
    assert first["world_size"] == 2
    assert first["resumed_from"] == 4
    assert result["attempts"][1]["world_size"] == 1
    assert result["restarts"] == 1
    assert_continuity(result["losses"], fleet_reference["losses"])
    assert result["input_state"] == fleet_reference["input_state"]
    # Fleet goodput aggregated across both attempts' ranks, with the
    # recomputed step time charged as lost.
    g = result["goodput"]
    assert g["num_streams"] == 3  # 2 ranks in attempt 0 + 1 in attempt 1
    assert 0.0 < g["fleet_goodput_fraction"] < 1.0


@pytest.mark.multiprocess
def test_fleet_sigkill_reshard_1_to_2(fleet_reference, tmp_path):
    """The opposite reshard: a single process dies at step 5 and the job
    restarts as a 2-process fleet from the same checkpoint."""
    sup = _sup(tmp_path, "kill12", (1, 2))
    result = sup.run(faults=[FleetFault(attempt=0, step=5, kind="sigkill",
                                        rank=0)])
    assert result["attempts"][0]["resumed_from"] == 4
    assert result["attempts"][1]["world_size"] == 2
    assert_continuity(result["losses"], fleet_reference["losses"])
    assert result["input_state"] == fleet_reference["input_state"]


@pytest.mark.multiprocess
def test_fleet_mid_save_kill_never_commits_torn_step(fleet_reference,
                                                     tmp_path):
    """Rank 1 dies INSIDE the checkpoint write of the step-8 save, leaving a
    torn tmp shard. COMMITTED must never appear for a step with a missing
    shard; the fleet falls back to the previous COMMITTED step (4), and the
    re-save of step 8 (by the restarted 1-process fleet) leaves a step dir
    that is exactly its manifest — no tmp debris, no foreign shards."""
    sup = _sup(tmp_path, "savekill", (2, 1))
    result = sup.run(faults=[FleetFault(attempt=0, step=8, kind="save_kill",
                                        rank=1)])
    first = result["attempts"][0]
    assert first["outcome"] == "crash"
    # The torn step-8 save never became COMMITTED: resume fell back to 4.
    assert first["resumed_from"] == 4
    assert_continuity(result["losses"], fleet_reference["losses"])

    ckpt_dir = sup.checkpoint_dir
    for dirpath, _, files in os.walk(ckpt_dir):
        for fname in files:
            assert ".tmp" not in fname, os.path.join(dirpath, fname)
    # Every COMMITTED step dir holds exactly its index's world-size worth of
    # shards (+aux) — the attempt-0 world-2 debris in step_8 was cleaned by
    # the world-1 re-commit.
    committed = [d for d in sorted(os.listdir(ckpt_dir))
                 if os.path.exists(os.path.join(ckpt_dir, d, "COMMITTED"))]
    assert committed, ckpt_dir
    for d in committed:
        step_dir = os.path.join(ckpt_dir, d)
        with open(os.path.join(step_dir, "index.json")) as f:
            index = json.load(f)
        shards = sorted(f for f in os.listdir(step_dir)
                        if f.startswith("shard_"))
        assert shards == [f"shard_{p}.npz"
                         for p in range(index["process_count"])], (d, shards)
    assert latest_committed_step(ckpt_dir) is not None


@pytest.mark.multiprocess
def test_fleet_sigterm_preempts_all_ranks_with_zero_lost_steps(
        fleet_reference, tmp_path):
    """A cluster preemption notice (SIGTERM drill) reaches every rank at
    step 6: all exit 143 after an emergency save commits through the
    cross-process barrier; the restart loses ZERO steps."""
    sup = _sup(tmp_path, "term", (2,))
    result = sup.run(faults=[FleetFault(attempt=0, step=6, kind="sigterm")])
    first = result["attempts"][0]
    assert first["outcome"] == "preempt"
    assert first["exit_codes"] == [143, 143]
    # The hook set the event after step 6 completed, so the emergency save
    # committed label 7 ("next step to run" — same convention as periodic
    # saves): steps 0..6 are all preserved.
    assert first["resumed_from"] == 7
    assert all(p["committed"] for p in first["preempted"])
    assert_continuity(result["losses"], fleet_reference["losses"])
    # Zero lost steps -> nothing charged to restart_loss.
    assert result["goodput"]["lost_s"] == 0.0


# ------------------------------- FSDP sharding -------------------------------


FSDP_SUBPROCESS = r"""
import jax
import numpy as np

from repro.core.config import config_for_function, update_configs_recursively
from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
from repro.trainer import optimizers as opt_lib
from repro.trainer.mesh_rules import FsdpModifier
from repro.trainer.trainer import SpmdTrainer

assert len(jax.devices()) == 4

# Baseline = fully replicated params (clear the model's own data-axis
# partitions) so the measured shrink is attributable to FsdpModifier alone.
PART_FIELDS = ["weight_partition", "qkv_weight_partition",
               "out_weight_partition", "up_weight_partition",
               "down_weight_partition", "gate_weight_partition"]


def make(fsdp):
    layer = TransformerLayer.default_config().set(input_dim=32)
    layer.self_attention.set(num_heads=4, num_kv_heads=2)
    layer.feed_forward.set(hidden_dim=64)
    model = CausalLM.default_config().set(
        decoder=Decoder.default_config().set(
            vocab_size=32, dim=32,
            stack=Repeat.default_config().set(
                layer=layer, num_layers=2, remat_policy=None)))
    cfg = SpmdTrainer.default_config().set(
        name="t", model=model, max_steps=2, log_every_n=1, seed=1,
        mesh_shape=(4,), mesh_axis_names=("data",))
    update_configs_recursively(cfg.model, {f: None for f in PART_FIELDS})
    cfg.input.set(task="lm", vocab_size=32, seq_len=16, global_batch_size=8)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=1e-2)
    if fsdp:
        cfg = FsdpModifier.default_config().set(
            axes=("data",)).instantiate().apply(cfg)
        assert cfg.fsdp_axes == ("data",)
    return cfg


def per_device_param_bytes(state, shardings):
    total = 0
    for leaf, sh in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(shardings["params"])):
        total += int(np.prod(sh.shard_shape(leaf.shape))) * leaf.dtype.itemsize
    return total


out = {}
for fsdp in (False, True):
    trainer = make(fsdp).instantiate()
    res = trainer.run()
    state = res["state"]
    shardings = trainer.state_shardings(jax.eval_shape(lambda: state))
    for leaf, sh in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(shardings["params"])):
        assert leaf.sharding == sh, (leaf.shape, leaf.sharding, sh)
    out[fsdp] = (per_device_param_bytes(state, shardings),
                 float(res["final"]["loss"]))
ratio = out[False][0] / out[True][0]
assert ratio > 2.0, f"FSDP saved only {ratio:.2f}x on a 4-way data mesh"
assert abs(out[False][1] - out[True][1]) < 1e-4, out
print(f"OK ratio={ratio:.3f}")
"""


def test_fsdp_modifier_shards_params_on_multidevice_mesh():
    """Per-device parameter bytes shrink on a 4-device data mesh under
    FsdpModifier, with losses identical to the replicated run. Subprocess so
    the forced 4-CPU-device topology can't leak into the suite."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4").strip()
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = (os.path.abspath(src) + os.pathsep
                         + env.get("PYTHONPATH", ""))
    proc = subprocess.run([sys.executable, "-c", FSDP_SUBPROCESS],
                          env=env, capture_output=True, text=True,
                          timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "OK ratio=" in proc.stdout
