"""Golden-configuration tests (paper §7.3).

"Key training configs are serialized into human-readable format and
committed along with code changes" — config drift across the 10 assigned
architectures produces reviewable diffs instead of silent experiment
changes. Regenerate after INTENDED changes with:

    PYTHONPATH=src python tests/test_golden_configs.py --regen
"""

import json
import os
import sys

import pytest

from repro.configs import registry
from repro.core.config import config_to_dict

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


def _golden_path(arch):
    return os.path.join(GOLDEN_DIR, f"{arch}.json")


def _serialize(arch):
    spec = registry.get_spec(arch)
    d = config_to_dict(spec.make_model())
    return json.dumps(d, indent=1, sort_keys=True, default=str)


@pytest.mark.parametrize("arch", registry.ASSIGNED_ARCHS)
def test_golden_config(arch):
    path = _golden_path(arch)
    if not os.path.exists(path):
        pytest.skip(f"no golden file for {arch}; run --regen")
    with open(path) as f:
        golden = f.read()
    current = _serialize(arch)
    assert current == golden, (
        f"{arch} config drifted from golden snapshot. If intended, regen: "
        "PYTHONPATH=src python tests/test_golden_configs.py --regen")


def test_golden_files_cover_all_archs():
    missing = [a for a in registry.ASSIGNED_ARCHS
               if not os.path.exists(_golden_path(a))]
    assert not missing, f"goldens missing for {missing}"


if __name__ == "__main__":
    if "--regen" in sys.argv:
        os.makedirs(GOLDEN_DIR, exist_ok=True)
        for arch in registry.ASSIGNED_ARCHS:
            with open(_golden_path(arch), "w") as f:
                f.write(_serialize(arch))
            print(f"[golden] wrote {arch}")
