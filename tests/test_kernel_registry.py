"""Kernel registry: capability-based dispatch (op × platform × feature
matrix), rejection-reason errors, explicit-override precedence, and the
compile-count guard proving the memoized dispatch adds no retraces on the
decode/train hot paths."""

import dataclasses
import glob
import os
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.module import functional
from repro.kernels import ops, ref
from repro.kernels import registry as reg
from repro.kernels.registry import (
    KernelConfig,
    KernelDispatchError,
    KernelFeatures,
    KernelSpec,
)


def feats(platform="cpu", **kw):
    return KernelFeatures(platform=platform, **kw)


# ------------------------- resolution matrix ---------------------------------


# (op, platform, feature overrides) -> expected backend under "auto".
AUTO_MATRIX = [
    # attention.fwd: pallas on TPU, blockwise elsewhere; ragged/1-token and
    # grad-carrying calls stay capability-routed.
    ("attention.fwd", "cpu", {}, "blockwise"),
    ("attention.fwd", "tpu", {}, "pallas"),
    ("attention.fwd", "gpu", {}, "blockwise"),
    ("attention.fwd", "tpu", {"needs_grad": True}, "pallas"),  # custom_vjp
    ("attention.fwd", "tpu", {"ragged_positions": True}, "blockwise"),
    ("attention.fwd", "tpu", {"single_query": True}, "blockwise"),
    ("attention.fwd", "cpu", {"interpret": True}, "pallas:interpret"),
    ("attention.fwd", "tpu", {"sliding_window": True}, "pallas"),
    # attention.decode: pallas needs a replicated cache; paged stays pallas.
    ("attention.decode", "cpu", {}, "ref"),
    ("attention.decode", "tpu", {}, "pallas"),
    ("attention.decode", "tpu", {"paged": True}, "pallas"),
    ("attention.decode", "tpu", {"replicated_cache": False}, "ref"),
    ("attention.decode", "cpu", {"interpret": True}, "pallas:interpret"),
    # rmsnorm / wkv6: forward-only kernels reject training.
    ("rmsnorm", "tpu", {}, "pallas"),
    ("rmsnorm", "tpu", {"needs_grad": True}, "ref"),
    ("rmsnorm", "cpu", {}, "ref"),
    ("wkv6", "tpu", {}, "pallas"),
    ("wkv6", "tpu", {"needs_grad": True}, "ref"),
    ("wkv6", "cpu", {"interpret": True}, "pallas:interpret"),
    ("wkv6", "gpu", {}, "ref"),
]


@pytest.mark.parametrize("op,platform,overrides,expected", AUTO_MATRIX)
def test_auto_resolution_matrix(op, platform, overrides, expected):
    spec = reg.resolve(op, feats(platform, **overrides))
    assert spec.backend == expected, (op, platform, overrides)


def test_registered_backends_priority_order():
    assert reg.registered_backends("attention.fwd") == [
        "pallas", "pallas:interpret", "blockwise", "ref"]
    assert set(reg.registered_ops()) == {
        "attention.fwd", "attention.decode", "rmsnorm", "wkv6",
        "wkv6.decode"}


# --------------------- rejection reasons / errors ----------------------------


def test_error_lists_every_candidate_with_reason():
    """The debuggability contract: a failed resolve enumerates each
    candidate backend and why it was rejected."""
    with pytest.raises(KernelDispatchError) as e:
        reg.resolve("attention.decode",
                    feats("cpu", replicated_cache=False), backend="pallas")
    msg = str(e.value)
    for backend in reg.registered_backends("attention.decode"):
        assert backend in msg, f"candidate {backend} missing from error"
    assert "requires platform" in msg
    assert "excluded by explicit backend" in msg


def test_error_on_unknown_op_and_backend():
    with pytest.raises(KernelDispatchError, match="registered ops"):
        reg.resolve("attention.bwd", feats())
    with pytest.raises(KernelDispatchError, match="registered backends"):
        reg.resolve("attention.fwd", feats(), backend="cudnn")


def test_sharded_cache_rejection_reason_is_actionable():
    with pytest.raises(KernelDispatchError, match="replicated KV cache"):
        reg.resolve("attention.decode",
                    feats("tpu", replicated_cache=False), backend="pallas")


def test_unavailable_spec_surfaces_import_reason():
    """Satellite: kernel availability is explicit at import time — an
    unavailable backend carries the real import error into resolution
    messages instead of a silent ref fallback."""
    spec = KernelSpec(op="attention.fwd", backend="nki", fn=None,
                      platforms=("*",), priority=200, available=False,
                      unavailable_reason="ModuleNotFoundError: neuronxcc")
    reg.register(spec)
    try:
        # Auto skips it (with the reason recorded)...
        assert reg.resolve("attention.fwd", feats()).backend == "blockwise"
        # ...and an explicit request fails WITH the import error.
        with pytest.raises(KernelDispatchError,
                           match="ModuleNotFoundError: neuronxcc"):
            reg.resolve("attention.fwd", feats(), backend="nki")
    finally:
        del reg._REGISTRY["attention.fwd"]["nki"]
        reg.clear_dispatch_cache()


def test_wkv6_pallas_registered_available_with_fn():
    """The in-tree wkv6 kernel imports cleanly here: the registry must have
    it available (the old `except ImportError` hid real failures)."""
    spec = reg._REGISTRY["wkv6"]["pallas"]
    assert spec.available and spec.fn is not None


# ------------------------ explicit-override precedence -----------------------


def test_explicit_backend_overrides_auto_priority():
    s = reg.resolve("attention.fwd", feats("cpu"), backend="ref")
    assert s.backend == "ref"


def test_op_overrides_beat_layer_backend():
    cfg = KernelConfig().set(backend="ref",
                             op_overrides={"attention.decode": "blockwise"})
    assert cfg.backend_for("attention.fwd") == "ref"
    assert cfg.backend_for("attention.decode") == "blockwise"


def test_interpret_normalizes_explicit_pallas():
    cfg = KernelConfig().set(backend="pallas", interpret=True)
    assert cfg.backend_for("attention.fwd") == "pallas:interpret"
    cfg2 = KernelConfig().set(backend="pallas")
    assert cfg2.backend_for("attention.fwd") == "pallas"


def test_explicit_waives_heuristics_not_correctness():
    # single_query is a perf heuristic: waived for explicit requests.
    s = reg.resolve("attention.fwd", feats("tpu", single_query=True),
                    backend="pallas")
    assert s.backend == "pallas"
    # ragged positions are a correctness bound: never waived.
    with pytest.raises(KernelDispatchError, match="not provably identical"):
        reg.resolve("attention.fwd", feats("tpu", ragged_positions=True),
                    backend="pallas")


def test_layerwide_backend_falls_back_for_unregistered_ops():
    """A layer-wide backend is a preference across heterogeneous ops: ops
    that don't register it resolve via auto instead of erroring (the old
    impl="blockwise"/"pallas" configs kept decoding through ref)."""
    # attention.decode has no "blockwise" backend -> auto -> ref on CPU.
    cfg = KernelConfig().set(backend="blockwise")
    spec = reg.resolve_backend("attention.decode", feats("cpu"), cfg)
    assert spec.backend == "ref"
    # wkv6.decode is ref-only; layer-wide pallas(:interpret) falls back.
    cfg = KernelConfig().set(backend="pallas", interpret=True)
    spec = reg.resolve_backend("wkv6.decode", feats("cpu"), cfg)
    assert spec.backend == "ref"
    # Per-op overrides name the op: unknown backends there ARE config bugs.
    cfg = KernelConfig().set(op_overrides={"wkv6.decode": "pallas"})
    with pytest.raises(KernelDispatchError, match="registered backends"):
        reg.resolve_backend("wkv6.decode", feats("cpu"), cfg)


def test_rwkv_layerwide_pallas_backend_generates():
    """End-to-end repro of the layer-wide-backend crash: an RWKV mixer with
    kernel backend="pallas" (the documented impl="pallas" migration) must
    still decode — its recurrent step is ref-only."""
    from repro.layers.rwkv import RWKV6TimeMix

    cfg = RWKV6TimeMix.default_config().set(
        name="tm", input_dim=32, head_dim=16, decay_lora_dim=8,
        kernel=KernelConfig().set(backend="pallas", interpret=True,
                                  wkv_chunk_size=4))
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, 32)) * 0.1
    cache, _ = functional(layer, state=state, inputs=(1, 8),
                          method="init_states")
    (cache, y), _ = functional(
        layer, state=state, inputs={"state": cache, "x": x},
        method="prefill")
    (cache, y1), _ = functional(
        layer, state=state, inputs={"state": cache, "x_step": x[:, :1]},
        method="extend_step")
    assert np.isfinite(np.asarray(y1)).all()


def test_interpret_backend_never_auto_selected_without_flag():
    s = reg.resolve("attention.decode", feats("cpu"))
    assert s.backend == "ref"
    # But explicitly selectable even with interpret=False.
    s = reg.resolve("attention.decode", feats("cpu"),
                    backend="pallas:interpret")
    assert s.backend == "pallas:interpret"


# ------------------------------ numerics -------------------------------------


def test_dispatched_backends_agree_numerically():
    """Every eligible attention.fwd backend (on this platform) produces the
    same output for the same inputs."""
    B, S, H, D = 2, 64, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    expect = ref.reference_attention(q, k, v)
    for backend in ("ref", "blockwise", "pallas:interpret"):
        out = ops.flash_attention(
            q, k, v, kernel=KernelConfig().set(backend=backend,
                                               blockwise_chunk_size=16))
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=2e-5, rtol=2e-5,
                                   err_msg=f"backend={backend}")


# ------------------------- dispatch cache / retraces -------------------------


def test_resolve_is_memoized():
    reg.clear_dispatch_cache()
    f = feats("cpu", dtype="bfloat16")
    s1 = reg.resolve("attention.fwd", f)
    stats0 = reg.dispatch_cache_stats()
    for _ in range(100):
        s2 = reg.resolve("attention.fwd", f)
    assert s2 is s1
    stats1 = reg.dispatch_cache_stats()
    assert stats1["hits"] >= stats0["hits"] + 100
    assert stats1["misses"] == stats0["misses"]


def _tiny_attn(S=16, **kernel_kw):
    from repro.layers import MultiheadAttention

    cfg = MultiheadAttention.default_config().set(
        name="a", input_dim=32, num_heads=4, num_kv_heads=2,
        kv_cache_dtype=jnp.float32)
    if kernel_kw:
        cfg.set(kernel=KernelConfig().set(**kernel_kw))
    layer = cfg.instantiate()
    state = layer.initialize_parameters_recursively(jax.random.PRNGKey(0))
    return layer, state


def test_decode_hot_path_compiles_once():
    """Compile-count guard: repeated decode steps through registry dispatch
    reuse ONE compiled program — resolution happens at trace time and the
    memo cache keeps it off the step path."""
    layer, state = _tiny_attn()
    cache, _ = functional(layer, state=state, inputs=(2, 16),
                          method="init_states")

    @jax.jit
    def step(state, cache, x):
        (cache, y), _ = functional(
            layer, state=state, inputs={"state": cache, "x_step": x},
            method="extend_step")
        return cache, y

    x = jnp.ones((2, 1, 32))
    for _ in range(4):
        cache, _ = step(state, cache, x)
    assert step._cache_size() == 1, "decode hot path retraced"


def test_train_hot_path_compiles_once():
    layer, state = _tiny_attn()

    @jax.jit
    def loss_grad(state, x):
        def loss(s):
            out, _ = functional(layer, state=s, inputs=(x,),
                                is_training=True)
            return jnp.sum(out ** 2)

        return jax.grad(loss)(state)

    x = jnp.ones((2, 16, 32))
    for _ in range(3):
        loss_grad(state, x)
    assert loss_grad._cache_size() == 1, "train hot path retraced"


# --------------------------- layer-level contract ----------------------------


def test_no_impl_string_branching_in_layers():
    """Acceptance criterion: no `impl`-string branching remains anywhere in
    src/repro/layers/ — every kernel call site goes through the registry."""
    layers_dir = os.path.join(os.path.dirname(__file__), os.pardir,
                              "src", "repro", "layers", "*.py")
    pattern = re.compile(r"""\bimpl\s*[=!]=|cfg\.impl\b|\bdecode_impl\b"""
                         r"""|\bkernel_interpret\b""")
    offenders = []
    for path in glob.glob(layers_dir):
        for i, line in enumerate(open(path), 1):
            if pattern.search(line):
                offenders.append(f"{os.path.basename(path)}:{i}: {line.strip()}")
    assert not offenders, "\n".join(offenders)


def test_kernel_modifier_is_ten_line_backend_story():
    """The paper's claim, end to end: adding a hypothetical GPU backend is
    one register() call + one mesh rule — zero layer edits."""
    from repro.trainer.mesh_rules import KernelModifier

    calls = []

    def fake_cudnn(q, k, v, *, q_positions, k_positions, causal,
                   sliding_window, logit_softcap, scale, cfg):
        calls.append("cudnn")
        return ref.reference_attention(
            q, k, v, q_positions=q_positions, k_positions=k_positions,
            causal=causal, sliding_window=sliding_window,
            logit_softcap=logit_softcap, scale=scale)

    reg.register(KernelSpec(op="attention.fwd", backend="cudnn",
                            fn=fake_cudnn, platforms=("gpu", "cpu"),
                            priority=80))
    try:
        layer, state = _tiny_attn()
        mod = KernelModifier.default_config().set(
            op_overrides={"attention.fwd": "cudnn"}).instantiate()
        cfg2 = mod.apply(layer.config.clone())
        layer2 = cfg2.instantiate()
        x = jnp.ones((1, 8, 32))
        out2, _ = functional(layer2, state=state, inputs=(x,))
        assert calls == ["cudnn"]
        out1, _ = functional(layer, state=state, inputs=(x,))
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2),
                                   atol=2e-5, rtol=2e-5)
    finally:
        del reg._REGISTRY["attention.fwd"]["cudnn"]
        reg.clear_dispatch_cache()
