"""Unified observability: registry semantics, trace schema, trainer/serving
instrumentation, zero-retrace + overhead budgets, fleet trace merge."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import config_for_function
from repro.observability import (
    MemorySink,
    build_observability,
    MetricsRegistry,
    ObservabilityConfig,
    ProfilerWindow,
    Tracer,
    compiled_cost,
    estimate_mfu,
    load_trace,
    merge_traces,
    validate_chrome_trace,
)
from repro.observability.metrics import RECORD_BASE_FIELDS, JsonlSink
from repro.runtime.goodput import GoodputMonitor


# ------------------------------- registry ------------------------------------


def test_registry_instruments_get_or_create():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.gauge("b") is reg.gauge("b")
    assert reg.histogram("c") is reg.histogram("c")
    reg.counter("a").inc(3)
    reg.gauge("b").set(2.5)
    snap = reg.snapshot()
    assert snap["counters"]["a"]["value"] == 3
    assert snap["gauges"]["b"] == {"value": 2.5, "updates": 1}


def test_histogram_reservoir_bounded_and_representative():
    reg = MetricsRegistry(reservoir_size=64)
    h = reg.histogram("lat")
    for i in range(10_000):
        h.record(float(i))
    snap = h.snapshot()
    # Exact aggregates regardless of sampling; memory stays at the bound.
    assert snap["count"] == 10_000
    assert snap["min"] == 0.0 and snap["max"] == 9999.0
    assert snap["reservoir_len"] == 64
    assert len(h.values) == 64
    # Uniform stream: the sampled median lands near the true median.
    assert 2000.0 < snap["p50"] < 8000.0
    assert snap["p99"] >= snap["p90"] >= snap["p50"]


def test_jsonl_sink_stable_schema(tmp_path):
    path = str(tmp_path / "m.jsonl")
    reg = MetricsRegistry(sinks=[JsonlSink(path)])
    reg.counter("requests").inc()
    reg.gauge("depth").set(4)
    reg.histogram("lat").record(0.01)
    reg.record_event("fault", rank=1, error="boom")
    reg.close()
    records = [json.loads(line) for line in open(path)]
    assert len(records) == 4  # 1 event (immediate) + 3 instruments (flush)
    kinds = {r["kind"] for r in records}
    assert kinds == {"event", "counter", "gauge", "histogram"}
    for r in records:
        for field in RECORD_BASE_FIELDS:
            assert field in r, r
        assert r["schema"] == 1
    ev = next(r for r in records if r["kind"] == "event")
    assert ev["name"] == "fault" and ev["rank"] == 1


def test_goodput_monitor_adopts_registry_schema():
    sink = MemorySink()
    reg = MetricsRegistry(sinks=[sink])
    monitor = GoodputMonitor(sink=reg.goodput_sink())
    with monitor.bucket("step", step=7):
        pass
    monitor.add_event("restart_loss", 1.5, virtual=True)
    names = [r["name"] for r in sink.records]
    assert names == ["goodput/step", "goodput/restart_loss"]
    step_ev = sink.records[0]
    assert step_ev["kind"] == "event" and step_ev["step"] == 7
    assert "dur_s" in step_ev and "t_start" in step_ev


# -------------------------------- tracing ------------------------------------


def test_tracer_emits_valid_chrome_trace(tmp_path):
    tracer = Tracer(pid=3, process_name="rank 3")
    with tracer.span("outer", step=1):
        with tracer.span("inner"):
            pass
    tracer.instant("fault")
    tracer.counter("queue_depth", 5)
    path = tracer.save(str(tmp_path / "t.json"))
    stats = validate_chrome_trace(load_trace(path))
    assert stats["num_spans"] == 2
    assert stats["pids"] == [3]
    names = {e["name"] for e in load_trace(path)["traceEvents"]}
    assert {"outer", "inner", "fault", "queue_depth",
            "process_name"} <= names


def test_validate_rejects_partial_overlap_and_bad_events():
    bad = {"traceEvents": [
        {"name": "a", "ph": "X", "pid": 0, "tid": 0, "ts": 0.0, "dur": 10.0},
        {"name": "b", "ph": "X", "pid": 0, "tid": 0, "ts": 5.0, "dur": 10.0},
    ]}
    with pytest.raises(ValueError, match="partially overlaps"):
        validate_chrome_trace(bad)
    with pytest.raises(ValueError, match="missing required key"):
        validate_chrome_trace({"traceEvents": [{"ph": "X"}]})
    with pytest.raises(ValueError, match="traceEvents"):
        validate_chrome_trace({})


def test_merge_traces_keeps_per_rank_lanes(tmp_path):
    paths = []
    for rank in range(2):
        t = Tracer(pid=rank, process_name=f"rank {rank}")
        with t.span("step", step=0):
            pass
        paths.append(t.save(str(tmp_path / f"r{rank}.json")))
    out = str(tmp_path / "merged.json")
    merged = merge_traces(paths, out_path=out)
    stats = validate_chrome_trace(merged)
    assert stats["pids"] == [0, 1] and stats["num_spans"] == 2
    # Restart attempts re-emit identical process metadata: merge dedups it.
    remerged = merge_traces([out, paths[0]])
    metas = [e for e in remerged["traceEvents"]
             if e.get("ph") == "M" and e["pid"] == 0]
    assert len(metas) == 1


# ------------------------------- hardware ------------------------------------


def test_compiled_cost_and_mfu():
    fn = jax.jit(lambda x: (x @ x).sum())
    compiled = fn.lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
    cost = compiled_cost(compiled)
    # 64^3 multiply-adds: XLA reports ~2*64^3 flops.
    assert cost["flops"] and cost["flops"] >= 2 * 64**3 * 0.5
    mfu = estimate_mfu(cost["flops"], 1e-3, peak_flops_per_device=1e9)
    assert mfu == pytest.approx(cost["flops"] / 1e-3 / 1e9)
    assert estimate_mfu(None, 1e-3) is None
    assert estimate_mfu(1e6, 0.0) is None
    # The denominator scales with device count.
    assert estimate_mfu(1e6, 1.0, num_devices=2, peak_flops_per_device=1e6
                        ) == pytest.approx(0.5)


def test_profiler_window_state_machine(tmp_path):
    w = ProfilerWindow("", start_step=0, stop_step=0)
    assert not w.enabled  # no logdir -> inert
    w.on_step_start(0)
    assert not w.active
    with pytest.raises(ValueError, match="precedes"):
        ProfilerWindow(str(tmp_path), start_step=5, stop_step=3)
    w = ProfilerWindow(str(tmp_path), start_step=1, stop_step=2)
    w.on_step_start(0)
    assert not w.active
    w.on_step_start(1)  # window opens (or records the backend's refusal)
    w.on_step_end(1)
    assert not w.captured or w.error or not w.active
    w.on_step_start(2)
    w.on_step_end(2)
    w.close()
    assert w.captured and not w.active
    # One-shot: a later step never re-opens the window.
    w.on_step_start(3)
    assert not w.active


# --------------------------- trainer integration -----------------------------


def _tiny_trainer_cfg(steps=6, observability=None):
    from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer
    from repro.trainer import optimizers as opt_lib
    from repro.trainer.trainer import SpmdTrainer

    dim = 32
    layer = TransformerLayer.default_config().set(input_dim=dim)
    layer.self_attention.set(num_heads=4, num_kv_heads=2)
    layer.feed_forward.set(hidden_dim=2 * dim)
    model = CausalLM.default_config().set(
        decoder=Decoder.default_config().set(
            vocab_size=32, dim=dim,
            stack=Repeat.default_config().set(layer=layer, num_layers=2,
                                              remat_policy=None)))
    cfg = SpmdTrainer.default_config().set(
        name="t_obs", model=model, max_steps=steps, log_every_n=2,
        observability=observability)
    cfg.input.set(task="lm", vocab_size=32, seq_len=16, global_batch_size=8)
    cfg.learner.optimizer = config_for_function(opt_lib.adamw).set(
        peak_lr=1e-2)
    return cfg


@pytest.fixture(scope="module")
def trainer_run(tmp_path_factory):
    """One instrumented run shared by the trainer-integration tests."""
    tmp = tmp_path_factory.mktemp("obs")
    obs = ObservabilityConfig(metrics_path=str(tmp / "metrics.jsonl"),
                              trace_path=str(tmp / "trace.json"))
    trainer = _tiny_trainer_cfg(steps=6, observability=obs).instantiate()
    result = trainer.run()
    return trainer, result, obs


def test_trainer_trace_has_per_step_spans(trainer_run):
    _, _, obs = trainer_run
    stats = validate_chrome_trace(load_trace(obs.trace_path))
    events = load_trace(obs.trace_path)["traceEvents"]
    step_spans = [e for e in events
                  if e.get("ph") == "X" and e["name"] == "step"]
    # max_steps=6: one compile span (step 0) + five warm step spans.
    assert len(step_spans) == 5
    assert {e["args"]["step"] for e in step_spans} == {1, 2, 3, 4, 5}
    assert any(e["name"] == "compile" for e in events if e.get("ph") == "X")
    assert any(e["name"] == "input_stall" for e in events
               if e.get("ph") == "X")
    assert stats["pids"] == [0]


def test_trainer_summaries_routed_to_registry(trainer_run):
    trainer, result, _ = trainer_run
    snap = result["telemetry"]
    gauges = snap["gauges"]
    # add_summary values (model accuracy/loss) now leave OutputCollection.
    assert gauges["summaries/accuracy"]["value"] is not None
    assert gauges["train/loss"]["value"] == pytest.approx(
        result["final"]["loss"])
    assert gauges["train/grad_norm"]["value"] > 0
    assert gauges["train/param_norm"]["value"] > 0
    assert gauges["train/update_norm"]["value"] > 0
    assert snap["histograms"]["train/step_time_s"]["count"] >= 2
    assert gauges["train/tokens_per_s"]["value"] > 0
    assert gauges["train/tokens_per_s_per_device"]["value"] > 0


def test_trainer_mfu_and_step_cost(trainer_run):
    trainer, result, _ = trainer_run
    cost = result["step_cost"]
    assert cost["flops"] > 0 and cost["peak_hbm_proxy_bytes"] > 0
    gauges = result["telemetry"]["gauges"]
    assert 0 < gauges["hardware/mfu"]["value"]
    assert gauges["hardware/step_flops"]["value"] == cost["flops"]
    # Memoized: the extra lower+compile happens once.
    assert trainer.step_cost_analysis() is trainer.step_cost_analysis()


def test_trainer_metrics_jsonl_valid(trainer_run):
    _, _, obs = trainer_run
    records = [json.loads(line) for line in open(obs.metrics_path)]
    assert records, "metrics sink is empty"
    assert all(r["schema"] == 1 and "kind" in r and "name" in r
               for r in records)
    # Goodput buckets adopted the registry schema (satellite a of the
    # unified stream): step events appear as goodput/step events.
    assert any(r["name"] == "goodput/step" and r["kind"] == "event"
               for r in records)
    assert any(r["name"] == "train/loss" and r["kind"] == "gauge"
               for r in records)


def test_trainer_zero_retrace_with_observability_on(trainer_run):
    trainer, _, _ = trainer_run
    # Instrumentation lives outside jit: the train step compiled exactly
    # once even with metrics + tracing + MFU hooks armed. (The MFU AOT
    # lower+compile is a separate executable, not a _jit_step retrace.)
    assert trainer._jit_step._cache_size() == 1, \
        "observability instrumentation caused a retrace"


def test_trainer_without_observability_unchanged():
    trainer = _tiny_trainer_cfg(steps=2).instantiate()
    result = trainer.run()
    assert result["telemetry"] is None
    assert trainer.observability is None


# --------------------------- serving integration -----------------------------


def _gateway(observability=None, **kw):
    from tests.test_serving import _engine, _tiny_lm

    from repro.serving import ServingGateway

    engine = _engine(_tiny_lm("paged", num_pages=17, page=8), max_len=32,
                     slots=4)
    return ServingGateway(engine, prefill_chunk=8,
                          observability=observability, **kw)


def test_gateway_bounded_telemetry_preserves_percentile_api():
    from repro.serving import SamplingParams

    gw = _gateway(max_done_results=3)
    for i in range(8):
        gw.submit(np.arange(1, 5) % 3 + 1,
                  sampling=SamplingParams(max_new_tokens=4))
    gw.drain()
    # Retention is bounded: completed results and their token queues retire
    # FIFO past the cap — no per-request growth for the process lifetime.
    assert len(gw.scheduler._done) <= 3
    assert len(gw._queues) <= 3
    m = gw.metrics()
    for key in ("queue_depth", "running", "block_utilization", "completed",
                "timeouts", "preemptions", "restores", "prefill_chunks",
                "decode_steps", "max_concurrent", "tokens_out",
                "tokens_per_s", "ttft_p50_s", "ttft_p99_s", "tpot_p50_s",
                "tpot_p99_s"):
        assert key in m, key
    assert m["completed"] == 8  # counters survive result retirement
    assert m["ttft_p50_s"] > 0 and m["tpot_p50_s"] > 0
    assert m["ttft_p99_s"] >= m["ttft_p50_s"]
    # ...and the reservoirs saw every completed request.
    assert gw.registry.histogram("serving/ttft_s").count == 8


def test_gateway_request_lifecycle_spans(tmp_path):
    obs_cfg = ObservabilityConfig(trace_path=str(tmp_path / "serve.json"))
    obs = build_observability(obs_cfg)
    gw = _gateway(observability=obs)
    rids = [gw.submit(np.arange(1, 6), priority=p) for p in (0, 1)]
    gw.drain()
    obs.save_trace()
    trace = load_trace(obs_cfg.trace_path)
    validate_chrome_trace(trace)
    spans = [e for e in trace["traceEvents"] if e.get("ph") == "X"]
    by_name = {}
    for e in spans:
        by_name.setdefault(e["name"], []).append(e)
    # Lifecycle spans per request on the request's own tid lane...
    for name in ("queued", "prefill", "decode"):
        assert {e["tid"] for e in by_name[name]} == set(rids), name
    # ...plus live chunk/decode spans and queue counter samples.
    assert by_name["prefill_chunk"] and by_name["decode_step"]
    assert any(e["name"] == "queue_depth" and e["ph"] == "C"
               for e in trace["traceEvents"])
    # Per-step gauges landed in the shared registry.
    snap = obs.registry.snapshot()
    assert "serving/queue_depth" in snap["gauges"]
    assert "serving/page_pool_utilization" in snap["gauges"]


def test_serving_instrumentation_zero_retrace():
    obs = build_observability(ObservabilityConfig(trace_path="unused.json"))
    gw = _gateway(observability=obs)
    engine = gw.scheduler.engine
    for _ in range(2):
        gw.submit(np.arange(1, 6))
    gw.drain()
    compiles = {k: fn._cache_size() for k, fn in engine._jit_fns.items()}
    for _ in range(3):
        gw.submit(np.arange(1, 6))
    gw.drain()
    after = {k: fn._cache_size() for k, fn in engine._jit_fns.items()}
    assert after == compiles, "instrumented serving loop retraced"
    assert all(v == 1 for v in after.values())


# ------------------------------ fleet merge ----------------------------------


def test_fleet_two_process_merged_trace(tmp_path):
    """2-rank fleet -> ONE merged Chrome trace: per-rank pid lanes,
    per-step spans on each, valid against the trace-event schema, plus the
    step-boundary straggler gauge (the issue's acceptance gate)."""
    from repro.runtime.supervisor import FleetSupervisor

    sup = FleetSupervisor(
        str(tmp_path), schedule=(2,), steps=4, grad_microbatches=2,
        trace=True, builder_kwargs={"steps": 4, "checkpoint_every_n": 4})
    res = sup.run()
    assert res["trace_path"] and os.path.exists(res["trace_path"])
    trace = load_trace(res["trace_path"])
    stats = validate_chrome_trace(trace)
    assert stats["pids"] == [0, 1]
    for rank in (0, 1):
        steps = {e["args"]["step"] for e in trace["traceEvents"]
                 if e.get("ph") == "X" and e["name"] in ("step", "compile")
                 and e["pid"] == rank}
        assert steps == {0, 1, 2, 3}, f"rank {rank} missing step spans"
    skew = res["straggler"]
    assert skew["num_steps"] > 0
    assert skew["max_skew_s"] >= skew["mean_skew_s"] >= 0


def test_step_boundary_skew_math():
    from repro.runtime.supervisor import step_boundary_skew

    events = {
        (0, 0): [{"bucket": "step", "step": 1, "t_start": 10.0, "dur_s": 1.0},
                 {"bucket": "step", "step": 2, "t_start": 12.0, "dur_s": 1.0}],
        (0, 1): [{"bucket": "step", "step": 1, "t_start": 10.0, "dur_s": 1.5},
                 {"bucket": "init", "t_start": 0.0, "dur_s": 5.0}],
    }
    skew = step_boundary_skew(events)
    assert skew["num_steps"] == 1  # step 2 seen by one rank only
    assert skew["max_skew_s"] == pytest.approx(0.5)
    assert skew["max_skew_step"] == 1
    assert step_boundary_skew({})["num_steps"] == 0


# ------------------------------ overhead gate --------------------------------


def test_observability_overhead_under_budget(tmp_path):
    """Per-log-step instrumentation cost stays under an absolute 1ms —
    <1% of any real (100ms+) training step even at log_every_n=1.

    Asserted as an absolute bound on the full metrics-export path (all
    per-step gauges + histogram + MFU + delta flush into a real JSONL
    sink), measured in place during an instrumented run, NOT as an
    off-vs-on step-time A/B: on a sub-3ms toy CPU step under CI load the
    A/B delta is dominated by scheduler/GC noise and flakes either way
    (``bench_observability`` reports the exact interleaved-median delta,
    for an idle machine). A companion bound pins the tracer's per-span
    cost, so both halves of the hot path are enforced."""
    import statistics
    import time

    obs = ObservabilityConfig(metrics_path=str(tmp_path / "m.jsonl"),
                              trace_path=str(tmp_path / "t.json"))
    trainer = _tiny_trainer_cfg(steps=16, observability=obs).set(
        log_every_n=1).instantiate()
    costs = []
    orig = trainer._export_step_metrics

    def timed(*args, **kwargs):
        t0 = time.perf_counter()
        r = orig(*args, **kwargs)
        costs.append(time.perf_counter() - t0)
        return r

    trainer._export_step_metrics = timed
    trainer.run()
    assert len(costs) >= 15  # every step logged
    export_cost = statistics.median(costs)
    assert export_cost < 1e-3, (
        f"per-log-step metrics export {export_cost * 1e6:.0f}us exceeds "
        f"the 1ms budget (<1% of a real 100ms step)")

    tracer = trainer.observability.tracer
    t0 = time.perf_counter()
    for _ in range(1000):
        with tracer.span("budget_probe"):
            pass
    per_span = (time.perf_counter() - t0) / 1000
    assert per_span < 50e-6, (
        f"tracer span cost {per_span * 1e6:.1f}us exceeds 50us budget")
