"""Inference engine tests: generation, equivalence, continuous batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.module import functional
from repro.inference.engine import InferenceEngine, Request
from repro.layers import CausalLM, Decoder, Repeat, TransformerLayer


def _tiny_lm(vocab=48, dim=32, L=2, window=None):
    layer = TransformerLayer.default_config().set(input_dim=dim)
    layer.self_attention.set(num_heads=4, num_kv_heads=2, 
                             kv_cache_dtype=jnp.float32, sliding_window=window)
    layer.feed_forward.set(hidden_dim=dim * 2)
    return CausalLM.default_config().set(
        name="lm",
        decoder=Decoder.default_config().set(
            vocab_size=vocab, dim=dim,
            stack=Repeat.default_config().set(layer=layer, num_layers=L,
                                              remat_policy=None)))


def _engine(model_cfg, max_len=32, slots=4):
    cfg = InferenceEngine.default_config().set(
        name="engine", model=model_cfg, max_len=max_len, slots=slots)
    engine = cfg.instantiate()
    params = engine.model.initialize_parameters_recursively(jax.random.PRNGKey(0))
    engine.load(params)
    return engine, params


def test_generate_greedy_matches_manual_decode():
    engine, params = _engine(_tiny_lm())
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, 48))
    tokens, metrics = engine.generate(prompts, max_new_tokens=6)
    assert tokens.shape == (2, 6)
    assert metrics["ttft_s"] > 0 and metrics["tpot_s"] > 0

    # Manual greedy using full forward each step (teacher-forced replay).
    model = engine.model
    seq = prompts.copy()
    for step in range(6):
        logits, _ = functional(model, state=params,
                               inputs=({"input_ids": jnp.asarray(seq)},),
                               method="predict")
        nxt = np.asarray(jnp.argmax(logits[:, -1], -1))
        np.testing.assert_array_equal(nxt, tokens[:, step])
        seq = np.concatenate([seq, nxt[:, None]], axis=1)


def test_generate_with_sliding_window_cache():
    engine, _ = _engine(_tiny_lm(window=8), max_len=64)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 48))
    tokens, _ = engine.generate(prompts, max_new_tokens=4)
    assert tokens.shape == (2, 4)
    cache = engine.init_cache(2)
    # Bounded cache: enabler for long_500k decode.
    k_leaves = [v for k, v in cache.items()] if isinstance(cache, dict) else []
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    kv = [l for p, l in flat if "'k'" in jax.tree_util.keystr(p)]
    assert all(a.shape[-3] == 8 for a in kv if a.ndim == 4)


def test_continuous_batching_matches_batch_generate():
    """Slot-scheduled serving must produce the same greedy tokens as plain
    batched generation — scheduling is semantics-free."""
    engine, _ = _engine(_tiny_lm(), max_len=32, slots=2)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, 48, size=(5, 8))
    reqs = [Request(request_id=i, prompt=prompts[i], max_new_tokens=5)
            for i in range(5)]
    results = engine.serve(reqs)
    ref_tokens, _ = engine.generate(prompts, max_new_tokens=5)
    for i, res in enumerate(results):
        assert res.request_id == i
        np.testing.assert_array_equal(np.asarray(res.tokens),
                                      ref_tokens[i, :len(res.tokens)])
        assert res.ttft_s > 0


def test_continuous_batching_mixed_lengths():
    """Requests with different max_new_tokens: slots free up and admit new
    requests mid-flight; outputs still match batch generation."""
    engine, _ = _engine(_tiny_lm(), max_len=32, slots=2)
    rng = np.random.default_rng(1)
    prompts = rng.integers(0, 48, size=(4, 8))
    lens = [3, 7, 5, 2]
    reqs = [Request(request_id=i, prompt=prompts[i], max_new_tokens=lens[i])
            for i in range(4)]
    results = engine.serve(reqs)
    ref_tokens, _ = engine.generate(prompts, max_new_tokens=max(lens))
    for i, res in enumerate(results):
        assert len(res.tokens) == lens[i]
        np.testing.assert_array_equal(np.asarray(res.tokens), ref_tokens[i, :lens[i]])


def test_rwkv_engine_generation():
    """Attention-free arch through the same engine — unified serving."""
    from repro.layers.rwkv import RWKV6Block

    block = RWKV6Block.default_config().set(input_dim=32)
    block.time_mix.set(head_dim=16, decay_lora_dim=8)
    block.time_mix.kernel.set(wkv_chunk_size=4)
    block.channel_mix.set(hidden_dim=64)
    model = CausalLM.default_config().set(
        name="lm",
        decoder=Decoder.default_config().set(
            vocab_size=48, dim=32,
            stack=Repeat.default_config().set(layer=block, num_layers=2,
                                              remat_policy=None)))
    engine, _ = _engine(model, max_len=32)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0, 48))
    tokens, _ = engine.generate(prompts, max_new_tokens=4)
    assert tokens.shape == (2, 4)
