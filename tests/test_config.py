"""Unit + property tests for the config system (paper §4.1)."""

import copy

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.config import (
    REQUIRED,
    ConfigBase,
    Required,
    RequiredFieldMissingError,
    UnknownFieldError,
    config_class,
    config_for_class,
    config_for_function,
    config_to_dict,
    maybe_set,
    replace_config,
    visit_config,
)


@config_class
class _InnerCfg(ConfigBase):
    dim: Required[int] = REQUIRED
    scale: float = 1.0


@config_class
class _OuterCfg(ConfigBase):
    inner: _InnerCfg = _InnerCfg()
    n: int = 3
    tag: str = "x"


def test_set_and_clone():
    cfg = _OuterCfg()
    cfg.inner.dim = 8
    clone = cfg.clone(n=5)
    assert clone.n == 5 and cfg.n == 3
    clone.inner.dim = 16
    assert cfg.inner.dim == 8, "clone must deep-copy children"


def test_unknown_field_raises_with_suggestion():
    cfg = _OuterCfg()
    with pytest.raises(UnknownFieldError) as e:
        cfg.nn = 4
    assert "n" in str(e.value)


def test_required_tracking():
    cfg = _InnerCfg()
    assert cfg.required_fields_missing() == ["dim"]
    cfg.dim = 4
    assert cfg.required_fields_missing() == []


def test_default_isolation():
    """Mutable defaults (child configs) must not be shared across instances."""
    a, b = _OuterCfg(), _OuterCfg()
    a.inner.scale = 9.0
    assert b.inner.scale == 1.0


def test_maybe_set_only_fills_unset():
    cfg = _InnerCfg()
    maybe_set(cfg, dim=4, scale=2.0, nonexistent=1)
    assert cfg.dim == 4
    assert cfg.scale == 1.0  # already set -> untouched


def test_config_for_function():
    def make(a, b=2, *, c=3):
        return a + b + c

    cfg = config_for_function(make)
    assert cfg.required_fields_missing() == ["a"]
    cfg.a = 1
    assert cfg.instantiate() == 6
    cfg.c = 10
    assert cfg.instantiate() == 13


def test_config_for_function_missing_required():
    def make(a):
        return a

    with pytest.raises(RequiredFieldMissingError):
        config_for_function(make).instantiate()


def test_config_for_class():
    class Thing:
        def __init__(self, x, y=2):
            self.val = x * y

    cfg = config_for_class(Thing).set(x=3)
    assert cfg.instantiate().val == 6


def test_nested_instantiation_through_function_config():
    def inner(v):
        return v * 2

    def outer(child, offset=1):
        return child + offset

    cfg = config_for_function(outer)
    cfg.child = config_for_function(inner).set(v=5)
    assert cfg.instantiate() == 11


def test_visit_config_paths():
    cfg = _OuterCfg()
    seen = []
    visit_config(cfg, lambda path, c: seen.append((path, type(c).__name__)))
    assert ("", "_OuterCfg") in seen
    assert ("inner", "_InnerCfg") in seen


@config_class
class _AltInnerCfg(ConfigBase):
    dim: Required[int] = REQUIRED
    extra: int = 7


def test_replace_config_by_type_propagates_interface_fields():
    cfg = _OuterCfg()
    cfg.inner.dim = 32
    n = replace_config(
        cfg,
        target=_InnerCfg,
        new_cfg=_AltInnerCfg(),
        propagate=("dim",),
    )
    assert n == 1
    assert isinstance(cfg.inner, _AltInnerCfg)
    assert cfg.inner.dim == 32, "interface field must carry over"


def test_replace_config_in_lists():
    @config_class
    class StackCfg(ConfigBase):
        layers: list = []

    cfg = StackCfg()
    cfg.layers = [_InnerCfg().set(dim=1), _AltInnerCfg().set(dim=2), _InnerCfg().set(dim=3)]
    n = replace_config(cfg, target=_InnerCfg, new_cfg=_AltInnerCfg(), propagate=("dim",))
    assert n == 2
    assert all(isinstance(l, _AltInnerCfg) for l in cfg.layers)
    assert [l.dim for l in cfg.layers] == [1, 2, 3]


def test_replace_config_with_predicate_and_factory():
    cfg = _OuterCfg()
    cfg.inner.dim = 8
    replace_config(
        cfg,
        target=lambda c: isinstance(c, _InnerCfg) and c.dim == 8,
        new_cfg=lambda old: _AltInnerCfg().set(dim=old.dim * 2),
        propagate=(),
    )
    assert cfg.inner.dim == 16


def test_config_to_dict_golden_stability():
    cfg = _OuterCfg()
    cfg.inner.dim = 4
    d1 = config_to_dict(cfg)
    d2 = config_to_dict(copy.deepcopy(cfg))
    assert d1 == d2
    assert d1["inner"]["dim"] == 4
    assert d1["__type__"].endswith("_OuterCfg")


# --------------------------- property tests --------------------------------


@st.composite
def outer_cfgs(draw):
    cfg = _OuterCfg()
    cfg.n = draw(st.integers(-100, 100))
    cfg.tag = draw(st.text(max_size=8))
    cfg.inner.dim = draw(st.integers(1, 4096))
    cfg.inner.scale = draw(st.floats(allow_nan=False, allow_infinity=False, width=32))
    return cfg


@given(outer_cfgs())
@settings(max_examples=50, deadline=None)
def test_clone_roundtrip_property(cfg):
    clone = cfg.clone()
    assert clone == cfg
    assert config_to_dict(clone) == config_to_dict(cfg)


@given(outer_cfgs(), st.integers(1, 64))
@settings(max_examples=50, deadline=None)
def test_replace_is_idempotent_property(cfg, dim):
    cfg.inner.dim = dim
    n1 = replace_config(cfg, target=_InnerCfg, new_cfg=_AltInnerCfg(), propagate=("dim",))
    n2 = replace_config(cfg, target=_InnerCfg, new_cfg=_AltInnerCfg(), propagate=("dim",))
    assert n1 == 1 and n2 == 0
    assert cfg.inner.dim == dim
